"""Table 3: inference comparison — NAI vs vanilla SGC / GLNN / TinyGNN /
Quantization on four datasets. Metrics: ACC, total MACs/node, FP MACs/node,
time/node, FP time/node, plus acceleration ratios vs vanilla."""
from __future__ import annotations

import numpy as np

from benchmarks.common import K_FOR, csv_row, dataset, grid_search_ts, trained
from repro.gnn import NAIConfig, accuracy, infer_all
from repro.gnn.baselines import (run_glnn, run_quantized, run_tinygnn,
                                 run_vanilla)

DATASETS = ["pubmed-like", "flickr-like", "arxiv-like", "products-like"]


def run(datasets=DATASETS) -> list:
    rows = []
    for name in datasets:
        g = dataset(name)
        cfg, params, _ = trained(name)
        n_test = len(g.test_idx)

        van = run_vanilla(cfg, g, params)
        glnn = run_glnn(cfg, g, params["cls"][cfg.k], epochs=150)
        tiny = run_tinygnn(cfg, g, params["cls"][cfg.k], epochs=150)
        quant = run_quantized(cfg, g, params)

        # speed-first NAI (the paper's NAI_1): aggressive threshold
        ts = grid_search_ts(name)[3]
        nai = infer_all(cfg, NAIConfig(t_s=ts, t_min=1, t_max=2,
                                       batch_size=500), params, g)
        nai_acc = accuracy(nai, g)

        def us(t):
            return 1e6 * t / n_test

        rows += [
            csv_row(f"table3/{name}/SGC", us(van.time_s),
                    f"acc={van.acc:.4f};macs={van.macs:.0f};fp_macs={van.fp_macs:.0f}"),
            csv_row(f"table3/{name}/GLNN", us(glnn.time_s),
                    f"acc={glnn.acc:.4f};macs={glnn.macs:.0f};fp_macs=0"),
            csv_row(f"table3/{name}/TinyGNN", us(tiny.time_s),
                    f"acc={tiny.acc:.4f};macs={tiny.macs:.0f};fp_macs={tiny.fp_macs:.0f}"),
            csv_row(f"table3/{name}/Quantization", us(quant.time_s),
                    f"acc={quant.acc:.4f};macs={quant.macs:.0f};fp_macs={quant.fp_macs:.0f}"),
            csv_row(f"table3/{name}/NAI", us(nai.wall_time_s),
                    f"acc={nai_acc:.4f};macs={nai.total_macs:.0f};"
                    f"fp_macs={nai.fp_macs:.0f};"
                    f"macs_speedup={van.macs / max(nai.total_macs, 1):.1f}x;"
                    f"fp_speedup={van.fp_macs / max(nai.fp_macs, 1):.1f}x;"
                    f"time_speedup={van.time_s / max(nai.wall_time_s, 1e-9):.1f}x"),
        ]
    return rows
