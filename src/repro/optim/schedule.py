"""LR schedules (cosine / linear / constant with linear warmup)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.common import TrainConfig


def make_schedule(tc: TrainConfig):
    def schedule(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(tc.warmup_steps, 1), 1.0)
        frac = jnp.clip((s - tc.warmup_steps)
                        / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
                        0.0, 1.0)
        if tc.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif tc.schedule == "linear":
            decay = 1.0 - frac
        else:
            decay = 1.0
        return tc.learning_rate * warm * decay
    return schedule
