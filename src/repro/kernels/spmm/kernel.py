"""Block-ELL SpMM Pallas kernel with NAP row-block predication.

TPU adaptation of the paper's sparse feature propagation (DESIGN.md §3):
the adjacency is tiled into dense (RB, CB) coefficient tiles (block-ELL:
a fixed budget of `max_tb` tiles per row block, zero-padded). The kernel is
a block-sparse matmul driven by scalar-prefetched tile column indices — the
standard TPU pattern for data-dependent addressing (cf. megablox). NAP's
early exit feeds the `active` vector: a row block whose nodes have ALL
exited is skipped entirely (`@pl.when`), so saved compute scales with the
fraction of exited tiles — the paper's O(qmf) at tile granularity.

Grid: (row_blocks, feature_blocks, max_tiles_per_row_block); the tile loop
is innermost so the output block stays resident in VMEM while accumulating.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RB = 8      # rows per adjacency tile (sublane-aligned)
CB = 128    # cols per adjacency tile (lane-aligned)
FB = 128    # feature block


def _kernel(tile_col_ref, active_ref, valid_ref,   # scalar prefetch
            tiles_ref, x_ref, out_ref):
    rb = pl.program_id(0)
    t = pl.program_id(2)
    ntb = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    is_active = active_ref[rb] != 0
    is_valid = valid_ref[rb * ntb + t] != 0

    @pl.when(is_active & is_valid)
    def _acc():
        a = tiles_ref[0, 0]                      # (RB, CB)
        x = x_ref[...]                           # (CB, FB)
        out_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32
                                ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmm_block_ell(tiles, tile_col, valid, active, x, *, interpret=True):
    """tiles (n_rb, max_tb, RB, CB) f32 adjacency coefficient tiles;
    tile_col (n_rb, max_tb) int32 column-block index per tile;
    valid (n_rb, max_tb) int32 1 for real tiles, 0 for padding;
    active (n_rb,) int32 NAP row-block predicate;
    x (n_cb*CB, F) features (F % FB == 0).
    Returns out (n_rb*RB, F)."""
    n_rb, max_tb = tile_col.shape
    n, F = x.shape
    assert n % CB == 0 and F % FB == 0, (n, F)

    grid = (n_rb, F // FB, max_tb)
    flat_cols = tile_col.reshape(-1).astype(jnp.int32)
    flat_valid = valid.reshape(-1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, RB, CB), lambda rb, fb, t, *_: (rb, t, 0, 0)),
            pl.BlockSpec((CB, FB),
                         lambda rb, fb, t, cols, active, valid_s: (cols[rb * pl.num_programs(2) + t], fb)),
        ],
        out_specs=pl.BlockSpec((RB, FB), lambda rb, fb, t, *_: (rb, fb)),
    )
    out_shape = jax.ShapeDtypeStruct((n_rb * RB, F), x.dtype)
    fn = pl.pallas_call(_kernel, grid_spec=grid_spec, out_shape=out_shape,
                        interpret=interpret)
    return fn(flat_cols, active.astype(jnp.int32), flat_valid, tiles, x)
