"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
experiments/dryrun/*.json. Manual sections (§Perf narrative, §Paper-repro)
live in EXPERIMENTS.md outside the AUTOGEN markers and are preserved."""
from __future__ import annotations

import glob
import json
import os
import re

DIR = "experiments/dryrun"
MD = "EXPERIMENTS.md"
BEGIN = "<!-- AUTOGEN:DRYRUN BEGIN -->"
END = "<!-- AUTOGEN:DRYRUN END -->"

ARCH_ORDER = ["granite-34b", "deepseek-coder-33b", "whisper-small",
              "gemma-7b", "recurrentgemma-9b", "mistral-large-123b",
              "grok-1-314b", "rwkv6-3b", "dbrx-132b", "llama-3.2-vision-11b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(b) >= div:
            return f"{b / div:.2f}{unit}"
    return f"{b:.0f}B"


def fmt_ms(s):
    return f"{1e3 * s:.2f}"


def load():
    recs = {}
    for p in glob.glob(os.path.join(DIR, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs, mesh):
    lines = [
        f"### Mesh {mesh} ({'512' if mesh == '2x16x16' else '256'} chips)",
        "",
        "| arch | shape | mode | lower s | compile s | params | arg bytes | temp bytes | HLO FLOPs (global) | collectives/chip |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if not r:
                continue
            t = r["roofline"]
            coll = {k.split("-")[1] if "-" in k else k: fmt_bytes(v)
                    for k, v in t["collectives"].items() if v}
            coll_s = ", ".join(f"{k}={v}" for k, v in sorted(coll.items())) or "-"
            lines.append(
                f"| {a} | {s} | {r['mode']} | {r['lower_s']} | "
                f"{r.get('compile_s', '-')} | {r['params'] / 1e9:.1f}B | "
                f"{fmt_bytes(r['memory']['argument_bytes'])} | "
                f"{fmt_bytes(r['memory']['temp_bytes'])} | "
                f"{t['hlo_flops']:.3e} | {coll_s} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "Single-pod (16x16 = 256 chips) roofline terms per step, TPU v5e "
        "constants (197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI/link). "
        "t_* in ms; dominant term bold; `useful` = MODEL_FLOPS / HLO_FLOPs.",
        "",
        "| arch | shape | t_compute | t_memory | t_collective | dominant | useful | bottleneck note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        "compute": "MXU-bound; increase arithmetic intensity only",
        "memory": "HBM traffic bound: fuse/remat-tune, cut activation round-trips, bf16 stats",
        "collective": "ICI bound: resharding or gradient all-reduce dominates; change layout/overlap",
    }
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "16x16"))
            if not r:
                continue
            t = r["roofline"]
            vals = {"compute": t["t_compute_s"], "memory": t["t_memory_s"],
                    "collective": t["t_collective_s"]}
            cells = {k: fmt_ms(v) for k, v in vals.items()}
            cells[t["dominant"]] = f"**{cells[t['dominant']]}**"
            lines.append(
                f"| {a} | {s} | {cells['compute']} | {cells['memory']} | "
                f"{cells['collective']} | {t['dominant']} | "
                f"{t['useful_ratio']:.2f} | {notes[t['dominant']]} |")
    return "\n".join(lines)


def main():
    recs = load()
    n1 = sum(1 for k in recs if k[2] == "16x16")
    n2 = sum(1 for k in recs if k[2] == "2x16x16")
    body = [
        BEGIN,
        "",
        f"## §Dry-run ({n1} single-pod + {n2} multi-pod combos, all compiled OK)",
        "",
        "Every (architecture x input shape) lowers AND compiles for both "
        "production meshes. `train_4k` lowers the full train step (fwd + bwd "
        "+ AdamW); `prefill_32k` the prefill (last logits + KV caches); "
        "decode shapes the single-token `serve_step` with materialized KV "
        "cache (full-attention archs serve `long_500k` through the "
        "sliding-window variant, window 4096 — DESIGN.md §4).",
        "",
        dryrun_table(recs, "16x16"),
        "",
        dryrun_table(recs, "2x16x16"),
        "",
        "## §Roofline",
        "",
        roofline_table(recs),
        "",
        END,
    ]
    text = open(MD).read() if os.path.exists(MD) else "# EXPERIMENTS\n\n" + BEGIN + "\n" + END + "\n"
    pattern = re.compile(re.escape(BEGIN) + ".*?" + re.escape(END), re.S)
    if pattern.search(text):
        text = pattern.sub("\n".join(body), text)
    else:
        text += "\n" + "\n".join(body) + "\n"
    open(MD, "w").write(text)
    print(f"wrote {MD}: {n1}+{n2} records")


if __name__ == "__main__":
    main()
