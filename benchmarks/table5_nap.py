"""Table 5: NAP ablation — NAI vs 'NAI w/o NAP' (fixed propagation order)
for T_max in 2..k, with node exit-order distributions."""
from __future__ import annotations


from benchmarks.common import csv_row, dataset, grid_search_ts, trained
from repro.gnn import NAIConfig, accuracy, infer_all, order_distribution

DATASETS = ["arxiv-like", "products-like"]


def run(datasets=DATASETS) -> list:
    rows = []
    for name in datasets:
        g = dataset(name)
        cfg, params, _ = trained(name)
        ts = grid_search_ts(name)[2]
        for t_max in range(2, cfg.k + 1):
            # NAI w/o NAP: T_s = 0 -> every node propagates exactly t_max
            off = infer_all(cfg, NAIConfig(t_s=0.0, t_min=1, t_max=t_max,
                                           batch_size=500), params, g)
            on = infer_all(cfg, NAIConfig(t_s=ts, t_min=1, t_max=t_max,
                                          batch_size=500), params, g)
            n = len(g.test_idx)
            rows += [
                csv_row(f"table5/{name}/Tmax{t_max}/wo_NAP",
                        1e6 * off.wall_time_s / n,
                        f"acc={accuracy(off, g):.4f};"
                        f"dist={list(order_distribution(off, cfg.k))}"),
                csv_row(f"table5/{name}/Tmax{t_max}/NAI",
                        1e6 * on.wall_time_s / n,
                        f"acc={accuracy(on, g):.4f};"
                        f"fp_macs={on.fp_macs:.0f};"
                        f"dist={list(order_distribution(on, cfg.k))}"),
            ]
    return rows
