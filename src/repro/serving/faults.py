"""Deterministic fault injection for the serving stack.

The serving path is four failure domains deep — MmapStore reads, the
host sample/pack stage, the pipelined device NAP stage, and the SLO
front-end — and each used to assume the previous one succeeds. This
module provides the CHAOS side of the failure story: a seeded,
replayable schedule of faults (`FaultPlan`) that the engine and a
`FaultyStore` wrapper consult at well-defined injection points, so the
isolation machinery (typed store errors, per-batch failure, NaN guard,
watchdog, circuit breaker) can be exercised and GATED in CI instead of
waiting for production to exercise it.

Design rules:

* **Deterministic.** A `FaultPlan` is pure data; `plan.injector()`
  mints a fresh `FaultInjector` whose draws come from
  `np.random.default_rng([seed, stage_index])` and whose positional
  triggers (`at=`) count events per stage from injector birth. The same
  plan driven through the same request stream fires the same faults —
  chaos_bench's conservation gate is reproducible, and a failing seed is
  a bug report, not a flake.
* **Injection points, not monkeypatches.** The engine asks
  ``injector.fire(stage)`` at each stage boundary; the store wrapper
  does the same around ``gather_features``. Nothing in the fault layer
  reaches into engine internals, so a fault-free plan (or no plan) is
  bit-identical to not having the layer at all.
* **Typed errors.** Injected failures raise `InjectedFault`; the
  engine's guards raise `NaNGuardError` / `WatchdogTimeout`. Request
  `error` strings carry the type name, so tests and benches can assert
  WHICH domain failed.

Stages (event counter = one tick per served batch, or per gather call
for the store stages):

    ``store_read``     gather raises StoreIOError (transient read fail)
    ``store_latency``  gather sleeps ``delay_s`` first (slow disk)
    ``host``           host sample/pack stage raises
    ``device``         device dispatch raises
    ``nan``            device results poisoned with NaN (bad logits)
    ``hang``           device results never become ready (hung sync)
    ``slow``           host stage sleeps ``delay_s`` (straggler batch)

Offline full-graph inference stages (repro.launch.full_graph_infer;
event counter = one tick per checkpoint write / checkpoint read /
dispatched superstep attempt):

    ``ckpt_write``     checkpoint payload written but the manifest
                       commit raises (crash mid-checkpoint)
    ``ckpt_read``      a committed checkpoint reads back corrupt
                       (typed CheckpointCorruption from the manager)
    ``superstep_hang`` a dispatched superstep is declared hung — the
                       driver's per-superstep watchdog retries it

New stages are APPENDED to `STAGES`: rng streams are seeded by stage
index (``[seed, i]``), so inserting in the middle would silently
re-deal every existing plan's random draws.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.gnn.store import GraphStore, StoreIOError

STAGES = ("store_read", "store_latency", "host", "device", "nan",
          "hang", "slow", "ckpt_write", "ckpt_read", "superstep_hang")


class InjectedFault(RuntimeError):
    """An artificial failure raised at a FaultPlan injection point."""


class NaNGuardError(RuntimeError):
    """Device results failed the finite/range guard — the batch is
    failed rather than letting garbage reach a completed Request."""


class WatchdogTimeout(RuntimeError):
    """A device sync exceeded the engine watchdog deadline — the batch
    is declared hung and failed so the pipeline can re-arm."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault rule: fire at `stage` either randomly (`rate` per
    event) or positionally (`at` = event indices), at most `max_fires`
    times. `delay_s` parameterizes the latency stages."""
    stage: str
    rate: float = 0.0
    at: Tuple[int, ...] = ()
    delay_s: float = 0.0
    max_fires: int = -1          # -1 = unbounded

    def __post_init__(self):
        if self.stage not in STAGES:
            raise ValueError(f"unknown fault stage {self.stage!r} "
                             f"(expected one of {STAGES})")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.delay_s < 0.0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        if any(i < 0 for i in self.at):
            raise ValueError(f"at indices must be >= 0, got {self.at}")
        object.__setattr__(self, "at", tuple(int(i) for i in self.at))


class FaultPlan:
    """An immutable schedule of `FaultSpec`s plus the seed that makes it
    deterministic. Plans are shareable; per-run mutable state lives in
    the `FaultInjector` minted by `injector()`."""

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)

    @property
    def empty(self) -> bool:
        return not self.specs

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    def describe(self) -> List[Dict]:
        """JSON-able summary (recorded into bench payloads)."""
        return [dataclasses.asdict(s) for s in self.specs]

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, specs={list(self.specs)!r})"


class FaultInjector:
    """Per-run mutable state of a `FaultPlan`: one event counter and one
    seeded rng stream per stage, plus `fired` tallies for benches.

    `fire(stage)` advances that stage's event counter by exactly one and
    draws exactly one uniform per rate-spec on that stage, REGARDLESS of
    whether anything fires — so firing decisions at event k never depend
    on what happened at events < k, and two injectors from the same plan
    agree event-for-event."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_stage: Dict[str, List[FaultSpec]] = {s: [] for s in STAGES}
        for spec in plan.specs:
            self._by_stage[spec.stage].append(spec)
        self._rng = {s: np.random.default_rng([plan.seed, i])
                     for i, s in enumerate(STAGES)}
        self._events = {s: 0 for s in STAGES}
        self._spec_fires: Dict[int, int] = {}
        self.fired: Dict[str, int] = {s: 0 for s in STAGES}

    def events(self, stage: str) -> int:
        return self._events[stage]

    def fire(self, stage: str) -> Optional[FaultSpec]:
        """Advance `stage`'s event counter; return the first spec that
        fires at this event (None if none do)."""
        i = self._events[stage]
        self._events[stage] = i + 1
        hit: Optional[FaultSpec] = None
        rng = self._rng[stage]
        for si, spec in enumerate(self._by_stage[stage]):
            fires = i in spec.at
            if spec.rate > 0.0:
                # always draw, even after a positional hit: keeps the
                # stream aligned across plans that differ only in `at`
                fires = (rng.random() < spec.rate) or fires
            if not fires or hit is not None:
                continue
            key = id(spec) ^ si
            count = self._spec_fires.get(key, 0)
            if spec.max_fires >= 0 and count >= spec.max_fires:
                continue
            self._spec_fires[key] = count + 1
            self.fired[stage] += 1
            hit = spec
        return hit

    def summary(self) -> Dict[str, Dict[str, int]]:
        return {s: {"events": self._events[s], "fired": self.fired[s]}
                for s in STAGES if self._events[s] or self.fired[s]}


class _HungResult:
    """Stand-in for a device array that never becomes ready. The engine
    watchdog polls `is_ready()`; if no watchdog is armed, the eventual
    forced sync raises instead of blocking the process forever (the
    injection must never deadlock the harness itself)."""

    def is_ready(self) -> bool:
        return False

    def __array__(self, dtype=None, copy=None):
        raise InjectedFault(
            "sync of a hung device batch (arm EngineConfig.watchdog_s "
            "to detect hangs without blocking)")


def poison_results(injector: Optional[FaultInjector], preds, orders):
    """Post-dispatch injection point: replace device results with NaN
    payloads (``nan`` stage — simulating non-finite logits out of the
    backend) or never-ready futures (``hang`` stage). Called by the
    engine on every dispatched batch so event counters stay aligned."""
    if injector is None:
        return preds, orders
    spec = injector.fire("nan")
    if spec is not None:
        shape = tuple(getattr(preds, "shape", ())) or (1,)
        bad = np.full(shape, np.nan, np.float32)
        return bad, np.full(tuple(getattr(orders, "shape", ())) or (1,),
                            np.nan, np.float32)
    if injector.fire("hang") is not None:
        return _HungResult(), _HungResult()
    return preds, orders


class FaultyStore(GraphStore):
    """Delegating `GraphStore` wrapper that injects storage faults in
    front of an inner store: ``store_read`` raises a typed
    `StoreIOError` (as an exhausted-retry read would), ``store_latency``
    sleeps `delay_s` before the real gather (slow disk). Everything else
    delegates, so a plan with no store specs is the inner store."""

    def __init__(self, inner: GraphStore, injector: FaultInjector):
        self.inner = inner
        self.injector = injector
        self.name = inner.name
        self.n = inner.n
        self.feat_dim = inner.feat_dim
        self.num_classes = inner.num_classes
        self.num_edges = inner.num_edges
        self.num_self_loops = inner.num_self_loops

    @property
    def row_ptr(self) -> np.ndarray:
        return self.inner.row_ptr

    @property
    def col_idx(self) -> np.ndarray:
        return self.inner.col_idx

    @property
    def features(self) -> np.ndarray:
        return self.inner.features

    @property
    def degrees(self) -> np.ndarray:
        return self.inner.degrees

    @property
    def labels(self):
        return self.inner.labels

    def gather_features(self, nodes: np.ndarray) -> np.ndarray:
        spec = self.injector.fire("store_latency")
        if spec is not None and spec.delay_s > 0.0:
            time.sleep(spec.delay_s)
        if self.injector.fire("store_read") is not None:
            raise StoreIOError(
                f"injected read failure on {self.name} "
                f"(gather event {self.injector.events('store_read') - 1})")
        return self.inner.gather_features(nodes)

    def drop_resident(self) -> int:
        return self.inner.drop_resident()

    def close(self) -> None:
        self.inner.close()
