"""Benchmark entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table3,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets, fewer epochs")
    ap.add_argument("--only", default="",
                    help="comma list: table3,table5,table6,table7,fig2,fig3,"
                         "roofline,kernels,ablation,serving,"
                         "serving_sharded,frontend,chaos,offline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    if args.quick:
        import dataclasses
        import benchmarks.common as C
        C.SCALES = {k: min(v, 0.05) for k, v in C.SCALES.items()}
        C.SCALES["products-like"] = 0.001
        C._DC = dataclasses.replace(C._DC, epochs_base=60, epochs_offline=30,
                                    epochs_online=30)

    suites = []
    if only is None or "table3" in only:
        from benchmarks.table3_inference import run as t3
        suites.append(("table3", t3))
    if only is None or "table5" in only:
        from benchmarks.table5_nap import run as t5
        suites.append(("table5", t5))
    if only is None or "table6" in only:
        from benchmarks.table6_distill import run as t6
        suites.append(("table6", t6))
    if only is None or "table7" in only:
        from benchmarks.table7_generalization import run as t7
        suites.append(("table7", t7))
    if only is None or "fig2" in only:
        from benchmarks.fig2_tradeoff import run as f2
        suites.append(("fig2", f2))
    if only is None or "fig3" in only:
        from benchmarks.fig3_sensitivity import run as f3
        suites.append(("fig3", f3))
    if only is None or "roofline" in only:
        from benchmarks.roofline_report import run as rl
        suites.append(("roofline", rl))
    if only is None or "kernels" in only:
        from benchmarks.kernel_bench import run as kb
        suites.append(("kernels", kb))
    if only is None or "ablation" in only:
        from benchmarks.ablation_batch import run as ab
        suites.append(("ablation", ab))
    if only is None or "serving" in only:
        from benchmarks.serving_bench import run as sb
        suites.append(("serving", sb))
    if only is None or "serving_sharded" in only:
        from benchmarks.serving_bench import run_sharded as sbs
        suites.append(("serving_sharded", sbs))
    if only is None or "frontend" in only:
        from benchmarks.frontend_bench import run as fb
        suites.append(("frontend", fb))
    if only is None or "chaos" in only:
        from benchmarks.chaos_bench import run as cb
        suites.append(("chaos", cb))
    if only is None or "offline" in only:
        from benchmarks.full_graph_infer_bench import run as ob
        suites.append(("offline", ob))

    print("name,us_per_call,derived")
    failures = 0
    for tag, fn in suites:
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
        except Exception:
            traceback.print_exc()
            failures += 1
        print(f"# {tag} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
