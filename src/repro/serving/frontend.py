"""Deadline-aware serving front-end with SLO classes.

Production traffic is a Poisson stream of single requests with
heterogeneous deadlines — not the pre-formed fixed-size batches the
engine's closed-loop benchmarks feed it. The front-end turns the former
into the latter:

* **Request queue with backpressure** — each SLO class owns a bounded
  lane (`queue_depth`); a submit beyond the bound is rejected (shed)
  immediately instead of queued into a certain deadline miss. Shedding
  keeps the queueing delay of every ACCEPTED request bounded by
  roughly `queue_depth / service_rate`, which is what lets goodput track
  throughput under overload instead of collapsing.

* **Deadline-aware batch former** — dispatch rides the engine's
  `form_batch`: a batch closes on size OR age, whichever fires first
  (a full `batch_size` immediately; a partial batch once its oldest
  request has waited the class's `max_wait_s` — unconditionally, with
  no minimum-fill guard). `step(now)` polls every lane; quiet ticks
  advance the engine pipelines non-blockingly, so `pipeline_depth=2`
  engines keep their host/device overlap under bursty arrivals.

* **SLO classes** — the paper's deployment claim is that "the trade-off
  between accuracy and inference latency can be flexibly controlled by
  simple hyper-parameters to match different latency constraints of
  application scenarios": T_max/T_min are those hyper-parameters, and
  the front-end turns them into per-request latency tiers. Each class
  (e.g. ``gold`` / ``best_effort``) routes to its own
  `NAIServingEngine` compiled at the class's `NAIConfig` — gold at a
  high T_max (full accuracy, more propagation), best-effort at a low
  one (cheap, fast) — while the {1,2,3}·2^k bucket policy keeps each
  engine's compiled-shape set small. A request's class picks its
  engine; its deadline (class default or per-request override) is
  carried on the `Request` and scored at completion.

**Goodput** — answers delivered within their deadline — is the
front-end's currency: `ClassStats` counts offered / accepted / rejected
/ completed / deadline hits+misses per class, and `summary()` merges
those with the per-engine latency percentiles. `benchmarks/
frontend_bench.py` sweeps offered load open-loop and records the
goodput-vs-load curve into BENCH_serving.json.

Every method takes an optional ``now`` so the whole front-end can run on
a virtual clock: batch formation then depends only on the submitted
timestamps, making runs deterministic — the property the parity tests
(front-end == direct engine serving, pipelined == serial) and the
zero-steady-state-compile gates are built on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.gnn.nai import NAIConfig
from repro.serving.engine import (EngineConfig, NAIServingEngine, Request)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One latency tier: a name, the engine config it compiles
    (the T_max knob), its default per-request latency budget, the batch
    former's age bound, and the backpressure depth of its lane.

    ``engine`` optionally pins a full per-class `EngineConfig` (e.g. a
    different spmm_impl or pipeline depth per tier); classes that leave
    it None inherit the front-end's base config. Either way the class's
    ``max_wait_s`` overrides the config's age bound — the SLO class owns
    its latency knobs."""
    name: str
    nai: NAIConfig
    deadline_s: float            # default latency budget per request
    max_wait_s: float            # close a partial batch at this age
    queue_depth: int = 256       # reject (shed) submits beyond this
    engine: Optional[EngineConfig] = None   # per-class engine override
    demote_to: Optional[str] = None   # breaker-open fallback class

    def __post_init__(self):
        if not self.name:
            raise ValueError("SLO class needs a non-empty name")
        if self.deadline_s <= 0:
            raise ValueError(f"{self.name}: deadline_s must be > 0, "
                             f"got {self.deadline_s}")
        if self.max_wait_s < 0:
            raise ValueError(f"{self.name}: max_wait_s must be >= 0, "
                             f"got {self.max_wait_s}")
        if self.queue_depth < 1:
            raise ValueError(f"{self.name}: queue_depth must be >= 1, "
                             f"got {self.queue_depth}")


def default_slo_classes(base: NAIConfig, *, gold_deadline_s: float = 0.5,
                        best_effort_deadline_s: float = 0.2,
                        gold_max_wait_s: float = 0.05,
                        best_effort_max_wait_s: float = 0.02,
                        queue_depth: Optional[int] = None
                        ) -> Sequence[SLOClass]:
    """The two-tier default: ``gold`` serves at the base config's full
    T_max (accuracy tier), ``best_effort`` at T_max = T_min (cheapest
    compiled shape, fastest answer). Both reuse the base batch size so
    their bucket series coincide."""
    qd = queue_depth if queue_depth is not None else 4 * base.batch_size
    return (
        SLOClass("gold", base, deadline_s=gold_deadline_s,
                 max_wait_s=gold_max_wait_s, queue_depth=qd,
                 demote_to="best_effort"),
        SLOClass("best_effort",
                 dataclasses.replace(base, t_max=base.t_min),
                 deadline_s=best_effort_deadline_s,
                 max_wait_s=best_effort_max_wait_s, queue_depth=qd),
    )


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Per-class circuit-breaker policy (shared by every class of a
    front-end that installs one). The breaker watches TERMINAL outcomes
    — failures, plus deadline misses when `count_misses` — over a
    sliding window and trips when the bad fraction is sustained."""
    window: int = 32             # sliding window of terminal outcomes
    trip_frac: float = 0.5       # bad fraction that opens the breaker
    min_events: int = 16         # don't trip on a near-empty window
    cooldown_s: float = 1.0      # open -> half_open after this long
    probes: int = 3              # half_open: successes needed to close
    open_depth_frac: float = 0.5     # lane-depth scale while not closed
    count_misses: bool = True    # deadline misses count as bad outcomes

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if not 0.0 < self.trip_frac <= 1.0:
            raise ValueError(f"trip_frac must be in (0, 1], got "
                             f"{self.trip_frac}")
        if not 1 <= self.min_events <= self.window:
            raise ValueError(f"min_events must be in [1, window], got "
                             f"{self.min_events}")
        if self.cooldown_s <= 0:
            raise ValueError(f"cooldown_s must be > 0, got "
                             f"{self.cooldown_s}")
        if self.probes < 1:
            raise ValueError(f"probes must be >= 1, got {self.probes}")
        if not 0.0 < self.open_depth_frac <= 1.0:
            raise ValueError(f"open_depth_frac must be in (0, 1], got "
                             f"{self.open_depth_frac}")


class CircuitBreaker:
    """closed -> open -> half_open -> closed, driven by terminal request
    outcomes on one SLO class.

    *closed*: all traffic routes natively; a sustained bad fraction
    (`trip_frac` over the last `window` outcomes, at least `min_events`
    of them) OPENS the breaker.
    *open*: no native traffic — the front-end demotes to the class's
    `demote_to` engine (already compiled at its T_min shape) or sheds,
    and sheds earlier either way (`open_depth_frac` lane bound). After
    `cooldown_s` the next routing decision moves to half_open.
    *half_open*: up to `probes` requests route natively as probes; any
    probe failing re-opens (fresh cooldown), `probes` successes close.

    Transitions are recorded as ``(t, from, to)`` — the observable
    chaos_bench gates on."""

    def __init__(self, cfg: BreakerConfig):
        self.cfg = cfg
        self.state = "closed"
        self.trips = 0
        self.transitions: List[Tuple[float, str, str]] = []
        self._events = deque(maxlen=cfg.window)
        self._opened_at = 0.0
        self._probes_out = 0
        self._probe_ok = 0

    def _to(self, state: str, now: float) -> None:
        self.transitions.append((now, self.state, state))
        self.state = state
        if state == "open":
            self.trips += 1
            self._opened_at = now
            self._probes_out = 0
            self._probe_ok = 0
            self._events.clear()
        elif state == "closed":
            self._events.clear()

    def route(self, now: float) -> str:
        """Routing decision for one submit: ``"native"`` | ``"probe"``
        | ``"reroute"``. Also where open ages into half_open."""
        if (self.state == "open"
                and now - self._opened_at >= self.cfg.cooldown_s):
            self._to("half_open", now)
        if self.state == "closed":
            return "native"
        if (self.state == "half_open"
                and self._probes_out < self.cfg.probes):
            self._probes_out += 1
            return "probe"
        return "reroute"

    def on_terminal(self, bad: bool, probe: bool, now: float) -> None:
        """Feed one terminal outcome (completion, failure, or
        deadline-scored completion) back into the state machine."""
        if probe:
            if self.state != "half_open":
                return            # stale probe from before a transition
            if bad:
                self._to("open", now)
                return
            self._probe_ok += 1
            if self._probe_ok >= self.cfg.probes:
                self._to("closed", now)
            return
        if self.state != "closed":
            return                # outcomes of pre-trip traffic draining
        self._events.append(bool(bad))
        if (len(self._events) >= self.cfg.min_events
                and sum(self._events)
                >= self.cfg.trip_frac * len(self._events)):
            self._to("open", now)


@dataclasses.dataclass
class ClassStats:
    offered: int = 0          # every submit attempt
    accepted: int = 0         # made it past backpressure
    rejected: int = 0         # shed at submit (lane full / breaker open)
    completed: int = 0
    deadline_hits: int = 0    # completed within budget (goodput)
    deadline_misses: int = 0
    failed: int = 0           # terminal status="failed" (batch fault)
    retried: int = 0          # completed via the engine's reference path
    degraded: int = 0         # accepted onto the demote_to engine

    def summary(self) -> Dict[str, float]:
        return {
            "offered": self.offered, "accepted": self.accepted,
            "rejected": self.rejected, "completed": self.completed,
            "deadline_hits": self.deadline_hits,
            "deadline_misses": self.deadline_misses,
            "failed": self.failed, "retried": self.retried,
            "degraded": self.degraded,
            "goodput_frac": self.deadline_hits / max(self.offered, 1),
        }


class ServingFrontend:
    """Routes single requests into per-SLO-class `NAIServingEngine`s.

    ``classes`` is an ordered sequence of `SLOClass`; the first is the
    default routing target. The base engine configuration comes either
    as one ``engine=EngineConfig(...)`` or as the legacy keyword
    arguments (``mode=``, ``spmm_impl=``, ``mesh=``, ...) — not both.
    Each class engine gets the base config (or the class's own
    ``engine`` override) with the class's `NAIConfig` and `max_wait_s`
    substituted in, so per-SLO-class engine configs are declarative.
    """

    def __init__(self, cfg, params, graph,
                 classes: Sequence[SLOClass], *,
                 engine: Optional[EngineConfig] = None,
                 breaker: Optional[BreakerConfig] = None,
                 mode: str = "compiled", pipeline_depth: int = 1,
                 latency_window: int = 4096, **engine_kwargs):
        if not classes:
            raise ValueError("need at least one SLO class")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO class names: {names}")
        for c in classes:
            if c.demote_to is not None and (c.demote_to not in names
                                            or c.demote_to == c.name):
                raise ValueError(
                    f"{c.name}: demote_to={c.demote_to!r} must name a "
                    f"DIFFERENT class of this front-end ({names})")
        if engine is not None and engine_kwargs:
            raise ValueError(
                f"pass either engine=EngineConfig(...) or engine kwargs, "
                f"not both (got kwargs {sorted(engine_kwargs)})")
        base = engine if engine is not None else EngineConfig(
            mode=mode, pipeline_depth=pipeline_depth,
            latency_window=latency_window, **engine_kwargs)
        self.classes: Dict[str, SLOClass] = {c.name: c for c in classes}
        self.default_class = classes[0].name
        self.engine_config = base
        self.pipeline_depth = base.pipeline_depth
        self.engines: Dict[str, NAIServingEngine] = {
            c.name: NAIServingEngine(
                cfg, c.nai, params, graph,
                config=dataclasses.replace(
                    c.engine if c.engine is not None else base,
                    max_wait_s=c.max_wait_s))
            for c in classes}
        self.stats: Dict[str, ClassStats] = {
            c.name: ClassStats() for c in classes}
        # one breaker per class when a policy is installed (None keeps
        # the pre-breaker routing byte-for-byte: no state, no draws)
        self.breaker_config = breaker
        self.breakers: Dict[str, CircuitBreaker] = (
            {c.name: CircuitBreaker(breaker) for c in classes}
            if breaker is not None else {})

    # ---------------------------------------------------------- ingress
    def submit(self, node_id: int, slo_class: Optional[str] = None,
               now: Optional[float] = None,
               budget_s: Optional[float] = None) -> Optional[Request]:
        """Route one request into its class lane. Returns the `Request`
        if accepted, None if shed by backpressure (lane at
        `queue_depth`). ``budget_s`` overrides the class's default
        latency budget; the absolute deadline is stamped on the request
        as ``arrival + budget``."""
        name = self.default_class if slo_class is None else slo_class
        if name not in self.classes:
            raise KeyError(f"unknown SLO class {name!r} "
                           f"(one of {sorted(self.classes)})")
        c, eng, st = self.classes[name], self.engines[name], self.stats[name]
        # validate BEFORE any accounting: a malformed id is the caller's
        # error (raised), not an offered-and-shed request
        nid = eng._validate_node_id(node_id)
        now = time.perf_counter() if now is None else now
        st.offered += 1
        probe = degraded = False
        depth = c.queue_depth
        br = self.breakers.get(name)
        if br is not None:
            route = br.route(now)
            if route == "probe":
                probe = True
            elif route == "reroute":
                if c.demote_to is None:
                    # nowhere to degrade to: the open breaker sheds
                    st.rejected += 1
                    return None
                # demote onto the fallback engine (already compiled at
                # its own — cheaper — shapes), with an earlier shed
                # bound so a tripped class can't flood its fallback
                eng = self.engines[c.demote_to]
                depth = max(1, int(self.classes[c.demote_to].queue_depth
                                   * br.cfg.open_depth_frac))
                degraded = True
        if len(eng.queue) >= depth:
            st.rejected += 1
            return None
        budget = c.deadline_s if budget_s is None else budget_s
        req = Request(nid, now, deadline_s=now + budget,
                      slo_class=name, probe=probe, degraded=degraded)
        eng.submit_request(req)
        st.accepted += 1
        if degraded:
            st.degraded += 1
        return req

    # ----------------------------------------------------------- egress
    def _account(self, terminal: List[Request],
                 now: Optional[float] = None) -> List[Request]:
        """Score terminal requests into their ORIGIN class's stats
        (demoted requests keep their class tag) and feed the outcomes to
        that class's breaker."""
        if terminal and now is None:
            now = time.perf_counter()
        for r in terminal:
            st = self.stats[r.slo_class]
            if r.status == "failed":
                st.failed += 1
                bad = True
            else:
                st.completed += 1
                if r.retried:
                    st.retried += 1
                if r.within_deadline:
                    st.deadline_hits += 1
                    bad = False
                else:
                    st.deadline_misses += 1
                    bad = self.breaker_config.count_misses \
                        if self.breaker_config is not None else False
            br = self.breakers.get(r.slo_class)
            if br is not None:
                br.on_terminal(bad, r.probe, now)
        return terminal

    def step(self, now: Optional[float] = None) -> List[Request]:
        """Poll every class lane once: dispatch batches the former has
        closed (size or age), advance pipelines non-blockingly
        otherwise. Returns newly terminal requests across classes."""
        done: List[Request] = []
        for eng in self.engines.values():
            done += self._account(eng.poll(now), now)
        return done

    def flush(self, now: Optional[float] = None) -> List[Request]:
        """Explicit drain: force-close every partial batch still queued,
        then sync every in-flight batch. The end-of-stream path — never
        called on the hot serving loop."""
        done: List[Request] = []
        for eng in self.engines.values():
            while eng.queue:
                done += self._account(eng.step(), now)
            done += self._account(eng.flush(), now)
        return done

    # ------------------------------------------------------------ stats
    def pending(self) -> int:
        """Requests accepted but not yet terminal (queued + in flight)."""
        return sum(len(eng.queue)
                   + sum(len(fl.requests) for fl in eng._inflight)
                   for eng in self.engines.values())

    def pending_by_class(self) -> Dict[str, int]:
        """Pending counts keyed by ORIGIN class (demoted requests sit in
        their fallback engine but count against the class that accepted
        them — the per-class conservation ledger chaos_bench gates:
        offered == rejected + completed + failed + pending)."""
        out = {name: 0 for name in self.classes}
        for eng in self.engines.values():
            for r in eng.queue:
                out[r.slo_class] += 1
            for fl in eng._inflight:
                for r in fl.requests:
                    out[r.slo_class] += 1
        return out

    def close(self) -> None:
        """Drain every engine and release the (shared) store's OS
        resources. Idempotent — store close is."""
        for eng in self.engines.values():
            eng.close()

    def reset_stats(self) -> None:
        """Zero the per-class counters and per-engine serving stats
        (bench warm-up boundary) through each engine's own
        `reset_stats` — request stats, timings, row accounting, and
        feature-cache counters. Compile caches, pack pools, cache
        CONTENTS, and high-water marks are deliberately kept — steady
        state is the point of resetting."""
        for name, eng in self.engines.items():
            eng.reset_stats()
            self.stats[name] = ClassStats()

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-class goodput counters merged with the class engine's
        latency percentiles and structural counters."""
        out: Dict[str, Dict[str, float]] = {}
        for name, eng in self.engines.items():
            s = self.stats[name].summary()
            es = eng.stats.summary()
            s.update(p50_ms=es["p50_ms"], p95_ms=es["p95_ms"],
                     p99_ms=es["p99_ms"], batches=es["batches"],
                     jit_compiles=eng.jit_stats["compiles"],
                     pack_allocs=eng.pack_stats["allocs"])
            br = self.breakers.get(name)
            if br is not None:
                s.update(breaker_state=br.state, breaker_trips=br.trips)
            out[name] = s
        return out
