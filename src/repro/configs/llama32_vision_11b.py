"""llama-3.2-vision-11b — VLM with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision]. 40L (32 self + 8 cross-attn),
d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 128256.
The ViT vision encoder + projector is a STUB: input_specs provides patch
embeddings (B, 1600, d_model)."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    mlp_kind="swiglu",
    rope_theta=500000.0,
    num_image_tokens=1600,
)
