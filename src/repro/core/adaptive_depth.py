"""Adaptive-Depth Inference (ADI) — the paper's NAP generalized to
transformer decoding (beyond-paper; DESIGN.md §3).

NAP's exit criterion is distance to a closed-form stationary state (Eq. 7).
Pre-norm residual transformers have no closed form, but hidden states
*saturate* with depth; the analogous criterion is the per-token relative
saturation distance

    d_t^(l) = ||h_t^(l) - h_t^(l-1)|| / ||h_t^(l)||      (cf. Eq. 8)

A token exits at the first block l in [t_min, t_max] with d < t_s and is
classified by its exit head (inception-distilled, repro.core.
inception_distill). Exited tokens keep a frozen hidden state that still
flows through later layers' KV projections (so subsequent tokens can attend)
while their FFN/attention-query compute is masked — on TPU the masking is
realized as block predication, exactly like the SpMM kernel's NAP rows.

This module is the compiled masked path; the compute saving shows up at
tile granularity (documented), numerics are exact w.r.t. the host
early-exit semantics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn import blocks as B
from repro.nn.basic import apply_norm


def saturation_distance(x_new: jax.Array, x_old: jax.Array) -> jax.Array:
    """(B, 1, d) -> (B,) relative saturation distance."""
    num = jnp.linalg.norm((x_new - x_old).astype(jnp.float32), axis=-1)
    den = jnp.linalg.norm(x_new.astype(jnp.float32), axis=-1) + 1e-9
    return (num / den)[:, 0]


def adaptive_decode_step(cfg, params, cache, tokens, pos,
                         frontend=None) -> Tuple[jax.Array, dict, dict]:
    """Early-exit decode step. Returns (logits, new_cache, info) where
    info = {exit_block (B,), saturation (B,), flops_saved_frac ()}.

    Requires cfg.adaptive.enabled. Exit heads are those trained by
    Inception Distillation; tokens that never cross the threshold use the
    full trunk + final head (Algorithm 1 line 17)."""
    from repro.models.decoder_lm import (_embed_tokens, _project_logits,
                                         exit_logits)
    ad = cfg.adaptive
    assert ad.enabled, "cfg.adaptive.enabled must be set"
    R = cfg.pattern_repeats
    t_max = ad.t_max if ad.t_max >= 0 else R - 1

    positions = jnp.broadcast_to(pos[None, None] if hasattr(pos, "shape")
                                 else jnp.full((1, 1), pos), tokens.shape)
    x = _embed_tokens(cfg, params, tokens, positions)
    Bsz = tokens.shape[0]

    exit_block = jnp.full((Bsz,), -1, jnp.int32)
    exit_state = jnp.zeros_like(x)
    sat = jnp.ones((Bsz,), jnp.float32)

    def block_body(carry, xs):
        x, exit_block, exit_state, sat, idx = xs[0] if False else carry
        pblock, cblock = xs
        active = exit_block < 0                       # (B,)
        x_old = x
        x_new = x
        new_cblock = []
        for j, kind in enumerate(cfg.pattern):
            x_new, c, _ = B.apply_layer(cfg, kind, pblock[j], x_new,
                                        mode="decode", cache=cblock[j],
                                        pos=pos, frontend=frontend)
            new_cblock.append(c)
        # freeze exited tokens (their KV was still written above — later
        # tokens can attend; the FFN result is discarded = predicated away)
        am = active[:, None, None]
        x = jnp.where(am, x_new, x_old)
        d = saturation_distance(x_new, x_old)
        sat = jnp.where(active, d, sat)
        crosses = active & (idx >= ad.t_min) & (idx <= t_max) & (d < ad.t_s)
        exit_block = jnp.where(crosses, idx, exit_block)
        exit_state = jnp.where(crosses[:, None, None], x_new, exit_state)
        return (x, exit_block, exit_state, sat, idx + 1), tuple(new_cblock)

    (x, exit_block, exit_state, sat, _), new_blocks = jax.lax.scan(
        block_body, (x, exit_block, exit_state, sat, jnp.int32(0)),
        (params["blocks"], cache["blocks"]))

    new_rem = []
    for p, c, kind in zip(params["rem"], cache["rem"], cfg.remainder):
        x, c2, _ = B.apply_layer(cfg, kind, p, x, mode="decode", cache=c,
                                 pos=pos, frontend=frontend)
        new_rem.append(c2)

    # classify: exited tokens via their exit head, others via the trunk head
    x_final = apply_norm(cfg, params["final_norm"], x)
    trunk_logits = _project_logits(cfg, params, x_final)

    logits = trunk_logits
    if "exits" in params and ad.exit_layers:
        for i, blk in enumerate(ad.exit_layers):
            zi = exit_logits(cfg, params, exit_state, i)
            m = (exit_block == blk)[:, None, None]
            logits = jnp.where(m, zi, logits)

    # fraction of block-compute predicated away this step
    depth_used = jnp.where(exit_block < 0, R, exit_block + 1)
    flops_saved = 1.0 - jnp.mean(depth_used.astype(jnp.float32)) / R
    info = {"exit_block": exit_block, "saturation": sat,
            "flops_saved_frac": flops_saved}
    return logits, {"blocks": new_blocks, "rem": tuple(new_rem)}, info
