from repro.common.types import (
    AdaptiveDepthConfig,
    HardwareConfig,
    INPUT_SHAPES,
    LAYER_KINDS,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    TPU_V5E,
    TrainConfig,
)

__all__ = [
    "AdaptiveDepthConfig", "HardwareConfig", "INPUT_SHAPES", "LAYER_KINDS",
    "MeshConfig", "ModelConfig", "ShapeConfig", "TPU_V5E", "TrainConfig",
]
