"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427 Griffin / RecurrentGemma model card].
38L, d_model 4096, 16 heads (MQA kv=1), d_ff 12288, vocab 256000.
Pattern: (rglru, rglru, local) x 12 + (rglru, rglru) = 38 layers.
Local attention window 2048 -> natively sub-quadratic (long_500k runs)."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    remainder=("rglru", "rglru"),
    mlp_kind="geglu",
    sliding_window=2048,
    rnn_width=4096,
    conv1d_width=4,
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed_sqrt_d=True,
)
