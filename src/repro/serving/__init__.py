from repro.serving.engine import EngineStats, NAIServingEngine, Request
from repro.serving.frontend import (ClassStats, ServingFrontend, SLOClass,
                                    default_slo_classes)
from repro.serving.lm_engine import LMRequest, LMServingEngine

__all__ = ["EngineStats", "NAIServingEngine", "Request", "ClassStats",
           "ServingFrontend", "SLOClass", "default_slo_classes",
           "LMRequest", "LMServingEngine"]
