from repro.serving.engine import EngineStats, NAIServingEngine, Request
from repro.serving.lm_engine import LMRequest, LMServingEngine

__all__ = ["EngineStats", "NAIServingEngine", "Request", "LMRequest", "LMServingEngine"]
