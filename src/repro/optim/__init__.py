from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.schedule import make_schedule

__all__ = ["adamw_init", "adamw_update", "global_norm", "make_schedule"]
