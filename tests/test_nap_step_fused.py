"""Fused NAP step kernel (repro.kernels.nap_step) parity matrix: the one-
pass kernel must match the two-launch composition (spmm_block_ell then
nap_exit), the jnp oracle, and the numpy host semantics — including
non-uniform exit patterns (some nodes exit at order 1, some never), the
all-exited-row-block skip, and bit-equal exit orders end to end."""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import load_dataset
from repro.gnn.nai import (NAIConfig, infer_batch_masked,
                           support_stationary_factors)
from repro.gnn.packing import pack_support, step_active_blocks
from repro.gnn.sampler import sample_support
from repro.kernels.nap_step import (fused_step, nap_step_fused,
                                    ref_nap_step, two_launch_step)
from repro.kernels.spmm import CB, RB, build_block_ell, pad_features
from repro.gnn.store import as_store


def _random_graph(rng, n, deg):
    E = n * deg
    src = np.concatenate([rng.integers(0, n, E),
                          np.arange(n)]).astype(np.int32)
    dst = np.concatenate([rng.integers(0, n, E),
                          np.arange(n)]).astype(np.int32)
    key = dst.astype(np.int64) * n + src
    uk = np.unique(key)
    dst, src = (uk // n).astype(np.int32), (uk % n).astype(np.int32)
    coef = rng.random(len(src)).astype(np.float32)
    return src, dst, coef


def _operands(rng, n=192, deg=5, f=100, nb=32):
    src, dst, coef = _random_graph(rng, n, deg)
    ell = build_block_ell(src, dst, coef, n)
    x = jnp.asarray(pad_features(rng.standard_normal((n, f)), ell.n_pad))
    f_pad = x.shape[1]
    c_inf = jnp.asarray(rng.random(nb).astype(np.float32) + 0.1)
    s_inf = jnp.asarray(np.pad(
        rng.standard_normal(f).astype(np.float32), (0, f_pad - f)))
    return ell, x, c_inf, s_inf


@pytest.mark.parametrize("frac_active,frac_nodes",
                         [(1.0, 1.0), (0.6, 0.5), (1.0, 0.0), (0.3, 1.0)])
def test_fused_matches_two_launch_and_oracle(rng, frac_active, frac_nodes):
    """Same operands through the fused kernel, the two-launch composition
    it replaces, and the jnp oracle — all outputs must agree, with mixed
    skipped row blocks and partially exited node masks."""
    ell, x, c_inf, s_inf = _operands(rng)
    nb = c_inf.shape[0]
    n_rb = ell.tile_col.shape[0]
    active = jnp.asarray(
        (rng.random(n_rb) < frac_active).astype(np.int32)
    ).at[:nb // RB].set(1)
    nact = jnp.asarray((rng.random(nb) < frac_nodes).astype(np.int32)
                       )[:, None]
    t_s = 9.0
    ops = (jnp.asarray(ell.tiles), jnp.asarray(ell.tile_col),
           jnp.asarray(ell.valid), active, x, c_inf, s_inf, nact, t_s)
    out_f = fused_step(*ops, interpret=True)
    out_t = two_launch_step(*ops, interpret=True)
    out_r = ref_nap_step(*ops[:8], t_s * t_s)
    for f_arr, t_arr, r_arr in zip(out_f, out_t, out_r):
        np.testing.assert_allclose(np.asarray(f_arr), np.asarray(t_arr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(f_arr), np.asarray(r_arr),
                                   rtol=1e-4, atol=1e-4)
    # exit flags and block predicates are bit-exact, not just close
    assert np.array_equal(np.asarray(out_f[1]), np.asarray(out_t[1]))
    assert np.array_equal(np.asarray(out_f[2]), np.asarray(out_t[2]))


def test_all_exited_row_block_skip(rng):
    """active == 0 everywhere (whole batch exited) must touch zero tiles:
    propagated output exactly zero, no node exits, no block still live."""
    ell, x, c_inf, s_inf = _operands(rng)
    nb = c_inf.shape[0]
    n_rb = ell.tile_col.shape[0]
    out, exits, blk = fused_step(
        jnp.asarray(ell.tiles), jnp.asarray(ell.tile_col),
        jnp.asarray(ell.valid), jnp.zeros((n_rb,), jnp.int32), x,
        c_inf, s_inf, jnp.zeros((nb, 1), jnp.int32), 9.0, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0
    assert int(exits.sum()) == 0 and int(blk.sum()) == 0


def test_negative_ts2_gates_exits(rng):
    """A negative squared threshold (how T_min/T_max gating reaches the
    kernel) must keep every active node active."""
    ell, x, c_inf, s_inf = _operands(rng)
    nb = c_inf.shape[0]
    n_rb = ell.tile_col.shape[0]
    nact = jnp.asarray((rng.random(nb) < 0.7).astype(np.int32))[:, None]
    _, exits, blk = nap_step_fused(
        jnp.asarray(ell.tiles), jnp.asarray(ell.tile_col),
        jnp.asarray(ell.valid), jnp.ones((n_rb,), jnp.int32), x,
        c_inf, s_inf, nact, jnp.asarray([-1.0], jnp.float32),
        interpret=True)
    assert int(exits.sum()) == 0
    expect_blk = np.asarray(nact)[:, 0].reshape(-1, RB).any(axis=1)
    assert np.array_equal(np.asarray(blk)[:nb // RB, 0],
                          expect_blk.astype(np.int32))
    assert int(np.asarray(blk)[nb // RB:].sum()) == 0


# ------------------------------------------------ full NAP loop parity
@pytest.fixture(scope="module")
def packed_case():
    g = load_dataset("pubmed-like", scale=0.03, seed=1)
    rng = np.random.default_rng(0)
    batch = rng.choice(g.test_idx, size=37, replace=False)
    sup = sample_support(as_store(g), batch, 3, 0.5)
    x0 = g.features[sup.nodes][:, :64].astype(np.float32)
    c64, s64 = support_stationary_factors(g, sup, x0, 0.5)
    c32 = c64.astype(np.float32)
    s32 = s64.astype(np.float32)
    # dense x_inf from the f32 factors: the same arithmetic the fused
    # kernel performs in VMEM, so exit orders can be compared bit-wise
    packed = pack_support(sup, x0, np.outer(c32, s32),
                          x_inf_factors=(c32, s32))
    return g, sup, packed


def _dense_operator(packed):
    A = np.zeros((packed.n_pad, packed.n_pad), np.float32)
    for rb in range(packed.n_rb):
        for t in range(packed.tiles.shape[1]):
            if packed.valid[rb, t]:
                cb = int(packed.tile_col[rb, t])
                A[rb * RB:(rb + 1) * RB, cb * CB:(cb + 1) * CB] += \
                    packed.tiles[rb, t]
    return A


def _host_orders(packed, step_active, t_s, t_min, t_max):
    """Numpy reference for the masked-path semantics: dense padded
    operator, full propagation each (hop-masked) step, squared f32
    distance against the squared threshold — exactly the fused kernel's
    arithmetic contract."""
    n_pad, nb = packed.n_pad, packed.n_batch
    A = _dense_operator(packed)
    x_inf = packed.c_inf[:, None] * packed.s_inf[None, :]
    x = packed.x0.copy()
    orders = np.zeros(nb, np.int64)
    for l in range(1, t_max + 1):
        live = (orders == 0).any()
        row_active = np.repeat(step_active[l - 1] * int(live), RB
                               ).astype(bool)
        x = np.where(row_active[:, None], A @ x, 0.0).astype(np.float32)
        if not (t_min <= l < t_max):
            continue
        d2 = ((x[:nb] - x_inf) ** 2).sum(axis=1, dtype=np.float32)
        orders[(orders == 0) & (d2 < np.float32(t_s) ** 2)] = l
    orders[orders == 0] = t_max
    return orders


def _fused_orders(packed, nai, step_active):
    orders, series = infer_batch_masked(
        None, nai, None, None, None, None, jnp.asarray(packed.x0),
        jnp.asarray(packed.x_inf), packed.n_batch, spmm_impl="fused",
        ell=(jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
             jnp.asarray(packed.valid)),
        step_active=jnp.asarray(step_active),
        x_inf_factors=(jnp.asarray(packed.c_inf),
                       jnp.asarray(packed.s_inf)), interpret=True)
    return np.asarray(orders), series


def _step_distances(packed, t_max):
    """Per-step batch distances d_l for l = 1..t_max-1 (the decision
    steps), full unmasked propagation — what both paths compare to T_s."""
    A = _dense_operator(packed)
    x_inf = packed.c_inf[:, None] * packed.s_inf[None, :]
    x = packed.x0.copy()
    out = []
    for l in range(1, t_max):
        x = (A @ x).astype(np.float32)
        out.append(np.linalg.norm(x[:packed.nb_real]
                                  - x_inf[:packed.nb_real], axis=1))
    return out


def _split_ts(packed, t_max=3) -> float:
    """A threshold that splits the step-1 distances (non-uniform exits)
    while keeping EVERY decision-step distance well away from the cut, so
    f32 rounding cannot flip an exit on either path."""
    dists = _step_distances(packed, t_max)
    d1 = np.unique(dists[0])
    d_all = np.concatenate(dists)
    cands = (d1[1:] + d1[:-1]) / 2
    margins = np.array([np.abs(d_all - c).min() for c in cands])
    return float(cands[margins.argmax()])


def test_fused_infer_matches_block_ell_infer(packed_case):
    """The fused loop must reproduce the two-kernel block_ell loop on a
    real packed support with a non-uniform exit pattern: identical exit
    orders (bit-equal) and matching propagated series."""
    g, sup, packed = packed_case
    sa = step_active_blocks(packed.hop_rb, 3)
    nai = NAIConfig(t_s=_split_ts(packed), t_min=1, t_max=3)
    of, series_f = _fused_orders(packed, nai, sa)
    ob, series_b = infer_batch_masked(
        None, nai, None, None, None, None, jnp.asarray(packed.x0),
        jnp.asarray(packed.x_inf), packed.n_batch, spmm_impl="block_ell",
        ell=(jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
             jnp.asarray(packed.valid)),
        step_active=jnp.asarray(sa), interpret=True)
    assert np.array_equal(of, np.asarray(ob))
    np.testing.assert_allclose(np.asarray(series_f), np.asarray(series_b),
                               rtol=1e-4, atol=1e-4)
    # the pattern really is non-uniform on real rows
    real = of[:packed.nb_real]
    assert len(np.unique(real)) >= 2, real


def test_fused_infer_matches_host_orders(packed_case):
    """exit_order arrays are EQUAL (not close) between the fused Pallas
    loop and the numpy host reference across a threshold sweep covering
    all-exit-early, mixed, and never-exit patterns."""
    g, sup, packed = packed_case
    sa = step_active_blocks(packed.hop_rb, 3)
    mid = _split_ts(packed)
    for t_s in (1e-6, mid, 1e9):
        nai = NAIConfig(t_s=t_s, t_min=1, t_max=3)
        of, _ = _fused_orders(packed, nai, sa)
        oh = _host_orders(packed, sa, t_s, 1, 3)
        assert np.array_equal(of, oh), (t_s, of[:16], oh[:16])


def test_fused_skips_all_blocks_after_batch_exit(packed_case):
    """t_s huge => whole batch exits at T_min; the kernel-emitted block
    predicate then drives `live` to zero, so later series entries are
    exactly zero while exit orders stay 1."""
    g, sup, packed = packed_case
    sa = step_active_blocks(packed.hop_rb, 3)
    nai = NAIConfig(t_s=1e9, t_min=1, t_max=3)
    orders, series = _fused_orders(packed, nai, sa)
    assert (orders == 1).all()
    assert float(jnp.abs(series[1]).max()) > 0.0
    assert float(jnp.abs(series[2]).max()) == 0.0
    assert float(jnp.abs(series[3]).max()) == 0.0


# ------------------------------------------------------------ hypothesis
def test_property_fused_exit_order_equals_host():
    pytest.importorskip("hypothesis")
    from hypothesis import assume, given, settings, strategies as st

    @functools.lru_cache(maxsize=None)
    def graph_case(seed, n, deg, nb):
        rng = np.random.default_rng(seed)
        src, dst, coef = _random_graph(rng, n, deg)
        ell = build_block_ell(src, dst, coef, n)
        x0 = pad_features(rng.standard_normal((n, 4)).astype(np.float32),
                          ell.n_pad)
        f_pad = x0.shape[1]
        c = (rng.random(nb).astype(np.float32) * 0.5 + 0.1)
        s = np.zeros(f_pad, np.float32)
        s[:4] = rng.standard_normal(4).astype(np.float32)
        return ell, x0, c, s

    class _View:  # duck-typed PackedSupport view for _host_orders
        pass

    @given(st.integers(0, 2 ** 16), st.integers(24, 48), st.integers(2, 4),
           st.sampled_from([8, 16]), st.integers(2, 3),
           st.floats(0.05, 0.95))
    @settings(max_examples=10, deadline=None)
    def prop(seed, n, deg, nb, t_max, q):
        ell, x0, c, s = graph_case(seed, n, deg, nb)
        p = _View()
        p.n_pad, p.n_batch, p.nb_real = ell.n_pad, nb, nb
        p.n_rb = ell.tile_col.shape[0]
        p.tiles, p.tile_col, p.valid = ell.tiles, ell.tile_col, ell.valid
        p.x0 = x0
        p.c_inf, p.s_inf = c, s
        p.x_inf = c[:, None] * s[None, :]
        sa = np.ones((t_max, p.n_rb), np.int32)

        # threshold at a quantile of the step-1 distances, margin-guarded
        # over EVERY decision step so rounding cannot flip an exit
        dists = _step_distances(p, t_max)
        t_s = float(np.quantile(dists[0], q))
        d_all = np.concatenate(dists)
        assume(np.abs(d_all - t_s).min() > 1e-3 * max(t_s, 1.0))

        nai = NAIConfig(t_s=t_s, t_min=1, t_max=t_max)
        of, _ = _fused_orders(p, nai, sa)
        oh = _host_orders(p, sa, t_s, 1, t_max)
        assert np.array_equal(of, oh), (t_s, of, oh)

    prop()
