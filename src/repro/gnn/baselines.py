"""Paper baselines (§4.1): GLNN, TinyGNN (lite), and INT8 quantization.

All baselines share the NAI evaluation harness: ACC + per-node MACs split
into feature processing and classification + wall time.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import TrainConfig
from repro.core.inception_distill import hard_ce, offline_loss
from repro.gnn.graph import Graph, propagated_series
from repro.gnn.models import GNNConfig, apply_classifier
from repro.nn.params import ParamDef, init_tree
from repro.optim import adamw_init, adamw_update


def _mlp_defs(dims):
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = ParamDef((a, b), (None, None))
        out[f"b{i}"] = ParamDef((b,), (None,), "zeros")
    return out


def _mlp_apply(p, x, n_layers):
    for i in range(n_layers):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


def _fit(loss_fn, params, steps, lr=0.01, wd=1e-4):
    tc = TrainConfig(learning_rate=lr, weight_decay=wd, grad_clip=0.0,
                     schedule="constant")
    state = adamw_init(params, tc)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = adamw_update(grads, state, params, tc, lr)
        return params, state, loss

    for _ in range(steps):
        params, state, _ = step(params, state)
    return params


# ----------------------------------------------------------------- GLNN [39]
@dataclasses.dataclass
class BaselineResult:
    acc: float
    macs: float          # per node, total
    fp_macs: float       # per node, feature processing
    time_s: float
    fp_time_s: float


def run_glnn(cfg: GNNConfig, g: Graph, teacher_params, *, width_mult: int = 4,
             epochs: int = 300, temperature: float = 1.2, lam: float = 0.9,
             seed: int = 0) -> BaselineResult:
    """Distill f^(k) (teacher) into a plain MLP over raw features; inference
    touches NO edges (the paper's extreme case of NAI with order 0)."""
    g_train = g.train_subgraph()
    series = propagated_series(g_train, g.features, cfg.k, cfg.r)
    feats = jnp.asarray(np.stack(series))
    vtrain = np.concatenate([g.train_idx, g.unlabeled_idx])
    teacher = apply_classifier(cfg, teacher_params, feats[:, vtrain], cfg.k)

    dims = [cfg.feat_dim, cfg.hidden * width_mult, cfg.num_classes]
    params = init_tree(jax.random.PRNGKey(seed), _mlp_defs(dims), "float32")
    x_train = jnp.asarray(g.features[vtrain])
    y_l = jnp.asarray(g.labels[g.train_idx])
    x_l = jnp.asarray(g.features[g.train_idx])
    labels_vt = jnp.asarray(g.labels[vtrain])

    def loss(p):
        z = _mlp_apply(p, x_train, 2)
        kd = offline_loss(z, teacher, labels_vt, temperature=temperature,
                          lam=1.0)
        ce = hard_ce(_mlp_apply(p, x_l, 2), y_l)
        return lam * kd + (1 - lam) * ce

    params = _fit(loss, params, epochs)

    t0 = time.perf_counter()
    z = np.asarray(_mlp_apply(params, jnp.asarray(g.features[g.test_idx]), 2))
    dt = time.perf_counter() - t0
    acc = float((z.argmax(-1) == g.labels[g.test_idx]).mean())
    macs = sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    return BaselineResult(acc=acc, macs=macs, fp_macs=0.0, time_s=dt,
                          fp_time_s=0.0)


# ------------------------------------------------------------- TinyGNN [34]
def run_tinygnn(cfg: GNNConfig, g: Graph, teacher_params, *, epochs: int = 300,
                temperature: float = 1.2, lam: float = 0.9,
                seed: int = 0) -> BaselineResult:
    """Single-hop GNN student with a peer-aware self-attention module,
    distilled from f^(k). Captures the paper's trade-off: 1-hop propagation
    + an attention module whose extra MACs dominate on high-dim features."""
    g_train = g.train_subgraph()
    series = propagated_series(g_train, g.features, cfg.k, cfg.r)
    feats = jnp.asarray(np.stack(series))
    vtrain = np.concatenate([g.train_idx, g.unlabeled_idx])
    teacher = apply_classifier(cfg, teacher_params, feats[:, vtrain], cfg.k)

    f, h, c = cfg.feat_dim, cfg.hidden, cfg.num_classes
    defs = {
        "att_q": ParamDef((f, h), (None, None)),
        "att_k": ParamDef((f, h), (None, None)),
        "att_v": ParamDef((f, f), (None, None)),
        **_mlp_defs([f, h, c]),
    }
    params = init_tree(jax.random.PRNGKey(seed), defs, "float32")

    def peer_aware(p, x1, x0):
        """x1: 1-hop propagated; x0: raw. Peer attention between the two
        views (the PAM module, reduced to the 2-view case)."""
        q = x0 @ p["att_q"]
        kk = x1 @ p["att_k"]
        a = jax.nn.sigmoid(jnp.sum(q * kk, -1, keepdims=True)
                           / jnp.sqrt(float(q.shape[-1])))
        return a * (x1 @ p["att_v"]) + (1 - a) * x0

    def forward(p, x1, x0):
        return _mlp_apply(p, peer_aware(p, x1, x0), 2)

    x1_t = feats[1][jnp.asarray(vtrain)]
    x0_t = feats[0][jnp.asarray(vtrain)]
    labels_vt = jnp.asarray(g.labels[vtrain])

    def loss(p):
        z = forward(p, x1_t, x0_t)
        kd = offline_loss(z, teacher, labels_vt, temperature=temperature,
                          lam=1.0)
        return lam * kd + (1 - lam) * hard_ce(z, labels_vt)

    params = _fit(loss, params, epochs)

    # inference: 1-hop propagation for test nodes + PAM + MLP
    t0 = time.perf_counter()
    series_full = propagated_series(g, g.features, 1, cfg.r)
    fp_dt = time.perf_counter() - t0
    x1 = jnp.asarray(series_full[1][g.test_idx])
    x0 = jnp.asarray(g.features[g.test_idx])
    z = np.asarray(forward(params, x1, x0))
    dt = time.perf_counter() - t0
    acc = float((z.argmax(-1) == g.labels[g.test_idx]).mean())

    deg = float(g.degrees.mean() + 1)
    fp_macs = deg * f + 2 * (f * h) + f * f          # 1-hop spmm + PAM
    cls_macs = f * h + h * c
    return BaselineResult(acc=acc, macs=fp_macs + cls_macs, fp_macs=fp_macs,
                          time_s=dt, fp_time_s=fp_dt)


# --------------------------------------------------------- quantization [25]
def _fixed_order_inference(cfg: GNNConfig, g: Graph, params,
                           batch_size: int = 500) -> BaselineResult:
    """Fixed k-order propagation through the SAME inductive batched pipeline
    as NAI (support sampling per batch) — NAP with T_s=0 degenerates to
    exactly this, so MAC/time accounting is apples-to-apples (paper §4.1)."""
    from repro.gnn.nai import NAIConfig, infer_all
    nai = NAIConfig(t_s=0.0, t_min=1, t_max=cfg.k, batch_size=batch_size)
    res = infer_all(cfg, nai, params, g)
    acc = float((res.predictions == g.labels[g.test_idx]).mean())
    return BaselineResult(acc=acc, macs=res.total_macs, fp_macs=res.fp_macs,
                          time_s=res.wall_time_s, fp_time_s=res.fp_time_s)


def run_quantized(cfg: GNNConfig, g: Graph, params, *, seed: int = 0
                  ) -> BaselineResult:
    """Post-training INT8 quantization of the classifiers: weights are
    fake-quantized per-tensor; feature propagation stays FP32 (the paper's
    point: quantization cannot touch feature-processing cost, so fp_macs
    equal vanilla's)."""
    def q(x):
        x = np.asarray(x)
        s = np.abs(x).max() / 127.0 + 1e-12
        return jnp.asarray((np.round(x / s).clip(-127, 127) * s)
                           .astype(np.float32))

    qcls = {l: jax.tree.map(q, p) for l, p in params["cls"].items()}
    return _fixed_order_inference(cfg, g, dict(params, cls=qcls))


def run_vanilla(cfg: GNNConfig, g: Graph, params) -> BaselineResult:
    """The vanilla base model: full k-order propagation for every node."""
    return _fixed_order_inference(cfg, g, params)
