"""Batched NAI serving engine (the paper's deployment scenario: streaming
inference over unseen nodes with latency constraints).

Requests (node ids) arrive on a queue; the batch former groups them up to
`batch_size` or `max_wait_s`; each batch runs Algorithm 1 via
`infer_batch_host`. Latency percentiles and the exit-order histogram are
tracked per engine — the quantities a production deployment would alarm on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.gnn.graph import Graph
from repro.gnn.models import GNNConfig
from repro.gnn.nai import NAIConfig, infer_batch_host


@dataclasses.dataclass
class Request:
    node_id: int
    arrival_s: float
    done_s: float = -1.0
    prediction: int = -1
    exit_order: int = -1


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    batches: int = 0
    latencies: List[float] = dataclasses.field(default_factory=list)
    exit_hist: Dict[int, int] = dataclasses.field(default_factory=dict)

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "served": self.served,
            "batches": self.batches,
            "p50_ms": 1e3 * self.percentile(50),
            "p95_ms": 1e3 * self.percentile(95),
            "p99_ms": 1e3 * self.percentile(99),
            "mean_exit_order": (
                sum(k * v for k, v in self.exit_hist.items())
                / max(self.served, 1)),
        }


class NAIServingEngine:
    def __init__(self, cfg: GNNConfig, nai: NAIConfig, params, graph: Graph,
                 *, max_wait_s: float = 0.01):
        self.cfg = cfg
        self.nai = nai
        self.params = params
        self.graph = graph
        self.max_wait_s = max_wait_s
        self.queue: Deque[Request] = deque()
        self.stats = EngineStats()

    def submit(self, node_ids, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        for nid in np.atleast_1d(node_ids):
            self.queue.append(Request(int(nid), now))

    def _form_batch(self) -> List[Request]:
        batch: List[Request] = []
        deadline = (self.queue[0].arrival_s + self.max_wait_s
                    if self.queue else 0.0)
        while self.queue and len(batch) < self.nai.batch_size:
            batch.append(self.queue.popleft())
            if time.perf_counter() > deadline and len(batch) >= 1:
                # latency bound takes priority over batch fill
                if len(batch) >= self.nai.batch_size // 4:
                    break
        return batch

    def step(self) -> List[Request]:
        """Serve one batch; returns completed requests."""
        batch = self._form_batch()
        if not batch:
            return []
        nodes = np.asarray([r.node_id for r in batch])
        preds, orders, _, _, _ = infer_batch_host(
            self.cfg, self.nai, self.params, self.graph, nodes)
        done = time.perf_counter()
        for r, p, o in zip(batch, preds, orders):
            r.done_s = done
            r.prediction = int(p)
            r.exit_order = int(o)
            self.stats.latencies.append(done - r.arrival_s)
            self.stats.exit_hist[int(o)] = self.stats.exit_hist.get(int(o), 0) + 1
        self.stats.served += len(batch)
        self.stats.batches += 1
        return batch

    def run_until_drained(self) -> EngineStats:
        while self.queue:
            self.step()
        return self.stats
