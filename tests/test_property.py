"""Property-based tests (hypothesis) on system invariants."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.inception_distill import ensemble_teacher, hard_ce, soft_ce
from repro.gnn.graph import Graph, add_self_loops, edge_coefficients, spmm
from repro.gnn.sampler import sample_support
from repro.launch.hlo_analysis import _shape_bytes, _shape_elems
from repro.sharding.logical import fit_spec
from repro.gnn.store import as_store
from jax.sharding import PartitionSpec as P

SETTINGS = dict(max_examples=25, deadline=None)


def _graph_from_edges(n, pairs):
    u = np.array([p[0] % n for p in pairs] + [0], np.int64)
    v = np.array([p[1] % n for p in pairs] + [1 % n], np.int64)
    keep = u != v
    u, v = u[keep], v[keep]
    if len(u) == 0:
        u, v = np.array([0]), np.array([1 % n])
    eid = np.unique(np.minimum(u, v) * n + np.maximum(u, v))
    u, v = (eid // n).astype(np.int32), (eid % n).astype(np.int32)
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    src, dst = add_self_loops(src, dst, n)
    idx = np.arange(n, dtype=np.int32)
    return Graph(n=n, src=src, dst=dst,
                 features=np.zeros((n, 2), np.float32),
                 labels=np.zeros(n, np.int32), num_classes=2,
                 train_idx=idx[:1], unlabeled_idx=idx[1:2], test_idx=idx[2:])


@given(st.integers(4, 30),
       st.lists(st.tuples(st.integers(0, 29), st.integers(0, 29)),
                min_size=1, max_size=60),
       st.data())
@settings(**SETTINGS)
def test_spmm_mass_conservation_r1(n, pairs, data):
    """r=1 gives the transition matrix ÃD̃^{-1}: column-stochastic, so the
    total feature mass is conserved by propagation (paper Eq. 1)."""
    g = _graph_from_edges(n, pairs)
    x = np.asarray(data.draw(st.lists(st.floats(-5, 5), min_size=n,
                                      max_size=n)), np.float32)[:, None]
    coef = edge_coefficients(g, r=1.0)
    out = spmm(g, coef, x)
    np.testing.assert_allclose(out.sum(), x.sum(), rtol=1e-3, atol=1e-3)


@given(st.integers(4, 20),
       st.lists(st.tuples(st.integers(0, 19), st.integers(0, 19)),
                min_size=1, max_size=40))
@settings(**SETTINGS)
def test_spmm_linearity(n, pairs):
    g = _graph_from_edges(n, pairs)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, 3)).astype(np.float32)
    y = rng.standard_normal((n, 3)).astype(np.float32)
    coef = edge_coefficients(g, 0.5)
    lhs = spmm(g, coef, 2.0 * x + y)
    rhs = 2.0 * spmm(g, coef, x) + spmm(g, coef, y)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


# ------------------------------------------------- sampler invariants
@given(st.integers(6, 30),
       st.lists(st.tuples(st.integers(0, 29), st.integers(0, 29)),
                min_size=1, max_size=60),
       st.integers(1, 5), st.integers(1, 3), st.floats(0.1, 0.9),
       st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_sampler_invariants(n, pairs, bs, hops, r, seed):
    """Supporting-set invariants (Algorithm 1 line 3): batch nodes come
    first at hop 0, hop layers are monotone non-decreasing in discovery
    order and bounded by `hops`, and every propagation coefficient is
    strictly positive."""
    g = _graph_from_edges(n, pairs)
    batch = np.random.default_rng(seed).permutation(n)[:min(bs, n)]
    sup = sample_support(as_store(g), batch, hops, r)
    nb = len(batch)
    assert sup.n_batch == nb
    assert np.array_equal(sup.nodes[:nb], batch)
    assert (sup.hop[:nb] == 0).all()
    assert (np.diff(sup.hop) >= 0).all()          # hop monotonicity
    assert sup.hop.max() <= hops
    assert (sup.coef > 0).all()                   # coefficient positivity
    # support nodes are unique and every edge endpoint is in range
    assert len(np.unique(sup.nodes)) == len(sup)
    assert sup.src.max(initial=-1) < len(sup)
    assert sup.dst.max(initial=-1) < len(sup)


@given(st.integers(6, 24),
       st.lists(st.tuples(st.integers(0, 23), st.integers(0, 23)),
                min_size=1, max_size=50),
       st.integers(1, 4), st.integers(1, 3))
@settings(**SETTINGS)
def test_sampler_hop_layers_are_bfs_frontiers(n, pairs, bs, hops):
    """Every hop-h node has an in-neighbor at hop h-1 (frontier
    expansion), and no node closer to the batch is labeled farther."""
    g = _graph_from_edges(n, pairs)
    batch = np.arange(min(bs, n))
    sup = sample_support(as_store(g), batch, hops, 0.5)
    hop_of = {int(u): int(h) for u, h in zip(sup.nodes, sup.hop)}
    indptr, nbr = g.csr()
    for u, h in zip(sup.nodes, sup.hop):
        if h == 0:
            continue
        preds = [hop_of.get(int(v)) for v in nbr[indptr[u]:indptr[u + 1]]]
        assert min(p for p in preds if p is not None) == h - 1


@given(st.integers(2, 6), st.integers(2, 10), st.integers(1, 4),
       st.floats(1.0, 4.0))
@settings(**SETTINGS)
def test_ensemble_teacher_is_distribution(classes, nodes, r, scale):
    rng = np.random.default_rng(1)
    logits = [jnp.asarray(rng.standard_normal((nodes, classes)) * scale,
                          jnp.float32) for _ in range(r)]
    s = jnp.asarray(rng.standard_normal((classes, 1)), jnp.float32)
    ens = ensemble_teacher(logits, s)
    probs = jax.nn.softmax(ens, -1)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-4)
    # ensemble of identical predictions = that prediction
    same = ensemble_teacher([logits[0]] * max(r, 2), s)
    np.testing.assert_allclose(np.asarray(jax.nn.softmax(same, -1)),
                               np.asarray(jax.nn.softmax(logits[0], -1)),
                               rtol=1e-3, atol=1e-4)


@given(st.integers(2, 8), st.integers(1, 12), st.floats(1.0, 4.0))
@settings(**SETTINGS)
def test_soft_ce_minimized_at_teacher(classes, nodes, T):
    """KD loss is minimized when student == teacher (cross entropy >=
    entropy)."""
    rng = np.random.default_rng(2)
    t = jnp.asarray(rng.standard_normal((nodes, classes)), jnp.float32)
    s_other = jnp.asarray(rng.standard_normal((nodes, classes)), jnp.float32)
    assert float(soft_ce(t, t, T)) <= float(soft_ce(s_other, t, T)) + 1e-6


@given(st.integers(2, 10), st.integers(1, 16))
@settings(**SETTINGS)
def test_hard_ce_nonnegative(classes, nodes):
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.standard_normal((nodes, classes)), jnp.float32)
    y = jnp.asarray(rng.integers(0, classes, nodes), jnp.int32)
    assert float(hard_ce(z, y)) >= 0.0


@given(st.lists(st.sampled_from([None, "data", "model", ("data", "model")]),
                min_size=1, max_size=4),
       st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64,
                                 100, 256]), min_size=1, max_size=4))
@settings(**SETTINGS)
def test_fit_spec_always_legal(entries, dims):
    """fit_spec output must always divide the shape."""
    import jax
    n = min(len(entries), len(dims))
    entries, dims = entries[:n], dims[:n]
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    out = fit_spec(P(*entries), tuple(dims), mesh)
    sizes = {"data": 4, "model": 4}
    for e, d in zip(tuple(out), dims):
        if e is None:
            continue
        axes = e if isinstance(e, tuple) else (e,)
        prod = int(np.prod([sizes[a] for a in axes]))
        assert d % prod == 0, (out, dims)


@given(st.sampled_from(["f32[16,128]{1,0}", "bf16[2,3,4]", "pred[]",
                        "(f32[8], s32[4,4])", "u8[100]"]))
@settings(**SETTINGS)
def test_shape_parse_consistency(s):
    assert _shape_bytes(s) >= _shape_elems(s) * 0  # parses without error


def test_adamw_converges_quadratic():
    """Optimizer sanity: minimize ||x - c||^2."""
    from repro.common import TrainConfig
    from repro.optim import adamw_init, adamw_update
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=0.0)
    c = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = adamw_init(params, tc)
    for _ in range(300):
        g = {"x": 2 * (params["x"] - c)}
        params, state, _ = adamw_update(g, state, params, tc, 0.1)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(c),
                               atol=1e-2)


def test_fit_spec_frozen_layers_dim():
    """The stacked-scan layers dim must never receive a fallback axis."""
    import jax
    from repro.sharding.logical import fit_spec
    from repro.sharding import spec as logical_spec
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    # (layers=32, heads=40, hd=64): heads won't divide 4 -> axis must NOT
    # land on the frozen layers dim even though 32 % 4 == 0
    s = logical_spec("layers", "batch", "heads", None)
    out = fit_spec(s, (32, 6, 40, 64), mesh)
    assert tuple(out)[0] is None, out
