"""Beyond-paper ablation: serving batch size vs per-node cost.

Discovered while aligning baseline accounting (EXPERIMENTS.md): with
batched inductive inference, the supporting subgraphs of the batch nodes
OVERLAP, so per-node feature-processing MACs drop as the batch grows —
an effect the paper's fixed batch=500 evaluation never isolates. This
quantifies the amortization curve for vanilla (T_s=0) and NAI."""
from __future__ import annotations

from benchmarks.common import csv_row, dataset, grid_search_ts, trained
from repro.gnn import NAIConfig, accuracy, infer_all

BATCHES = (50, 125, 250, 500, 1000)


def run(name: str = "arxiv-like") -> list:
    rows = []
    g = dataset(name)
    cfg, params, _ = trained(name)
    ts = grid_search_ts(name)[2]
    for bs in BATCHES:
        van = infer_all(cfg, NAIConfig(t_s=0.0, t_min=1, t_max=cfg.k,
                                       batch_size=bs), params, g)
        nai = infer_all(cfg, NAIConfig(t_s=ts, t_min=1, t_max=cfg.k,
                                       batch_size=bs), params, g)
        n = len(g.test_idx)
        rows += [
            csv_row(f"ablation_batch/{name}/bs{bs}/vanilla",
                    1e6 * van.wall_time_s / n,
                    f"fp_macs={van.fp_macs:.0f};acc={accuracy(van, g):.4f}"),
            csv_row(f"ablation_batch/{name}/bs{bs}/NAI",
                    1e6 * nai.wall_time_s / n,
                    f"fp_macs={nai.fp_macs:.0f};acc={accuracy(nai, g):.4f};"
                    f"saving={1 - nai.fp_macs / max(van.fp_macs, 1):.2f}"),
        ]
    return rows
