"""rwkv6-3b "Finch" — attention-free SSM with data-dependent decay
[arXiv:2404.05892]. 32L, d_model 2560, d_ff 8960, vocab 65536, head_dim 64.
NAP (the paper's exit criterion) is inapplicable to the attention-free scan
(DESIGN.md §Arch-applicability); implemented without it. long_500k native."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # d_model / rwkv_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    pattern=("rwkv",),
    mlp_kind="gelu",         # unused by rwkv blocks (cmix has its own FFN)
    norm_kind="layernorm",
    use_rope=False,
    rwkv_head_dim=64,
)
