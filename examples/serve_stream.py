"""End-to-end serving driver (the paper's deployment scenario).

Simulates a stream of inference requests over unseen nodes arriving in
bursts, served by the batched NAI engine under a latency budget; reports
latency percentiles and the adaptive exit-order histogram for BOTH
serving paths:

* host     — numpy Algorithm 1 per batch (faithful reference)
* compiled — vectorized sampling -> bucket-padded packing -> one jitted
             propagate+classify step (segment-sum SpMM here; pass
             spmm_impl="block_ell" to drive the Pallas kernel, which on
             CPU runs in interpret mode and is an emulation, not a
             timing)

    PYTHONPATH=src python examples/serve_stream.py
"""
import time

import numpy as np

from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, load_dataset,
                       train_nai)
from repro.serving import NAIServingEngine

g = load_dataset("flickr-like", scale=0.03, seed=1)
cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=4, hidden=64,
                mlp_layers=2)
print(f"[setup] training on {g.name}: n={g.n} m={g.num_edges}")
params, _ = train_nai(cfg, g, DistillConfig(epochs_base=120,
                                            epochs_offline=60,
                                            epochs_online=60))

nai = NAIConfig(t_s=12.0, t_min=1, t_max=3, batch_size=256)
rng = np.random.default_rng(0)
n_bursts, burst = 8, 400
bursts = [rng.choice(g.test_idx, size=burst, replace=False)
          for _ in range(n_bursts)]

for mode, kw in (("host", {}), ("compiled", {"spmm_impl": "segment"})):
    engine = NAIServingEngine(cfg, nai, params, g, max_wait_s=0.005,
                              mode=mode, **kw)
    print(f"[serve:{mode}] {n_bursts} bursts x {burst} requests")
    t0 = time.perf_counter()
    for nodes in bursts:
        engine.submit(nodes)
        while engine.queue:
            engine.step()
    wall = time.perf_counter() - t0

    s = engine.stats.summary()
    print(f"[result:{mode}] served={s['served']} batches={s['batches']} "
          f"wall={wall:.2f}s")
    print(f"[result:{mode}] latency p50={s['p50_ms']:.1f}ms "
          f"p95={s['p95_ms']:.1f}ms p99={s['p99_ms']:.1f}ms")
    print(f"[result:{mode}] mean exit order={s['mean_exit_order']:.2f} "
          f"(k={cfg.k} would be vanilla)")
    print(f"[result:{mode}] exit histogram="
          f"{dict(sorted(engine.stats.exit_hist.items()))}")
    if mode == "compiled":
        print(f"[result:{mode}] jit compiles={engine.jit_stats['compiles']} "
              f"cache hits={engine.jit_stats['hits']} "
              f"(shape buckets keep steady-state compiles at 0)")
