"""Serving launcher.

  * GNN mode (the paper's scenario): batched NAI inference over a stream of
    unseen-node requests through repro.serving.NAIServingEngine.
  * LM mode: batched decode with KV cache for a (reduced) assigned arch,
    optionally with Adaptive-Depth Inference early exits.

    PYTHONPATH=src python -m repro.launch.serve --gnn pubmed-like --requests 2000
    PYTHONPATH=src python -m repro.launch.serve --arch granite-34b --smoke --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, smoke
from repro.models import decoder_lm as M


def serve_gnn(args) -> None:
    from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, load_dataset,
                           train_nai)
    from repro.serving import NAIServingEngine
    g = load_dataset(args.gnn, scale=args.scale, seed=args.seed)
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=args.k,
                    hidden=64, mlp_layers=2, dropout=0.1)
    dc = DistillConfig(epochs_base=args.epochs, epochs_offline=args.epochs // 2,
                       epochs_online=args.epochs // 2)
    print(f"[serve-gnn] training NAI model on {args.gnn} (n={g.n})...")
    params, _ = train_nai(cfg, g, dc)
    nai = NAIConfig(t_s=args.t_s, t_min=1, t_max=args.k // 2 + 1,
                    batch_size=args.batch)
    engine = NAIServingEngine(cfg, nai, params, g)

    rng = np.random.default_rng(args.seed)
    n_req = min(args.requests, len(g.test_idx))
    reqs = rng.choice(g.test_idx, size=n_req, replace=False)
    t0 = time.perf_counter()
    engine.submit(reqs)
    stats = engine.run_until_drained()
    dt = time.perf_counter() - t0
    s = stats.summary()
    print(f"[serve-gnn] served={s['served']} batches={s['batches']} "
          f"in {dt:.2f}s ({1e3 * dt / max(s['served'], 1):.2f} ms/req)")
    print(f"[serve-gnn] p50={s['p50_ms']:.1f}ms p95={s['p95_ms']:.1f}ms "
          f"p99={s['p99_ms']:.1f}ms mean_exit_order={s['mean_exit_order']:.2f}")
    print(f"[serve-gnn] exit histogram: {dict(sorted(stats.exit_hist.items()))}")


def serve_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    B, L = args.batch, args.tokens + 8
    cache = M.init_cache(cfg, B, L)
    rng = np.random.default_rng(args.seed)
    if cfg.is_encdec or cfg.num_image_tokens:
        n = cfg.encoder_seq if cfg.is_encdec else cfg.num_image_tokens
        fe = jnp.asarray(rng.standard_normal((B, n, cfg.d_model)),
                         jnp.dtype(cfg.dtype))
        cache = M.seed_frontend_cache(cfg, params, cache, fe)

    step = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    t0 = time.perf_counter()
    out_tokens = []
    for t in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"[serve-lm] {cfg.name}: {args.tokens} steps, batch {B}: "
          f"{1e3 * dt / args.tokens:.1f} ms/step (CPU, correctness run)")
    print(f"[serve-lm] sample continuation: {np.stack(out_tokens)[:8, 0]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gnn", default=None)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=100)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--t-s", type=float, default=16.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.gnn:
        serve_gnn(args)
    elif args.arch:
        serve_lm(args)
    else:
        ap.error("need --gnn or --arch")


if __name__ == "__main__":
    main()
