"""Linear-propagation scalable GNNs (paper §2.2) as per-order classifiers.

NAI needs one classifier f^(l) per propagation order l = 1..k. The base
model decides what f^(l) consumes:
    SGC   : X^(l)                      (linear/MLP head)
    S2GC  : mean(X^(0)..X^(l))
    SIGN  : concat(X^(0)..X^(l)) -> MLP
    GAMLP : node-wise attention over X^(0)..X^(l) -> MLP  (JK-attention form)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn.params import ParamDef, init_tree


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    base_model: str            # sgc | s2gc | sign | gamlp
    feat_dim: int
    num_classes: int
    k: int                     # max propagation order
    r: float = 0.5             # convolution coefficient (Eq. 1)
    hidden: int = 128
    mlp_layers: int = 2        # P in Table 1
    dropout: float = 0.2
    att_dim: int = 32          # GAMLP attention projection

    def input_dim(self, l: int) -> int:
        return self.feat_dim * (l + 1) if self.base_model == "sign" \
            else self.feat_dim


def classifier_defs(cfg: GNNConfig, l: int) -> Dict:
    """MLP head for order l (P=mlp_layers). SGC's paper form is linear —
    mlp_layers=1 reproduces it exactly."""
    dims = [cfg.input_dim(l)] + [cfg.hidden] * (cfg.mlp_layers - 1) \
        + [cfg.num_classes]
    layers = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        layers[f"w{i}"] = ParamDef((a, b), ("feature" if i == 0 else None, None))
        layers[f"b{i}"] = ParamDef((b,), (None,), "zeros")
    if cfg.base_model == "gamlp":
        layers["att_w"] = ParamDef((cfg.feat_dim, cfg.att_dim), ("feature", None), "small")
        layers["att_v"] = ParamDef((cfg.att_dim,), (None,), "small")
    return layers


def all_classifier_defs(cfg: GNNConfig) -> Dict[int, Dict]:
    return {l: classifier_defs(cfg, l) for l in range(1, cfg.k + 1)}


def init_classifiers(cfg: GNNConfig, key) -> Dict[int, Dict]:
    defs = all_classifier_defs(cfg)
    keys = jax.random.split(key, len(defs))
    return {l: init_tree(k, d, "float32")
            for (l, d), k in zip(sorted(defs.items()), keys)}


def _combine(cfg: GNNConfig, feats: jax.Array, l: int, p) -> jax.Array:
    """feats: (k+1, N, f) stacked propagation series X^(0..k)."""
    if cfg.base_model == "sgc":
        return feats[l]
    if cfg.base_model == "s2gc":
        return jnp.mean(feats[:l + 1], axis=0)
    if cfg.base_model == "sign":
        sub = feats[:l + 1]                                   # (l+1, N, f)
        return jnp.moveaxis(sub, 0, 1).reshape(feats.shape[1], -1)
    if cfg.base_model == "gamlp":
        sub = feats[:l + 1]
        scores = jnp.einsum("lnf,fa->lna", sub, p["att_w"])
        scores = jnp.einsum("lna,a->ln", jax.nn.tanh(scores), p["att_v"])
        w = jax.nn.softmax(scores, axis=0)                    # (l+1, N)
        return jnp.einsum("ln,lnf->nf", w, sub)
    raise ValueError(cfg.base_model)


def apply_classifier(cfg: GNNConfig, p, feats, l: int, *,
                     key: Optional[jax.Array] = None) -> jax.Array:
    """Logits of f^(l). feats (k+1, N, f) or (l+1, N, f). `key` enables
    dropout (training)."""
    x = _combine(cfg, jnp.asarray(feats), l, p)
    n_layers = cfg.mlp_layers
    for i in range(n_layers):
        if key is not None and cfg.dropout > 0:
            key, sub = jax.random.split(key)
            mask = jax.random.bernoulli(sub, 1 - cfg.dropout, x.shape)
            x = jnp.where(mask, x / (1 - cfg.dropout), 0.0)
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


def classification_macs(cfg: GNNConfig, l: int) -> int:
    """MACs per node for f^(l) (Table 1 / Table 3 accounting)."""
    dims = [cfg.input_dim(l)] + [cfg.hidden] * (cfg.mlp_layers - 1) \
        + [cfg.num_classes]
    macs = sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    if cfg.base_model == "gamlp":
        macs += (l + 1) * (cfg.feat_dim * cfg.att_dim + cfg.att_dim)
    return macs
