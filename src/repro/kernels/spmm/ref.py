"""Pure-jnp oracle for the block-ELL SpMM kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm.kernel import CB, RB


def ref_spmm_dense(src, dst, coef, n_pad, x, active):
    """Dense-materialized Â @ x with inactive row blocks zeroed.
    src/dst/coef: edge list (numpy); x (n_pad, F); active (n_rb,)."""
    A = np.zeros((n_pad, n_pad), np.float32)
    A[dst, src] = coef          # assumes deduped edges
    out = jnp.asarray(A) @ x.astype(jnp.float32)
    row_active = jnp.repeat(jnp.asarray(active) != 0, RB,
                            total_repeat_length=n_pad)
    return jnp.where(row_active[:, None], out, 0.0).astype(x.dtype)


def ref_spmm_tiles(tiles, tile_col, valid, active, x):
    """Oracle on the block-ELL operands themselves (catches converter bugs
    separately from kernel bugs)."""
    n_rb, max_tb = tile_col.shape
    F = x.shape[1]
    out = jnp.zeros((n_rb * RB, F), jnp.float32)
    xs = x.astype(jnp.float32)
    for rb in range(n_rb):
        if int(active[rb]) == 0:
            continue
        acc = jnp.zeros((RB, F), jnp.float32)
        for t in range(max_tb):
            if int(valid[rb, t]) == 0:
                continue
            cb = int(tile_col[rb, t])
            acc = acc + tiles[rb, t].astype(jnp.float32) @ xs[cb * CB:(cb + 1) * CB]
        out = out.at[rb * RB:(rb + 1) * RB].set(acc)
    return out.astype(x.dtype)
