"""Per-kind transformer blocks. One `layer_defs`/`apply_layer` pair covers
every layer kind in `repro.common.LAYER_KINDS`; the model trunk scans these.

apply_layer contract:
    x, cache, aux = apply_layer(cfg, kind, p, x, mode=...,
                                positions=..., cache=..., frontend=...,
                                pos=..., aux=...)
  mode     : 'train' | 'prefill' | 'decode'
  cache    : kind-specific pytree (see init_layer_cache) or None for 'train'
  frontend : stub embeddings (images / encoder output) for xattn/encdec
  pos      : scalar decode position
  aux      : accumulated auxiliary loss (MoE load balance)
"""
from __future__ import annotations


import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import rglru as rg
from repro.nn import rwkv as rk
from repro.nn.basic import apply_mlp, apply_norm, mlp_defs, norm_defs
from repro.nn.moe import apply_moe, apply_moe_decode, moe_defs
from repro.nn.params import ParamDef


# --------------------------------------------------------------------- defs
def layer_defs(cfg, kind: str):
    if kind in ("attn", "local", "enc"):
        return {"norm1": norm_defs(cfg), "attn": attn.attn_defs(cfg),
                "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg)}
    if kind == "attn_moe":
        return {"norm1": norm_defs(cfg), "attn": attn.attn_defs(cfg),
                "norm2": norm_defs(cfg), "moe": moe_defs(cfg)}
    if kind == "rglru":
        return {"norm1": norm_defs(cfg), "rglru": rg.rglru_defs(cfg),
                "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg)}
    if kind == "rwkv":
        return {"norm1": norm_defs(cfg), "norm2": norm_defs(cfg),
                **rk.rwkv_defs(cfg)}
    if kind == "xattn":
        return {"norm1": norm_defs(cfg), "xattn": attn.attn_defs(cfg, cross=True),
                "gate_attn": ParamDef((), (), "zeros"),
                "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg),
                "gate_mlp": ParamDef((), (), "zeros")}
    if kind == "encdec":
        return {"norm1": norm_defs(cfg), "attn": attn.attn_defs(cfg),
                "normx": norm_defs(cfg), "xattn": attn.attn_defs(cfg, cross=True),
                "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg)}
    raise ValueError(f"unknown layer kind {kind!r}")


# -------------------------------------------------------------------- cache
def init_layer_cache(cfg, kind: str, batch: int, length: int, dtype):
    """`length` = max decode length (KV cache size). Windowed layers use a
    ring buffer of `min(window, length)`."""
    if kind in ("attn", "attn_moe", "encdec"):
        c = attn.init_kv_cache(cfg, batch, length, dtype)
    elif kind == "local":
        w = min(cfg.sliding_window or length, length)
        c = attn.init_kv_cache(cfg, batch, w, dtype)
    elif kind == "rglru":
        return rg.init_rglru_cache(cfg, batch, dtype)
    elif kind == "rwkv":
        return rk.init_rwkv_cache(cfg, batch, dtype)
    elif kind == "xattn":
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        n = cfg.num_image_tokens
        z = jnp.zeros((batch, n, KV, hd), dtype)
        return {"xk": z, "xv": z}
    else:
        raise ValueError(kind)
    if kind == "encdec":
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        z = jnp.zeros((batch, cfg.encoder_seq, KV, hd), dtype)
        c = dict(c, xk=z, xv=z)
    return c


# -------------------------------------------------------------------- apply
def apply_layer(cfg, kind: str, p, x, *, mode: str = "train",
                positions=None, cache=None, frontend=None, pos=None, aux=0.0):
    if mode == "decode":
        return _decode_layer(cfg, kind, p, x, cache, frontend, pos, aux)
    return _full_layer(cfg, kind, p, x, positions, frontend, mode, aux)


def _full_layer(cfg, kind, p, x, positions, frontend, mode, aux):
    new_cache = None
    if kind == "rwkv":
        B = x.shape[0]
        H, hd = rk._heads(cfg)
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
        h, state = rk.rwkv_time_mix_full(cfg, p["tmix"], apply_norm(cfg, p["norm1"], x), state)
        x = x + h
        xn = apply_norm(cfg, p["norm2"], x)
        x = x + rk.rwkv_channel_mix_full(cfg, p["cmix"], xn)
        if mode == "prefill":
            new_cache = {"state": state,
                         "x_t": x[:, -1, :] * 0,  # overwritten below
                         "x_c": xn[:, -1, :]}
            # tmix shift state = last *normed* input token to tmix
            new_cache["x_t"] = apply_norm(cfg, p["norm1"], x)[:, -1, :]
        return x, new_cache, aux

    if kind == "rglru":
        h, h_last, conv_tail = rg.rglru_full(cfg, p["rglru"],
                                             apply_norm(cfg, p["norm1"], x))
        x = x + h
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": conv_tail}
        return x, new_cache, aux

    if kind == "xattn":
        xk, xv = attn.project_kv(cfg, p["xattn"], frontend)
        h = attn.cross_attention(cfg, p["xattn"], apply_norm(cfg, p["norm1"], x),
                                 (xk, xv))
        x = x + jnp.tanh(p["gate_attn"]) * h
        h = apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        x = x + jnp.tanh(p["gate_mlp"]) * h
        if mode == "prefill":
            new_cache = {"xk": xk, "xv": xv}
        return x, new_cache, aux

    # attention-style kinds
    window = cfg.sliding_window if kind == "local" else 0
    mask = None
    if kind == "enc":
        S = x.shape[1]
        mask = jnp.ones((1, S, S), bool)
    h, (k, v) = attn.self_attention(cfg, p["attn"],
                                    apply_norm(cfg, p["norm1"], x),
                                    positions, window=window, mask=mask)
    x = x + h
    if kind == "encdec":
        xk, xv = attn.project_kv(cfg, p["xattn"], frontend)
        h = attn.cross_attention(cfg, p["xattn"],
                                 apply_norm(cfg, p["normx"], x), (xk, xv))
        x = x + h
    xn = apply_norm(cfg, p["norm2"], x)
    if kind == "attn_moe":
        h, moe_aux = apply_moe(cfg, p["moe"], xn)
        aux = aux + moe_aux
    else:
        h = apply_mlp(cfg, p["mlp"], xn)
    x = x + h
    if mode == "prefill" and kind != "enc":
        new_cache = {"k": k, "v": v}
        if kind == "local":
            w = min(cfg.sliding_window, k.shape[1])
            new_cache = {"k": k[:, -w:], "v": v[:, -w:]}
        if kind == "encdec":
            new_cache = dict(new_cache, xk=xk, xv=xv)
    return x, new_cache, aux


def _decode_layer(cfg, kind, p, x, cache, frontend, pos, aux):
    if kind == "rwkv":
        xn = apply_norm(cfg, p["norm1"], x)
        h, state = rk.rwkv_tmix_decode(cfg, p["tmix"], xn, cache["state"],
                                       cache["x_t"])
        x = x + h
        xc = apply_norm(cfg, p["norm2"], x)
        x = x + rk.rwkv_cmix_decode(cfg, p["cmix"], xc, cache["x_c"])
        return x, {"state": state, "x_t": xn[:, 0, :], "x_c": xc[:, 0, :]}, aux

    if kind == "rglru":
        h, new_cache = rg.rglru_decode(cfg, p["rglru"],
                                       apply_norm(cfg, p["norm1"], x), cache)
        x = x + h
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        return x, new_cache, aux

    if kind == "xattn":
        h = attn.cross_attention(cfg, p["xattn"], apply_norm(cfg, p["norm1"], x),
                                 (cache["xk"], cache["xv"]))
        x = x + jnp.tanh(p["gate_attn"]) * h
        h = apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        x = x + jnp.tanh(p["gate_mlp"]) * h
        return x, cache, aux

    window = cfg.sliding_window if kind == "local" else 0
    h, kv = attn.decode_self_attention(cfg, p["attn"],
                                       apply_norm(cfg, p["norm1"], x),
                                       {"k": cache["k"], "v": cache["v"]},
                                       pos, window=window)
    x = x + h
    new_cache = dict(cache, k=kv["k"], v=kv["v"])
    if kind == "encdec":
        h = attn.cross_attention(cfg, p["xattn"], apply_norm(cfg, p["normx"], x),
                                 (cache["xk"], cache["xv"]))
        x = x + h
    xn = apply_norm(cfg, p["norm2"], x)
    if kind == "attn_moe":
        h, moe_aux = apply_moe_decode(cfg, p["moe"], xn)
        aux = aux + moe_aux
    else:
        h = apply_mlp(cfg, p["mlp"], xn)
    x = x + h
    return x, new_cache, aux
