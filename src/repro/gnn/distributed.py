"""Distributed feature propagation on the PropagationBackend stack.

This module used to carry a toy dense `shard_map` segment-sum that shared
zero code with the block-ELL/fused kernels the serving engine actually
runs — a dead end for scaling work. It is now a thin veneer over the
real stack: the whole graph is viewed as its own support (`
graph_as_support`), packed with `repro.gnn.packing.pack_support(
n_shards=D)` into the same shard-major row-partitioned operands serving
uses, and propagated by `repro.gnn.backends.run_propagation` under
shard_map — so ANY registered backend (``segment``, ``block_ell``,
``fused``) runs node-partitioned across the mesh's ``data`` axis, and
full-graph distributed propagation exercises exactly the code path that
serves batches. The old module's numeric oracles (host
`propagated_series` agreement) live on in tests/test_distributed_gnn.py
as cross-checks of the new path.

`distributed_nap_distances` keeps the feature-axis story: per-node
||x - x_inf|| with features sharded over ``model`` — a local partial
sum of squares plus a psum over the feature axis. Serving shards rows
(features are a few hundred wide; rows are the memory axis), but the
helper documents how a feature-sharded deployment would reduce Eq. 8.
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.gnn.backends import get_backend, pack_operands, run_propagation
from repro.gnn.packing import (pack_support, shard_batch_perm,
                               step_active_blocks)
from repro.gnn.sampler import Support
from repro.gnn.store import as_store


def graph_as_support(g, r: float = 0.5) -> Support:
    """The whole graph viewed as its own support: every node is a batch
    node at hop 0 and the induced subgraph is the graph itself. Feeding
    this through `pack_support(n_shards=D)` turns full-graph propagation
    into the serving engine's sharded operand problem. `g` is a
    `GraphStore` (or a raw `Graph`, wrapped): the edge list and
    coefficients come from the store's CSR views in CSR (dst-major)
    order, with degrees from the store-build metadata."""
    store = as_store(g)
    n = store.n
    src, dst = store.coo()
    return Support(nodes=np.arange(n, dtype=np.int64),
                   hop=np.zeros(n, np.int32), n_batch=n,
                   src=src, dst=dst,
                   coef=store.edge_coefficients(r),
                   sub_edges=store.num_edges)


def pack_graph(g, n_shards: int, r: float = 0.5,
               spmm_impl: str = "segment", *, nb_bucket=None,
               s_bucket=None, tb_bucket=None, halo: bool = False,
               stationary: bool = False):
    """(backend, PackedSupport) for full-graph propagation.

    Default (`stationary=False`, the `distributed_series` oracle path):
    exits are disabled downstream (t_min > t_max), so the stationary
    operands are inert — zero rank-1 factors for the fused backend, an
    all-zero dense x_inf otherwise. `stationary=True` (the offline
    full-graph NAI driver, `repro.launch.full_graph_infer`) packs the
    REAL Eq. 7 stationary state of the whole graph instead — the exact
    factors `repro.gnn.nai.support_stationary_factors` computes, cast
    f32 the same way the serving path casts them — so the Eq. 8 exit
    decision runs with the same arithmetic serving uses.

    Explicit buckets pin the padding geometry so runs at different
    shard counts are bit-comparable. `halo=True` emits the halo-frame
    metadata for the non-dense gather modes (full-graph partitions of a
    well-mixed graph reference most blocks, so expect a halo fraction
    near 1 — batch serving is where the halo pays)."""
    be = get_backend(spmm_impl)
    store = as_store(g)
    sup = graph_as_support(store, r)
    x0 = np.asarray(store.features, np.float32)
    f = x0.shape[1]
    if stationary:
        from repro.gnn.nai import support_stationary_factors
        c64, s64 = support_stationary_factors(store, sup, x0, r)
        factors = ((c64.astype(np.float32), s64.astype(np.float32))
                   if be.uses_factors else None)
        x_inf = (np.zeros((sup.n_batch, 0), np.float32)
                 if be.uses_factors
                 else (c64[:, None] * s64[None, :]).astype(np.float32))
    else:
        factors = ((np.zeros(sup.n_batch, np.float32),
                    np.zeros(f, np.float32)) if be.uses_factors else None)
        x_inf = np.zeros((sup.n_batch, 0 if be.uses_factors else f),
                         np.float32)
    packed = pack_support(sup, x0, x_inf, nb_bucket=nb_bucket,
                          s_bucket=s_bucket, tb_bucket=tb_bucket,
                          build_tiles=be.uses_tiles,
                          build_edges=be.uses_edges,
                          x_inf_factors=factors, n_shards=n_shards,
                          halo=halo)
    return be, packed


def distributed_series(mesh, g, k: int, r: float = 0.5,
                       spmm_impl: str = "segment", *,
                       interpret: bool = True, nb_bucket=None,
                       s_bucket=None, tb_bucket=None,
                       gather_mode: str = "dense"):
    """[X^(0..k)] computed with the sharded backend step; host-verifiable
    against `repro.gnn.graph.propagated_series`. The mesh's ``data`` axis
    size is the shard count (1 = single-device path). `gather_mode`
    selects the per-step frontier exchange (`repro.gnn.backends`)."""
    g = as_store(g)
    D = int(mesh.shape["data"]) if "data" in mesh.axis_names else 1
    halo = gather_mode != "dense" and D > 1
    be, packed = pack_graph(g, D, r, spmm_impl, nb_bucket=nb_bucket,
                            s_bucket=s_bucket, tb_bucket=tb_bucket,
                            halo=halo)
    # t_min > t_max keeps the threshold sentinel negative on every step,
    # so no node ever exits and the loop is pure propagation. NAIConfig
    # itself rejects that combination (a real serving config with it
    # silently returns -1 predictions), so this propagation-only use
    # passes the loop the raw attributes instead of a validated config.
    nai = SimpleNamespace(t_s=0.0, t_min=k + 1, t_max=k)
    sa = (step_active_blocks(packed.hop_rb, k) if be.uses_tiles else None)
    ops = {key: jnp.asarray(v)
           for key, v in pack_operands(be, packed, sa).items()}
    if be.uses_dense_x_inf:
        ops["x_inf"] = jnp.asarray(packed.x_inf)
    _, series = run_propagation(be, nai, ops, jnp.asarray(packed.x0),
                                packed.n_batch, interpret=interpret,
                                mesh=mesh if D > 1 else None,
                                gather_mode=gather_mode if halo
                                else "dense")
    if D > 1:
        series = series[:, shard_batch_perm(packed.n_batch, D), :]
    f = g.feat_dim
    return [series[ell, :g.n, :f] for ell in range(k + 1)]


def distributed_nap_distances(mesh, x, x_inf):
    """Per-node ||x - x_inf|| with features sharded over 'model': local
    partial sum of squares + psum over the feature axis."""

    def local(x, xi):
        d2 = jnp.sum(jnp.square(x - xi), axis=1, keepdims=True)
        return jax.lax.psum(d2, "model")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("data", "model"), P("data", "model")),
                   out_specs=P("data", None))
    return jnp.sqrt(fn(x, x_inf)[:, 0])
