"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block = [linear in (x, gate branches)] -> causal depthwise conv1d -> RG-LRU
-> gated output projection. Full-sequence mode uses an associative scan
(O(log T) depth — the TPU-native mapping of the sequential GPU kernel);
decode mode is a single state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.params import ParamDef
from repro.sharding import constrain

_C = 8.0  # Griffin's fixed scaling constant in a_t = exp(-c * softplus(Λ) * r_t)


def rglru_defs(cfg):
    d, w = cfg.d_model, cfg.resolved_rnn_width
    return {
        "w_x": ParamDef((d, w), ("embed", "rnn")),
        "w_gate": ParamDef((d, w), ("embed", "rnn")),
        "conv_w": ParamDef((cfg.conv1d_width, w), (None, "rnn"), "small"),
        "conv_b": ParamDef((w,), ("rnn",), "zeros"),
        "w_a": ParamDef((w, w), ("rnn", None), "small"),
        "w_i": ParamDef((w, w), ("rnn", None), "small"),
        "lam": ParamDef((w,), ("rnn",), "normal", 0.5),
        "w_out": ParamDef((w, d), ("rnn", "embed")),
    }


def _conv1d_full(p, x):
    """Causal depthwise conv; x (B,T,w)."""
    K = p["conv_w"].shape[0]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1], :] * p["conv_w"][i] for i in range(K))
    return out + p["conv_b"]


def _gates(p, xc):
    rf = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xc.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rf
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * i * xc.astype(jnp.float32)
    return a, b


def rglru_full(cfg, p, x):
    """x (B,T,d) -> (y (B,T,d), h_last (B,w), conv_tail (B,K-1,w))."""
    gate = jax.nn.gelu((x @ p["w_gate"]), approximate=True)
    xb = x @ p["w_x"]
    xc = _conv1d_full(p, xb)
    a, b = _gates(p, xc)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hh.astype(x.dtype)
    h = constrain(h, "batch", None, None)
    y = (h * gate) @ p["w_out"]
    K = p["conv_w"].shape[0]
    conv_tail = xb[:, -(K - 1):, :] if K > 1 else jnp.zeros(
        (x.shape[0], 0, xb.shape[-1]), xb.dtype)
    return constrain(y, "batch", "seq", "embed"), hh[:, -1, :], conv_tail


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    w, K = cfg.resolved_rnn_width, cfg.conv1d_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, w), dtype)}


def rglru_decode(cfg, p, x, cache):
    """x (B,1,d), cache {'h' (B,w) f32, 'conv' (B,K-1,w)} -> (y, cache)."""
    gate = jax.nn.gelu((x @ p["w_gate"]), approximate=True)
    xb = x @ p["w_x"]                                   # (B,1,w)
    K = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"], xb.astype(cache["conv"].dtype)],
                           axis=1)                      # (B,K,w)
    xc = jnp.einsum("bkw,kw->bw", hist, p["conv_w"]) + p["conv_b"]
    a, b = _gates(p, xc)                                # (B,w) f32
    h = a * cache["h"] + b
    y = ((h.astype(x.dtype) * gate[:, 0, :]) @ p["w_out"])[:, None, :]
    return y, {"h": h, "conv": hist[:, 1:, :]}
