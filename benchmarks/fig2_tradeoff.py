"""Figure 2: accuracy / inference-time trade-off — NAI_1..3 settings per
dataset vs vanilla."""
from __future__ import annotations

from benchmarks.common import csv_row, dataset, grid_search_ts, trained
from repro.gnn import NAIConfig, accuracy, infer_all
from repro.gnn.baselines import run_vanilla

DATASETS = ["pubmed-like", "flickr-like", "arxiv-like", "products-like"]


def run(datasets=DATASETS) -> list:
    rows = []
    for name in datasets:
        g = dataset(name)
        cfg, params, _ = trained(name)
        n = len(g.test_idx)
        van = run_vanilla(cfg, g, params)
        rows.append(csv_row(f"fig2/{name}/SGC", 1e6 * van.time_s / n,
                            f"acc={van.acc:.4f}"))
        qs = grid_search_ts(name)
        settings = {
            "NAI1": NAIConfig(t_s=qs[4], t_min=1, t_max=2, batch_size=500),
            "NAI2": NAIConfig(t_s=qs[2], t_min=1, t_max=max(cfg.k - 1, 2),
                              batch_size=500),
            "NAI3": NAIConfig(t_s=qs[0], t_min=1, t_max=cfg.k,
                              batch_size=500),
        }
        for tag, nc in settings.items():
            res = infer_all(cfg, nc, params, g)
            rows.append(csv_row(
                f"fig2/{name}/{tag}", 1e6 * res.wall_time_s / n,
                f"acc={accuracy(res, g):.4f};fp_macs={res.fp_macs:.0f}"))
    return rows
