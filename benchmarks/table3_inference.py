"""Table 3: inference comparison — NAI vs vanilla SGC / GLNN / TinyGNN /
Quantization on four datasets. Metrics: ACC, total MACs/node, FP MACs/node,
time/node, FP time/node, plus acceleration ratios vs vanilla.

Also reports the two serving paths of `NAIServingEngine` on the same
trained model: `serve-host` (numpy Algorithm 1 per batch) vs
`serve-compiled` (vectorized sampling -> bucket-padded packing -> one
jitted propagate+classify step). The full-test-set compiled rows use the
segment-sum SpMM — on CPU the Pallas kernels only run in interpret mode
(emulation, not a timing; their structural numbers live in kernel_bench).
A separate `serve-compiled-impl/*` trio drains the SAME capped node subset
through one engine per `spmm_impl` (segment / block_ell / fused) so the
three propagation operators are comparable side by side on identical
batches."""
from __future__ import annotations

import time


from benchmarks.common import csv_row, dataset, grid_search_ts, trained
from repro.gnn import NAIConfig, accuracy, infer_all
from repro.gnn.baselines import (run_glnn, run_quantized, run_tinygnn,
                                 run_vanilla)
from repro.serving import EngineStats, NAIServingEngine

DATASETS = ["pubmed-like", "flickr-like", "arxiv-like", "products-like"]


def _serve(mode: str, cfg, nai, params, g, nodes, passes: int = 1, **kw):
    """Drain `nodes` through one engine (`passes` times; only the last
    pass is recorded — earlier passes warm the jit shape buckets).
    Returns (stats, batch records, engine); each record is
    (wall_s, nodes_served, compiled_this_batch)."""
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0, mode=mode,
                           **kw)
    for p in range(max(passes, 1)):
        if p == max(passes, 1) - 1:
            eng.stats = EngineStats()      # report only the recorded pass
        records = []
        for i in range(0, len(nodes), nai.batch_size):
            eng.submit(nodes[i:i + nai.batch_size])
            served0 = eng.stats.served
            compiles0 = eng.jit_stats["compiles"]
            t0 = time.perf_counter()
            eng.step()
            records.append((time.perf_counter() - t0,
                            eng.stats.served - served0,
                            eng.jit_stats["compiles"] > compiles0))
    return eng.stats, records, eng


def run(datasets=DATASETS) -> list:
    rows = []
    for name in datasets:
        g = dataset(name)
        cfg, params, _ = trained(name)
        n_test = len(g.test_idx)

        van = run_vanilla(cfg, g, params)
        glnn = run_glnn(cfg, g, params["cls"][cfg.k], epochs=150)
        tiny = run_tinygnn(cfg, g, params["cls"][cfg.k], epochs=150)
        quant = run_quantized(cfg, g, params)

        # speed-first NAI (the paper's NAI_1): aggressive threshold
        ts = grid_search_ts(name)[3]
        nai = infer_all(cfg, NAIConfig(t_s=ts, t_min=1, t_max=2,
                                       batch_size=500), params, g)
        nai_acc = accuracy(nai, g)

        def us(t):
            return 1e6 * t / n_test

        rows += [
            csv_row(f"table3/{name}/SGC", us(van.time_s),
                    f"acc={van.acc:.4f};macs={van.macs:.0f};fp_macs={van.fp_macs:.0f}"),
            csv_row(f"table3/{name}/GLNN", us(glnn.time_s),
                    f"acc={glnn.acc:.4f};macs={glnn.macs:.0f};fp_macs=0"),
            csv_row(f"table3/{name}/TinyGNN", us(tiny.time_s),
                    f"acc={tiny.acc:.4f};macs={tiny.macs:.0f};fp_macs={tiny.fp_macs:.0f}"),
            csv_row(f"table3/{name}/Quantization", us(quant.time_s),
                    f"acc={quant.acc:.4f};macs={quant.macs:.0f};fp_macs={quant.fp_macs:.0f}"),
            csv_row(f"table3/{name}/NAI", us(nai.wall_time_s),
                    f"acc={nai_acc:.4f};macs={nai.total_macs:.0f};"
                    f"fp_macs={nai.fp_macs:.0f};"
                    f"macs_speedup={van.macs / max(nai.total_macs, 1):.1f}x;"
                    f"fp_speedup={van.fp_macs / max(nai.fp_macs, 1):.1f}x;"
                    f"time_speedup={van.time_s / max(nai.wall_time_s, 1e-9):.1f}x"),
        ]

        # serving paths (same model/threshold, full test set through the
        # engine); compiled warm = everything after the first batch, the
        # steady state a deployment sees
        ncfg = NAIConfig(t_s=ts, t_min=1, t_max=2, batch_size=500)
        sh, recs_h, _ = _serve("host", cfg, ncfg, params, g, g.test_idx)
        sc, recs_c, eng = _serve("compiled", cfg, ncfg, params, g,
                                 g.test_idx, passes=2, spmm_impl="segment")
        # warm = batches that triggered no jit compile (a partial last
        # batch lands in a fresh bucket and compiles, so "skip the first
        # batch" would miscount); pass 1 warmed every bucket, so pass 2
        # is the steady state a deployment sees
        warm = [(w, s) for w, s, compiled in recs_c if not compiled]
        warm_wall = sum(w for w, _ in warm)
        warm_nodes = sum(s for _, s in warm)
        warm_us = 1e6 * warm_wall / warm_nodes if warm_nodes else float("nan")
        rows += [
            csv_row(f"table3/{name}/NAI-serve-host",
                    us(sum(w for w, _, _ in recs_h)),
                    f"p50_ms={sh.summary()['p50_ms']:.1f};"
                    f"mean_exit={sh.summary()['mean_exit_order']:.2f}"),
            csv_row(f"table3/{name}/NAI-serve-compiled",
                    us(sum(w for w, _, _ in recs_c)),
                    f"p50_ms={sc.summary()['p50_ms']:.1f};"
                    f"mean_exit={sc.summary()['mean_exit_order']:.2f};"
                    f"jit_compiles={eng.jit_stats['compiles']};"
                    f"jit_hits={eng.jit_stats['hits']};"
                    f"warm_us_per_node={warm_us:.1f}"),
        ]

        # ---- spmm_impl trio on identical batches: the Pallas impls run
        # in interpret mode on CPU (emulation — relative numbers only;
        # the per-step kernel latency comparison lives in kernel_bench),
        # so cap batch and subset to keep this a side-by-side, not a soak
        tcfg = NAIConfig(t_s=ts, t_min=1, t_max=2, batch_size=128)
        subset = g.test_idx[:min(len(g.test_idx), 2 * tcfg.batch_size)]
        impl_wall = {}
        for impl in ("segment", "block_ell", "fused"):
            si, recs_i, eng_i = _serve("compiled", cfg, tcfg, params, g,
                                       subset, passes=2, spmm_impl=impl)
            warm_i = [(w, s) for w, s, compiled in recs_i if not compiled]
            wall = sum(w for w, _ in warm_i)
            nodes_served = sum(s for _, s in warm_i)
            impl_wall[impl] = wall
            speed = ""
            if impl == "fused" and impl_wall.get("block_ell"):
                speed = (f";speedup_vs_block_ell="
                         f"{impl_wall['block_ell'] / max(wall, 1e-9):.2f}x")
            rows.append(csv_row(
                f"table3/{name}/NAI-serve-compiled-impl/{impl}",
                1e6 * wall / max(nodes_served, 1),
                f"nodes={nodes_served};"
                f"mean_exit={si.summary()['mean_exit_order']:.2f};"
                f"jit_compiles={eng_i.jit_stats['compiles']}" + speed))
    return rows
