from repro.kernels.nap_step.kernel import CB, FB, RB, nap_step_fused
from repro.kernels.nap_step.ops import fused_step, two_launch_step
from repro.kernels.nap_step.ref import ref_nap_step

__all__ = ["CB", "FB", "RB", "nap_step_fused", "fused_step",
           "two_launch_step", "ref_nap_step"]
