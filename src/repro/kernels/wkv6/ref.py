"""Oracle for the WKV6 kernel: the direct per-timestep recurrence (the
mathematical definition of RWKV6 time mixing)."""
from __future__ import annotations

import numpy as np


def ref_wkv6_sequential(r, k, v, logw, u):
    """Direct recurrence, numpy f64. r/k/v/logw (BH, T, hd); u (BH, hd)."""
    r, k, v, logw, u = (np.asarray(a, np.float64) for a in (r, k, v, logw, u))
    BH, T, hd = r.shape
    out = np.zeros((BH, T, hd))
    for b in range(BH):
        S = np.zeros((hd, hd))
        for t in range(T):
            kv = np.outer(k[b, t], v[b, t])
            out[b, t] = r[b, t] @ (S + u[b][:, None] * kv)
            S = np.exp(logw[b, t])[:, None] * S + kv
    return out.astype(np.float32)
