"""Figure 3: sensitivity of Inception Distillation to T, lambda, r
(f^(1) accuracy on flickr-like)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALES, csv_row
from repro.gnn import DistillConfig, GNNConfig, evaluate_classifier, train_nai
from repro.gnn.graph import propagated_series


def run(name: str = "flickr-like") -> list:
    from repro.gnn import load_dataset
    g = load_dataset(name, scale=SCALES[name], seed=0, hard=True)
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=3,
                    hidden=64, mlp_layers=2, dropout=0.0)
    series = np.stack(propagated_series(g, g.features, cfg.k))
    rows = []

    def acc_with(dc: DistillConfig) -> float:
        params, _ = train_nai(cfg, g, dc)
        return evaluate_classifier(cfg, params["cls"][1], series, g.labels,
                                   g.test_idx, 1)

    base = dict(epochs_base=120, epochs_offline=60, epochs_online=60)
    for T in (1.0, 1.2, 1.5, 2.0):
        a = acc_with(DistillConfig(temperature=T, **base))
        rows.append(csv_row(f"fig3/{name}/T={T}", 0.0, f"f1_acc={a:.4f}"))
    for lam in (0.1, 0.5, 0.8, 1.0):
        a = acc_with(DistillConfig(lam=lam, **base))
        rows.append(csv_row(f"fig3/{name}/lam={lam}", 0.0, f"f1_acc={a:.4f}"))
    for r in (1, 2, 3):
        a = acc_with(DistillConfig(ensemble_r=r, **base))
        rows.append(csv_row(f"fig3/{name}/r={r}", 0.0, f"f1_acc={a:.4f}"))
    return rows
