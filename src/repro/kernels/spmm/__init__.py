from repro.kernels.spmm.kernel import CB, FB, RB, spmm_block_ell
from repro.kernels.spmm.ops import (BlockEll, active_blocks_from_nodes,
                                    build_block_ell, pad_features, spmm)
from repro.kernels.spmm.ref import ref_spmm_dense, ref_spmm_tiles

__all__ = ["CB", "FB", "RB", "spmm_block_ell", "BlockEll",
           "active_blocks_from_nodes", "build_block_ell", "pad_features",
           "spmm", "ref_spmm_dense", "ref_spmm_tiles"]
