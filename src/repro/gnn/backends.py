"""Propagation backends: one interface over every SpMM implementation.

Before this module the three `spmm_impl` choices (``segment`` edge-list
segment-sum, ``block_ell`` Pallas SpMM + jnp exit distance, ``fused``
one-kernel SpMM+exit) each carried their own branch in
`repro.gnn.nai.infer_batch_masked`, their own operand-dict construction in
the serving engine, and no story for running across devices. Here every
implementation is a `PropagationBackend` registered in `BACKENDS`:

* ``step(operands, x_full, node_active, active_rb, ts2, ...)`` — ONE NAP
  propagation step: consume the (gathered) feature state, produce the
  propagated rows this backend owns plus the per-batch-node exit flags.
  The exit arithmetic is pinned to squared-f32 distance vs the squared
  threshold (negative threshold = exits disabled this step), exactly what
  the fused kernel computes in VMEM, so exit orders are bit-consistent
  across backends.
* `run_propagation` — the ONE masked NAP fori-loop (previously
  triplicated): carries ``(x, series, exit_order, live)``, asks the
  backend for each step, and runs either single-device or **sharded**
  under `shard_map` when given a mesh with a ``data`` axis.

Sharded execution (the scale story — supports larger than one device's
HBM): `repro.gnn.packing.pack_support(n_shards=D)` splits the padded
support rows round-robin by CB-row superblock across the ``data`` axis
(shard-major layout, every shard the same static shapes). Each step the
frontier rows a shard reads are rebuilt across node shards (features
stay unsharded: serving feature dims are a few hundred, rows are the
memory axis), each shard updates only the row blocks it owns, computes
exit distances for its own batch rows, and the global
any-batch-node-live flag is reduced with a `psum`. Because the packer
permutes whole CB superblocks, every tile keeps its single-device
contents and in-row-block accumulation order, so sharded propagation is
bit-identical to single-device — the parity oracle the sharded tests
hold us to. Operand partition specs are expressed through the logical
axis system (`repro.sharding.logical.spec`, rules ``row_shard`` /
``halo_shard``) so the same backend lowers on any mesh that names a
``data`` axis (e.g. `repro.launch.mesh.make_serving_mesh`).

**Frontier exchange** (``gather_mode=``): a shard's tiles only read the
CB column blocks named in its ``tile_col``, and that set is static at
pack time, so the exchange compiles to fixed shapes:

* ``"dense"`` — the PR-4 reference: `all_gather` the full (S_pad, f)
  frontier every step; interconnect bytes scale with total support
  size. Operands must be packed WITHOUT halo metadata (global
  coordinates).
* ``"halo"`` — operands packed with ``pack_support(halo=True)``: the
  loop still all-gathers, then each shard assembles its (H_pad·CB, f)
  halo frame with a static block gather and every backend consumes the
  frame instead of the full frontier. Compute-side win everywhere (the
  kernels' x operand shrinks to the true boundary); the interconnect
  win needs the ragged exchange below.
* ``"alltoall"`` — same halo pack; each shard sends exactly the blocks
  its peers' frames reference via one `jax.lax.all_to_all` per step
  (uniform (D·B_pad, CB, f) send/recv buffers from the packer's
  per-pair send lists), so interconnect bytes scale with the true
  boundary size instead of S_pad·D.

Frame rows are bit-identical copies of the dense frontier rows and tile
slot order never moves, so all three modes produce BIT-identical
predictions and exit orders (tests/test_sharded_serving.py).

Per-order classification also runs under shard_map when
`run_propagation` is given a ``classify`` hook: each shard classifies
its own batch rows and only the (nb,) argmax class ids and exit orders
leave the sharded region — the (T_max+1, nb, f) series and (nb, C)
logits are never replicated.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map

from repro.kernels.nap_step import nap_step_fused
from repro.kernels.spmm import spmm_block_ell
from repro.kernels.spmm.kernel import CB, RB
from repro.sharding.logical import spec

BACKENDS: Dict[str, "PropagationBackend"] = {}

GATHER_MODES = ("dense", "halo", "alltoall")

# halo-exchange operand specs (pack_support(halo=True) metadata): the
# leading axis is the owning shard, so every array block-slices to its
# shard exactly like the edge lists. These keys ride next to any
# backend's operand_logical — the backends themselves never see them
# (run_propagation pops them to build the frame gather).
HALO_LOGICAL: Dict[str, tuple] = {
    "halo_src_shard": ("halo_shard", None),
    "halo_src_block": ("halo_shard", None),
    "halo_send_block": ("halo_shard", None, None),
    "halo_frame_src": ("halo_shard", None),
}

# propagated-feature-cache seed operands (pack_support(seeds=...) packs):
# shard-stacked like the edge lists — the leading axis is the owning
# shard, seed row ids are shard-LOCAL. The NAP loop scatters
# `seed_vals[l-1]` over `seed_rows` after every step; backends never see
# these keys (`_masked_loop` pops them).
SEED_LOGICAL: Dict[str, tuple] = {
    "seed_rows": ("row_shard", None),
    "seed_vals": ("row_shard", None, None, None),
}


def operand_logical(backend: "PropagationBackend",
                    gather_mode: str = "dense",
                    seeds: bool = False) -> Dict[str, tuple]:
    """The backend's operand key -> logical dims table, grown with the
    halo specs for halo gather modes and the cache-seed specs for
    seeded packs — the ONE table the engine's device placement and
    `run_propagation`'s shard_map in_specs share."""
    table = dict(backend.operand_logical)
    if gather_mode != "dense":
        table.update(HALO_LOGICAL)
    if seeds:
        table.update(SEED_LOGICAL)
    return table


def register_backend(cls):
    """Class decorator: instantiate and register under ``cls.name``."""
    BACKENDS[cls.name] = cls()
    return cls


def get_backend(name: str) -> "PropagationBackend":
    if name not in BACKENDS:
        raise ValueError(f"unknown spmm_impl {name!r} "
                         f"(registered: {sorted(BACKENDS)})")
    return BACKENDS[name]


def normalize_mesh(mesh):
    """The ONE degenerate-mesh policy (every sharded entry point routes
    through here): None stays None, a mesh must name a ``data`` axis,
    and a data axis of size 1 collapses to None — the plain
    single-device path, so 1-device meshes cost no shard_map overhead
    and no CB*D batch padding."""
    if mesh is None:
        return None
    if "data" not in mesh.axis_names:
        raise ValueError(f"sharded propagation needs a 'data' mesh axis, "
                         f"got {mesh.axis_names}")
    return mesh if int(mesh.shape["data"]) > 1 else None


def _distance_exits(out, x_inf, ts2, n_batch):
    """Squared-f32 exit decision over the batch region — the arithmetic
    contract shared with the fused kernel (ts2 < 0 disables exits, since
    d2 >= 0 always)."""
    d2 = jnp.sum((out[:n_batch] - x_inf) ** 2, axis=1)
    return d2 < ts2


class PropagationBackend:
    """One NAP propagation step behind a uniform contract.

    Class attributes drive the rest of the stack generically:

    * ``uses_tiles`` — consumes block-ELL operands (``tiles``,
      ``tile_col``, ``valid``) plus the static ``step_active`` row-block
      predicate; the packer must build tiles.
    * ``uses_edges`` — consumes the bucket-padded edge list
      (``src``/``dst``/``coef``); the packer must build edges. Sharded,
      the edge arrays carry a leading shard axis and ``dst`` holds
      shard-LOCAL row ids.
    * ``uses_factors`` — consumes the rank-1 stationary-state factors
      (``c_inf``/``s_inf``) instead of a dense ``x_inf``.
    * ``uses_dense_x_inf`` — the exit distance is computed outside the
      kernel against the dense ``x_inf`` operand.
    * ``operand_logical`` — operand key -> logical dim names for the
      SHARDED layout (``row_shard`` = partitioned over the mesh's
      ``data`` axis, None = replicated); consumed by `run_propagation`'s
      shard_map specs and the engine's sharded device placement.
    """
    name: str = ""
    uses_tiles = False
    uses_edges = False
    uses_factors = False
    uses_dense_x_inf = True
    operand_logical: Dict[str, tuple] = {}

    def validate(self, operands: dict, x0, n_batch: int) -> None:
        """Raise ValueError on operand-contract violations (cheap, static
        shape checks only)."""

    def step(self, ops: dict, x_full, node_active, active_rb, ts2, *,
             n_batch: int, n_rows: int, interpret: bool
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One propagation + exit-decision step.

        ``x_full`` is the FULL (possibly all-gathered) feature state;
        ``node_active`` (n_batch,) int32 not-yet-exited flags;
        ``active_rb`` the (n_rb_local,) row-block predicate (None for
        backends without tiles); ``ts2`` the squared threshold (negative
        = exits disabled). Returns ``(x_out (n_rows, f), exits
        (n_batch,) bool)`` where ``x_out`` covers exactly the rows this
        shard owns.
        """
        raise NotImplementedError


@register_backend
class SegmentBackend(PropagationBackend):
    """jnp segment-sum over the edge list; every owned row updated every
    step (no tile predication — the baseline the kernels are measured
    against)."""
    name = "segment"
    uses_edges = True
    operand_logical = {
        "src": ("row_shard", None),
        "dst": ("row_shard", None),
        "coef": ("row_shard", None),
        "x_inf": ("row_shard", None),
    }

    def step(self, ops, x_full, node_active, active_rb, ts2, *,
             n_batch, n_rows, interpret):
        contrib = ops["coef"][:, None] * x_full[ops["src"]]
        out = jax.ops.segment_sum(contrib, ops["dst"], num_segments=n_rows)
        return out, _distance_exits(out, ops["x_inf"], ts2, n_batch)


@register_backend
class BlockEllBackend(PropagationBackend):
    """Pallas block-ELL SpMM kernel + separate jnp exit distance (one
    extra HBM read of the batch region per step)."""
    name = "block_ell"
    uses_tiles = True
    operand_logical = {
        "tiles": ("row_shard", None, None, None),
        "tile_col": ("row_shard", None),
        "valid": ("row_shard", None),
        "step_active": (None, "row_shard"),
        "x_inf": ("row_shard", None),
    }

    def step(self, ops, x_full, node_active, active_rb, ts2, *,
             n_batch, n_rows, interpret):
        out = spmm_block_ell(ops["tiles"], ops["tile_col"], ops["valid"],
                             active_rb, x_full, interpret=interpret)
        return out, _distance_exits(out, ops["x_inf"], ts2, n_batch)


@register_backend
class FusedBackend(PropagationBackend):
    """Fused NAP step kernel: SpMM accumulation, exit distance (rebuilt
    from the rank-1 stationary factors in VMEM) and per-node exit flags
    in one grid pass — the propagated block never round-trips HBM
    between matmul and distance check."""
    name = "fused"
    uses_tiles = True
    uses_factors = True
    uses_dense_x_inf = False
    operand_logical = {
        "tiles": ("row_shard", None, None, None),
        "tile_col": ("row_shard", None),
        "valid": ("row_shard", None),
        "step_active": (None, "row_shard"),
        "c_inf": ("row_shard",),
        "s_inf": (None,),
    }

    def validate(self, operands, x0, n_batch):
        S, f = x0.shape
        if n_batch % RB or S % CB:
            raise ValueError(
                f"fused path needs packed operands: n_batch {n_batch} "
                f"% RB, rows {S} % CB must be 0 (see repro.gnn.packing)")
        if "c_inf" not in operands or "s_inf" not in operands:
            raise ValueError("fused path needs x_inf_factors=(c, s), the "
                             "rank-1 stationary-state factors")
        c = operands["c_inf"].reshape(-1)
        s = operands["s_inf"].reshape(-1)
        if c.shape[0] != n_batch or s.shape[0] != f:
            raise ValueError(f"fused path needs factors padded to "
                             f"({n_batch},) and ({f},), got "
                             f"{c.shape} {s.shape}")

    def step(self, ops, x_full, node_active, active_rb, ts2, *,
             n_batch, n_rows, interpret):
        c_inf = ops["c_inf"].reshape(-1, 1).astype(x_full.dtype)
        s_inf = ops["s_inf"].reshape(1, -1).astype(x_full.dtype)
        out, exits, _blk_still = nap_step_fused(
            ops["tiles"], ops["tile_col"], ops["valid"], active_rb, x_full,
            c_inf, s_inf, node_active[:, None], ts2.reshape(1),
            interpret=interpret)
        # any(blk_still) == any(node_active & ~exits): the generic loop
        # recovers the live flag from exit_order, so blk_still is not
        # threaded out (it exists for two_launch parity of the raw kernel)
        return out, exits[:, 0] != 0


def pack_operands(backend: PropagationBackend, packed,
                  step_active=None) -> dict:
    """Host-side operand dict for a `repro.gnn.packing.PackedSupport`,
    keyed exactly as the backend's ``operand_logical`` (minus the dense
    ``x_inf``, which travels as its own argument through
    `make_compiled_infer`). One place instead of per-impl branches in
    every consumer (serving engine, distributed propagation, benches)."""
    ops = {}
    if backend.uses_tiles:
        if step_active is None:
            raise ValueError(f"{backend.name} needs the step_active "
                             f"row-block predicate")
        ops.update(tiles=packed.tiles, tile_col=packed.tile_col,
                   valid=packed.valid, step_active=step_active)
    if backend.uses_edges:
        ops.update(src=packed.src, dst=packed.dst, coef=packed.coef)
    if backend.uses_factors:
        ops.update(c_inf=packed.c_inf, s_inf=packed.s_inf)
    if packed.halo_src_shard is not None:
        ops.update(halo_src_shard=packed.halo_src_shard,
                   halo_src_block=packed.halo_src_block,
                   halo_send_block=packed.halo_send_block,
                   halo_frame_src=packed.halo_frame_src)
    if packed.seed_rows is not None:
        ops.update(seed_rows=packed.seed_rows, seed_vals=packed.seed_vals)
    return ops


# ------------------------------------------------------------ the loop
def _masked_loop(backend, nai, ops, x0, n_batch, n_rows, interpret,
                 gather, any_fn):
    """The ONE masked NAP fori-loop (previously triplicated per impl).

    Carries ``(x (n_rows, f), series (T_max+1, n_batch, f), exit_order
    (n_batch,), live ())`` where every row count is LOCAL to the shard
    when running under shard_map (`gather` rebuilds the full frontier,
    `any_fn` reduces the live flag across shards). Exit orders of 0
    after the loop mean never-exited and collapse to T_max.
    """
    tmax = nai.t_max
    f = x0.shape[1]
    ts2_on = jnp.float32(nai.t_s) ** 2
    sa = ops.get("step_active")
    seed_rows = ops.pop("seed_rows", None)
    seed_vals = ops.pop("seed_vals", None)
    if seed_rows is not None and seed_vals.shape[0] < tmax:
        # static guard: jnp dynamic indexing CLAMPS out-of-range, so a
        # too-short series would silently replay its last step
        raise ValueError(f"seed_vals covers {seed_vals.shape[0]} steps, "
                         f"loop needs {tmax}")

    def body(l, carry):
        x, series, exit_order, live = carry
        node_active = (exit_order == 0).astype(jnp.int32)
        # T_min/T_max gating via the threshold sentinel: a negative
        # squared threshold means nobody exits this step (shared with the
        # fused kernel, so gating arithmetic is identical across backends)
        ts2 = jnp.where((l >= nai.t_min) & (l < tmax), ts2_on,
                        jnp.float32(-1.0))
        active_rb = sa[l - 1] * live if sa is not None else None
        x, exits = backend.step(ops, gather(x), node_active, active_rb,
                                ts2, n_batch=n_batch, n_rows=n_rows,
                                interpret=interpret)
        exit_order = jnp.where((node_active != 0) & exits, l, exit_order)
        live = any_fn(exit_order == 0)
        # cache-hit rows: overwrite whatever the (edge-dropped) step left
        # there with the stored X^(l) values, so the NEXT step's gather
        # reads exact propagated features. Pad ids point one past the row
        # range — dropped. Batch rows are never seeded, so exits/series
        # (batch region only) are unaffected by scatter order.
        if seed_rows is not None:
            x = x.at[seed_rows].set(seed_vals[l - 1], mode="drop")
        # per-step history carries batch rows only (classification never
        # reads support rows; see ROADMAP "Pipelined serving")
        series = series.at[l].set(x[:n_batch])
        return x, series, exit_order, live

    series = jnp.zeros((tmax + 1, n_batch, f),
                       x0.dtype).at[0].set(x0[:n_batch])
    exit_order = jnp.zeros((n_batch,), jnp.int32)
    _, series, exit_order, _ = jax.lax.fori_loop(
        1, tmax + 1, body, (x0, series, exit_order, jnp.int32(1)))
    exit_order = jnp.where(exit_order == 0, tmax, exit_order)
    return exit_order, series


def _halo_gather(gather_mode: str, halo: dict, rows_loc: int):
    """Build the per-step frame-assembly `gather` from a shard's (local)
    halo metadata. Both modes return the (H_pad*CB, f) halo frame whose
    rows are bit-identical copies of the dense frontier rows the shard's
    frame-local tile_col/src indices name."""
    n_cb_loc = rows_loc // CB
    if gather_mode == "halo":
        # first implementation: the full frontier is still all-gathered,
        # then the frame is a static block gather out of it — the
        # kernels' x operand shrinks to the frame; the interconnect win
        # needs "alltoall"
        gblock = (halo["halo_src_shard"].astype(jnp.int32) * n_cb_loc
                  + halo["halo_src_block"].astype(jnp.int32))

        def gather(x):
            f = x.shape[-1]
            x_full = jax.lax.all_gather(x, "data", axis=0, tiled=True)
            return x_full.reshape(-1, CB, f)[gblock].reshape(-1, f)

        return gather

    # ragged exchange: each shard ships exactly the blocks its peers'
    # frames reference — one uniform (D*B_pad, CB, f) all_to_all; the
    # receive side drops into frame order via the packed recv slots
    send_idx = halo["halo_send_block"].astype(jnp.int32).reshape(-1)
    frame_src = halo["halo_frame_src"].astype(jnp.int32)

    def gather(x):
        f = x.shape[-1]
        send = x.reshape(n_cb_loc, CB, f)[send_idx]
        recv = jax.lax.all_to_all(send, "data", split_axis=0,
                                  concat_axis=0, tiled=True)
        return recv[frame_src].reshape(-1, f)

    return gather


def make_superstep(backend: PropagationBackend, nai, *, n_batch: int,
                   n_rows: int, interpret: bool = True, mesh=None,
                   gather_mode: str = "dense"):
    """One NAP propagation step as its own jitted callable — the unit
    of work of the offline full-graph driver
    (`repro.launch.full_graph_infer`), which checkpoints state between
    steps instead of running the whole fori-loop in one dispatch.

    Returns ``step(operands, x, exit_order, l) -> (x_new, exit_order)``
    replicating EXACTLY one iteration of `_masked_loop`'s body — the
    same threshold-sentinel T_min/T_max gating, the same row-block
    predicate (``step_active[l-1] * live``), the same exit-order
    update — so a chain of superstep calls from the same initial state
    is bit-identical to itself across interruption/resume (the driver's
    parity contract). The loop carry's ``live`` flag is recovered from
    the incoming ``exit_order`` (any batch row still at 0, psum-reduced
    across shards), which equals the value the fori-loop would carry in
    from the previous iteration. ``l`` is a traced int32 scalar, so ONE
    compilation serves every superstep of a run.

    Sharding follows `run_propagation`'s contract: with a mesh whose
    ``data`` axis is D > 1, operands must come from
    ``pack_support(n_shards=D)`` (plus halo metadata for non-dense
    ``gather_mode``), `x`/`exit_order` are in shard-major packed order,
    and outputs come back global in the same order. Cache-seed operands
    are not supported here (the offline driver packs without them).
    """
    if gather_mode not in GATHER_MODES:
        raise ValueError(f"unknown gather_mode {gather_mode!r} "
                         f"(one of {GATHER_MODES})")
    mesh = normalize_mesh(mesh)
    tmax = nai.t_max
    ts2_on = jnp.float32(nai.t_s) ** 2

    def body(ops, x, exit_order, l, gather, any_fn, nb, rows):
        node_active = (exit_order == 0).astype(jnp.int32)
        ts2 = jnp.where((l >= nai.t_min) & (l < tmax), ts2_on,
                        jnp.float32(-1.0))
        live = any_fn(exit_order == 0)
        sa = ops.get("step_active")
        active_rb = sa[l - 1] * live if sa is not None else None
        x, exits = backend.step(ops, gather(x), node_active, active_rb,
                                ts2, n_batch=nb, n_rows=rows,
                                interpret=interpret)
        exit_order = jnp.where((node_active != 0) & exits, l, exit_order)
        return x, exit_order

    if mesh is None:
        @jax.jit
        def step_single(operands, x, exit_order, l):
            backend.validate(operands, x, n_batch)
            return body(dict(operands), x, exit_order, l,
                        gather=lambda x: x,
                        any_fn=lambda m: jnp.any(m).astype(jnp.int32),
                        nb=n_batch, rows=n_rows)

        return step_single

    D = int(mesh.shape["data"])
    if n_batch % (CB * D) or n_rows % (CB * D):
        raise ValueError(
            f"sharded operands must be packed with n_shards={D}: "
            f"n_batch {n_batch} and rows {n_rows} must be multiples of "
            f"CB*D = {CB * D}")
    nb_loc, rows_loc = n_batch // D, n_rows // D
    logical = operand_logical(backend, gather_mode)
    keys = tuple(logical)
    in_specs = tuple(spec(*logical[k], mesh=mesh) for k in keys) + (
        spec("row_shard", None, mesh=mesh),   # x
        spec("row_shard", mesh=mesh),         # exit_order
        spec(mesh=mesh))                      # l (replicated scalar)
    out_specs = (spec("row_shard", None, mesh=mesh),
                 spec("row_shard", mesh=mesh))

    def local_fn(*args):
        (x, exit_order, l), args = args[-3:], args[:-3]
        ops = dict(zip(keys, args))
        if gather_mode == "dense":
            def gather(x):
                return jax.lax.all_gather(x, "data", axis=0, tiled=True)
        else:
            gather = _halo_gather(
                gather_mode, {k: ops.pop(k)[0] for k in HALO_LOGICAL},
                rows_loc)
        if backend.uses_edges:
            ops.update({k: ops[k][0] for k in ("src", "dst", "coef")})
        backend.validate(ops, x, nb_loc)
        return body(ops, x, exit_order, l, gather=gather,
                    any_fn=lambda m: (jax.lax.psum(
                        jnp.any(m).astype(jnp.int32), "data") > 0
                        ).astype(jnp.int32),
                    nb=nb_loc, rows=rows_loc)

    # check_rep=False for the same reason as run_propagation: parity
    # tests, not the rep tracker, are the correctness oracle
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)

    @jax.jit
    def step_sharded(operands, x, exit_order, l):
        missing = [k for k in keys if k not in operands]
        if missing:
            raise ValueError(f"sharded superstep is missing operands "
                             f"{missing}")
        return fn(*(operands[k] for k in keys), x, exit_order, l)

    return step_sharded


def run_propagation(backend: PropagationBackend, nai, operands: dict,
                    x0, n_batch: int, *, interpret: bool = True,
                    mesh=None, gather_mode: str = "dense",
                    classify=None, cls_params=None,
                    return_series: bool = False):
    """Run the masked NAP loop for any registered backend.

    ``operands`` holds the backend's packed arrays (including the dense
    ``x_inf`` for backends with ``uses_dense_x_inf``). Returns
    ``(exit_order (n_batch,), series (T_max+1, n_batch, f))`` — or
    ``(exit_order, preds (n_batch,))`` when ``classify`` is given:
    ``classify(cls_params, exit_order, series)`` runs right after the
    loop, INSIDE shard_map when sharded, so each shard classifies its
    own batch rows and only the argmax class ids are gathered (the
    series never leaves the sharded region). ``return_series=True``
    (with ``classify``) additionally returns the (T_max+1, n_batch, f)
    batch-row series as a third output — the propagated-feature cache's
    fill source; sharded it IS gathered off the mesh, in packed batch
    order like everything else.

    With ``mesh=None`` (or a ``data`` axis of size 1) this is the
    single-device path. Otherwise the loop runs under `shard_map`:
    operands must come from ``pack_support(..., n_shards=D)`` (row
    partition in shard-major superblock order) and the returned
    exit_order/series/preds are in the PACKED (permuted) batch order —
    undo with `repro.gnn.packing.shard_batch_perm`. ``gather_mode``
    picks the per-step frontier exchange (see the module docstring);
    halo modes require the halo metadata emitted by
    ``pack_support(halo=True)`` among the operands.
    """
    if gather_mode not in GATHER_MODES:
        raise ValueError(f"unknown gather_mode {gather_mode!r} "
                         f"(one of {GATHER_MODES})")
    mesh = normalize_mesh(mesh)
    has_halo = "halo_src_shard" in operands
    if mesh is None:
        if has_halo:
            raise ValueError("halo-packed operands (frame-local indices) "
                             "cannot run single-device — pack with "
                             "halo=False")
        backend.validate(operands, x0, n_batch)
        exit_order, series = _masked_loop(
            backend, nai, dict(operands), x0, n_batch, x0.shape[0],
            interpret, gather=lambda x: x,
            any_fn=lambda m: jnp.any(m).astype(jnp.int32))
        if classify is None:
            return exit_order, series
        preds = classify(cls_params, exit_order, series)
        if return_series:
            return exit_order, preds, series
        return exit_order, preds

    if (gather_mode != "dense") != has_halo:
        raise ValueError(
            f"gather_mode={gather_mode!r} and halo metadata disagree: "
            f"halo/alltoall need pack_support(halo=True) operands "
            f"(frame-local tile_col/src), dense needs global ones")
    D = int(mesh.shape["data"])
    S = x0.shape[0]
    if n_batch % (CB * D) or S % (CB * D):
        raise ValueError(
            f"sharded operands must be packed with n_shards={D}: n_batch "
            f"{n_batch} and rows {S} must be multiples of CB*D = {CB * D}")
    nb_loc, rows_loc = n_batch // D, S // D
    logical = operand_logical(backend, gather_mode,
                              seeds="seed_rows" in operands)
    keys = tuple(logical)
    arrays = [operands[k] for k in keys]
    in_specs = tuple(spec(*logical[k], mesh=mesh) for k in keys) \
        + (spec("row_shard", None, mesh=mesh),)
    series_spec = spec(None, "row_shard", None, mesh=mesh)
    out_specs = (spec("row_shard", mesh=mesh),
                 spec("row_shard", mesh=mesh) if classify is not None
                 else series_spec)
    if classify is not None and return_series:
        out_specs += (series_spec,)
    if classify is not None:
        in_specs += (spec(mesh=mesh),)   # replicated classifier tree

    def local_fn(*args):
        if classify is not None:
            args, params = args[:-1], args[-1]
        ops = dict(zip(keys, args[:-1]))
        x0_loc = args[-1]
        if gather_mode == "dense":
            def gather(x):
                return jax.lax.all_gather(x, "data", axis=0, tiled=True)
        else:
            # (D, ...) shard-stacked halo metadata block-slices to its
            # leading row — this shard's frame spec
            gather = _halo_gather(
                gather_mode, {k: ops.pop(k)[0] for k in HALO_LOGICAL},
                rows_loc)
        if backend.uses_edges:
            # (D, e) shard-stacked edge arrays block-slice to (1, e)
            ops.update({k: ops[k][0] for k in ("src", "dst", "coef")})
        if "seed_rows" in ops:
            # (D, k) / (D, L, k, f) shard-stacked seeds slice likewise
            ops.update(seed_rows=ops["seed_rows"][0],
                       seed_vals=ops["seed_vals"][0])
        backend.validate(ops, x0_loc, nb_loc)
        exit_order, series = _masked_loop(
            backend, nai, ops, x0_loc, nb_loc, rows_loc, interpret,
            gather=gather,
            any_fn=lambda m: (jax.lax.psum(jnp.any(m).astype(jnp.int32),
                                           "data") > 0).astype(jnp.int32))
        if classify is None:
            return exit_order, series
        preds = classify(params, exit_order, series)
        if return_series:
            return exit_order, preds, series
        return exit_order, preds

    # check_rep=False: the rep-tracker cannot see through the fori_loop
    # carry; correctness is covered by the bit-parity tests
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    if classify is not None:
        return fn(*arrays, x0, cls_params)
    return fn(*arrays, x0)
