"""Supporting-node sampling for inductive batches (Algorithm 1 line 3).

BFS from the batch nodes over the in-neighbor CSR up to `hops`, returning
the supporting set partitioned into hop layers plus the induced subgraph
(local ids, per-edge coefficients using GLOBAL degrees, per the paper).

The sampler is STORE-FIRST: it walks the `row_ptr` / `col_idx` /
`degrees` views of a `repro.gnn.store.GraphStore`, so the same code
serves an in-RAM `InMemoryStore` and a disk-backed `MmapStore` — the
only storage it ever materializes is the support itself. The sampler is
store-first: raw `Graph` arguments are a TypeError (the PR-7 deprecation
shim is gone) — wrap in-RAM graphs with `as_store` at the call site.

Per-batch cost is O(support), not O(n): the visited-set and local-id
maps are epoch-stamped scratch arrays cached on the store — no O(n)
allocation or memset per call, which at 1e7-node store scale is the
difference between the host stage tracking the support size and it
being dominated by clearing bookkeeping arrays.

`_sample_support_legacy` — the original per-node dict BFS — is NOT part
of the public API (dropped from `repro.gnn` in the store redesign); it
survives here only as the readable oracle the parity tests diff the
vectorized sampler against.

Batch ids must be duplicate-free (the serving engine dedupes per batch);
duplicates make the local-id map ambiguous in both implementations.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.gnn.store import GraphStore, as_store


@dataclasses.dataclass
class Support:
    nodes: np.ndarray          # (S,) global ids; nodes[:n_batch] == batch
    hop: np.ndarray            # (S,) BFS layer of each supporting node
    n_batch: int
    src: np.ndarray            # (Es,) LOCAL ids
    dst: np.ndarray            # (Es,) LOCAL ids
    coef: np.ndarray           # (Es,) propagation coefficients
    sub_edges: int             # undirected edge count of the subgraph
    # propagated-feature cache plumbing (None when sampled without one)
    hit: Optional[np.ndarray] = None        # (S,) bool cache-hit mask
    seed_vals: Optional[np.ndarray] = None  # (k_hit, t_max, F) series
    graph_version: int = 0     # store.mutation_clock at sample time
    def __len__(self):
        return len(self.nodes)


class _SamplerScratch:
    """Epoch-stamped visited/local-id maps, cached per store.

    `seen_stamp[v] == epoch` means v was discovered during the current
    call; bumping `epoch` invalidates everything in O(1) instead of an
    O(n) memset. Stamps are int64 — no wraparound within any realistic
    process lifetime."""

    def __init__(self, n: int):
        self.seen_stamp = np.zeros(n, np.int64)
        self.local_stamp = np.zeros(n, np.int64)
        self.local_id = np.zeros(n, np.int64)
        self.epoch = 0


def _scratch(store: GraphStore) -> _SamplerScratch:
    s = store.__dict__.get("_sampler_scratch")
    if s is None or len(s.seen_stamp) != store.n:
        s = _SamplerScratch(store.n)
        store.__dict__["_sampler_scratch"] = s
    return s


def _flat_neighbors(row_ptr: np.ndarray, col_idx: np.ndarray,
                    nodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenated CSR neighbor lists of `nodes`, in `nodes` order.
    Returns (neighbors, counts). On a memmapped CSR this gathers only
    the touched rows."""
    starts = np.asarray(row_ptr[nodes], np.int64)
    counts = np.asarray(row_ptr[nodes + 1], np.int64) - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, col_idx.dtype), counts
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    idx = np.arange(total, dtype=np.int64) + np.repeat(starts - offsets,
                                                       counts)
    return np.asarray(col_idx[idx]), counts


def _first_occurrence(a: np.ndarray) -> np.ndarray:
    """Unique values of `a` ordered by first occurrence (stable dedupe)."""
    _, first = np.unique(a, return_index=True)
    return a[np.sort(first)]


def sample_support(store, batch: np.ndarray, hops: int, r: float,
                   *, cache=None) -> Support:
    """Vectorized frontier expansion (numpy repeat/unique, no dicts)
    over a `GraphStore`'s CSR views. Store-first since PR 7: a raw
    `Graph` is a TypeError — wrap in-RAM graphs with
    `repro.gnn.store.as_store` (or `InMemoryStore`) at the call site.

    With `cache` (a `repro.gnn.propcache.PropCache`), each discovered
    layer is probed and hit nodes are marked in `Support.hit`, with
    their stored series in `Support.seed_vals`. The BFS still expands
    THROUGH hit nodes: the stationary exit factors (x_inf) depend on
    the full support's degrees/edges, so pruning the frontier at hits
    would change the exit decision and break cached-vs-cold bit-parity.
    The savings are downstream — hit rows' incoming edges are dropped
    from the packed block-ELL and their values seeded per step instead
    of recomputed (see `packing.pack_support`).
    """
    if not isinstance(store, GraphStore):
        raise TypeError(
            f"sample_support is store-first: expected a GraphStore, got "
            f"{type(store).__name__} (wrap an in-RAM Graph with "
            f"repro.gnn.store.as_store; the positional-Graph "
            f"deprecation shim was removed)")
    row_ptr, col_idx = store.csr()
    graph_version = store.mutation_clock
    scratch = _scratch(store)
    scratch.epoch += 1
    epoch, seen = scratch.epoch, scratch.seen_stamp
    batch = np.asarray(batch, np.int64)
    seen[batch] = epoch
    node_parts: List[np.ndarray] = [batch]
    hop_parts: List[np.ndarray] = [np.zeros(len(batch), np.int32)]
    # batch rows are never cache-served: their series IS the output
    hit_parts: List[np.ndarray] = [np.zeros(len(batch), bool)]
    frontier = batch
    for h in range(1, hops + 1):
        if len(frontier) == 0:
            break
        neigh, _ = _flat_neighbors(row_ptr, col_idx, frontier)
        cand = neigh[seen[neigh] != epoch].astype(np.int64)
        new = _first_occurrence(cand)
        seen[new] = epoch
        node_parts.append(new)
        hop_parts.append(np.full(len(new), h, np.int32))
        hit_parts.append(cache.probe(store, new) if cache is not None
                         else np.zeros(len(new), bool))
        frontier = new
    nodes = np.concatenate(node_parts)
    hop = np.concatenate(hop_parts)
    hit = np.concatenate(hit_parts) if cache is not None else None
    seed_vals = cache.gather(nodes[hit]) if cache is not None else None

    # induced edges (j -> i), ordered by destination's local id then CSR
    lstamp, lid = scratch.local_stamp, scratch.local_id
    lstamp[nodes] = epoch
    lid[nodes] = np.arange(len(nodes))
    neigh, counts = _flat_neighbors(row_ptr, col_idx, nodes)
    dst_all = np.repeat(np.arange(len(nodes), dtype=np.int64), counts)
    keep = lstamp[neigh] == epoch
    src = lid[neigh[keep]].astype(np.int32)
    dst = dst_all[keep].astype(np.int32)

    coef = _edge_coefs(store, nodes, src, dst, r)
    # count actual self loops (not one-per-node: graphs whose loops were
    # dropped, e.g. a train subgraph, would undercount otherwise)
    sub_edges = (len(src) - int((src == dst).sum())) // 2
    return Support(nodes=nodes, hop=hop, n_batch=len(batch), src=src,
                   dst=dst, coef=coef, sub_edges=max(sub_edges, 0),
                   hit=hit, seed_vals=seed_vals,
                   graph_version=graph_version)


def _edge_coefs(store: GraphStore, nodes: np.ndarray, src: np.ndarray,
                dst: np.ndarray, r: float) -> np.ndarray:
    # GLOBAL degrees (known at store build), gathered at support rows
    dt = (np.asarray(store.degrees[nodes]) + 1).astype(np.float64)
    return (dt[dst] ** (r - 1.0) * dt[src] ** (-r)).astype(np.float32)


def _sample_support_legacy(store, batch: np.ndarray, hops: int, r: float
                           ) -> Support:
    """Reference per-node dict BFS (original implementation). Test-only
    oracle — deliberately simple, quadratically slower, and absent from
    the public `repro.gnn` surface."""
    store = as_store(store)
    row_ptr, col_idx = store.csr()
    seen = {}
    order: List[int] = []
    hop_of: List[int] = []
    for b in batch:
        seen[int(b)] = 0
        order.append(int(b))
        hop_of.append(0)
    frontier = list(batch)
    for h in range(1, hops + 1):
        nxt = []
        for u in frontier:
            for v in col_idx[row_ptr[u]:row_ptr[u + 1]]:
                v = int(v)
                if v not in seen:
                    seen[v] = h
                    order.append(v)
                    hop_of.append(h)
                    nxt.append(v)
        frontier = nxt
    nodes = np.asarray(order, np.int64)
    local = {u: i for i, u in enumerate(order)}

    # induced edges (j -> i) for i in support whose source j is in support
    srcs, dsts = [], []
    for u in order:
        for v in col_idx[row_ptr[u]:row_ptr[u + 1]]:
            v = int(v)
            if v in local:
                dsts.append(local[u])
                srcs.append(local[v])
    src = np.asarray(srcs, np.int32)
    dst = np.asarray(dsts, np.int32)

    coef = _edge_coefs(store, nodes, src, dst, r)
    sub_edges = (len(src) - int((src == dst).sum())) // 2
    return Support(nodes=nodes, hop=np.asarray(hop_of, np.int32),
                   n_batch=len(batch), src=src, dst=dst, coef=coef,
                   sub_edges=max(sub_edges, 0))


# retired alias: import site for pre-store callers; the underscore name
# is the one the parity tests use
sample_support_legacy = _sample_support_legacy
