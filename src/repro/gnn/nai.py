"""Node-Adaptive Inference — Algorithm 1 of the paper.

Two execution paths:

* `infer_batch_host` — the faithful serving path. Real frontier shrinking:
  exited nodes drop out of the supporting set, later propagation steps touch
  fewer edges, and MAC counters track exactly the paper's four procedures
  (stationary state, feature propagation, distance computation,
  classification).

* `infer_batch_masked` — the compiled TPU path. Static shapes, a
  `lax.fori_loop` over orders with per-node active masks; compute saving is
  realized at tile granularity by the Pallas SpMM kernel's block
  predication (repro.kernels.spmm). Numerics match the host path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.graph import Graph
from repro.gnn.models import (GNNConfig, apply_classifier,
                              classification_macs)
from repro.gnn.sampler import Support, sample_support


@dataclasses.dataclass(frozen=True)
class NAIConfig:
    t_s: float = 0.1        # smoothness threshold T_s
    t_min: int = 1          # minimum propagation order
    t_max: int = 2          # maximum propagation order (<= k)
    batch_size: int = 500   # paper evaluates with batch 500


@dataclasses.dataclass
class NAIResult:
    predictions: np.ndarray      # (n_test,) argmax class
    orders: np.ndarray           # (n_test,) exit order per node (Table 4)
    macs: Dict[str, float]       # per-node averaged MACs by procedure
    fp_macs: float               # feature-processing MACs per node
    total_macs: float
    wall_time_s: float
    fp_time_s: float


def _subgraph_spmm(sup: Support, x: np.ndarray, active_nodes: np.ndarray
                   ) -> Tuple[np.ndarray, int]:
    """One propagation step restricted to edges whose destination is in
    `active_nodes` (bool mask over support). Returns (new_x, edges_used)."""
    emask = active_nodes[sup.dst]
    src, dst, coef = sup.src[emask], sup.dst[emask], sup.coef[emask]
    out = x.copy()
    acc = np.zeros_like(x)
    np.add.at(acc, dst, coef[:, None] * x[src])
    out[active_nodes] = acc[active_nodes]
    return out, int(emask.sum())


def support_stationary_factors(g: Graph, sup: Support, x0: np.ndarray,
                               r: float) -> Tuple[np.ndarray, np.ndarray]:
    """The stationary state Â^∞ X at the batch rows (Eq. 7) is rank-1 by
    construction; return its factors (c (n_batch,), s (f,)) in float64 so
    x_inf = c ⊗ s. The fused step kernel consumes the factors directly
    (it never materializes the dense x_inf)."""
    dt = (g.degrees[sup.nodes] + 1).astype(np.float64)
    denom = 2.0 * sup.sub_edges + len(sup)
    s = ((dt ** (1.0 - r))[:, None] * x0).sum(axis=0)
    c = (dt[:sup.n_batch] ** r) / denom
    return c, s


def support_stationary_state(g: Graph, sup: Support, x0: np.ndarray,
                             r: float) -> np.ndarray:
    """Rank-1 stationary state Â^∞ X at the batch rows (Eq. 7) over the
    sampled subgraph, float64. Shared by the host and compiled serving
    paths so their exit distances use the same arithmetic (the compiled
    path then casts to float32; nodes within f32 rounding of T_s may
    exit one order apart across paths)."""
    c, s = support_stationary_factors(g, sup, x0, r)
    return c[:, None] * s[None, :]


def _needed_mask(sup: Support, active_batch: np.ndarray, remaining_hops: int
                 ) -> np.ndarray:
    """Support nodes within `remaining_hops` of any active batch node —
    the only values the next propagation step must produce."""
    S = len(sup)
    dist = np.full(S, np.iinfo(np.int32).max, np.int32)
    dist[:sup.n_batch][active_batch] = 0
    in_frontier = np.zeros(S, bool)
    in_frontier[:sup.n_batch][active_batch] = True
    # reverse BFS over subgraph edges (dst -> src one hop per level); the
    # per-hop edge filter is an O(E) boolean gather over support ids, not
    # an np.isin merge-scan against the frontier list
    for h in range(1, remaining_hops + 1):
        if not in_frontier.any():
            break
        cand = sup.src[in_frontier[sup.dst]]
        new = cand[dist[cand] > h]
        dist[new] = h
        in_frontier[:] = False
        in_frontier[new] = True
    return dist <= remaining_hops


def infer_batch_host(cfg: GNNConfig, nai: NAIConfig, params, g: Graph,
                     batch_nodes: np.ndarray):
    """Algorithm 1 for one batch.
    Returns (preds, orders, macs, fp_time_s, wall_s)."""
    f = g.features.shape[1]
    t0 = time.perf_counter()
    sup = sample_support(g, batch_nodes, nai.t_max, cfg.r)
    nb = sup.n_batch
    x = g.features[sup.nodes].astype(np.float32)
    macs = {"stationary": 0.0, "propagation": 0.0, "distance": 0.0,
            "classification": 0.0}

    # line 2: stationary state over the sampled subgraph (Eq. 7, rank-1)
    x_inf = support_stationary_state(g, sup, x, cfg.r)
    macs["stationary"] += len(sup) * f + nb * f

    preds = np.full(nb, -1, np.int64)
    orders = np.zeros(nb, np.int64)
    active = np.ones(nb, bool)
    fp_t0 = time.perf_counter()
    fp_elapsed = 0.0

    series = [x]                                           # X^(0..l) at support
    for l in range(1, nai.t_max + 1):
        t_fp = time.perf_counter()
        needed = _needed_mask(sup, active, nai.t_max - l)
        x, edges = _subgraph_spmm(sup, series[-1], needed)
        series.append(x)
        macs["propagation"] += edges * f
        fp_elapsed += time.perf_counter() - t_fp

        if l < nai.t_min:
            continue
        exit_now = np.zeros(nb, bool)
        if l < nai.t_max:
            t_fp = time.perf_counter()
            d = np.linalg.norm(x[:nb][active] - x_inf[active], axis=1)
            macs["distance"] += active.sum() * f
            fp_elapsed += time.perf_counter() - t_fp
            idx = np.flatnonzero(active)
            exit_now[idx[d < nai.t_s]] = True
        else:
            exit_now = active.copy()
        if exit_now.any():
            feats_l = np.stack([s[:nb][exit_now] for s in series])  # (l+1,e,f)
            z = apply_classifier(cfg, params["cls"][l], jnp.asarray(feats_l), l)
            preds[exit_now] = np.asarray(jnp.argmax(z, -1))
            orders[exit_now] = l
            macs["classification"] += exit_now.sum() * classification_macs(cfg, l)
            active &= ~exit_now
        if not active.any():
            break
    wall = time.perf_counter() - t0
    macs = {k: v / nb for k, v in macs.items()}
    return preds, orders, macs, fp_elapsed, wall


def infer_all(cfg: GNNConfig, nai: NAIConfig, params, g: Graph,
              nodes: Optional[np.ndarray] = None) -> NAIResult:
    nodes = g.test_idx if nodes is None else nodes
    preds = np.empty(len(nodes), np.int64)
    orders = np.empty(len(nodes), np.int64)
    macs_sum: Dict[str, float] = {}
    fp_time = 0.0
    wall = 0.0
    for i in range(0, len(nodes), nai.batch_size):
        b = nodes[i:i + nai.batch_size]
        p, o, m, fp, w = infer_batch_host(cfg, nai, params, g, b)
        preds[i:i + len(b)] = p
        orders[i:i + len(b)] = o
        for k, v in m.items():
            macs_sum[k] = macs_sum.get(k, 0.0) + v * len(b)
        fp_time += fp
        wall += w
    n = len(nodes)
    macs = {k: v / n for k, v in macs_sum.items()}
    fp_macs = macs["propagation"] + macs["distance"]
    return NAIResult(
        predictions=preds, orders=orders, macs=macs, fp_macs=fp_macs,
        total_macs=sum(macs.values()), wall_time_s=wall, fp_time_s=fp_time)


def accuracy(result: NAIResult, g: Graph,
             nodes: Optional[np.ndarray] = None) -> float:
    nodes = g.test_idx if nodes is None else nodes
    return float((result.predictions == g.labels[nodes]).mean())


def order_distribution(result: NAIResult, k: int) -> np.ndarray:
    """Node count per exit order 1..k (paper Table 4)."""
    return np.bincount(result.orders, minlength=k + 1)[1:k + 1]


# --------------------------------------------------------------- jax masked
def infer_batch_masked(cfg: GNNConfig, nai: NAIConfig, params,
                       sup_src, sup_dst, sup_coef, x0, x_inf, n_batch: int,
                       *, spmm_impl: str = "segment", ell=None,
                       step_active=None, x_inf_factors=None,
                       interpret: bool = True):
    """Compiled NAP: fori over orders with exit masks (static shapes).

    Returns (exit_order (nb,), stacked BATCH-ROW features
    (T_max+1, n_batch, f)). The propagation state stays (S, f) inside the
    loop — every support row keeps propagating — but the per-step history
    written to the carry holds only the batch region: classification
    (`make_compiled_infer`) never reads support rows, and with T_max-hop
    supports S is routinely 10–50× n_batch, so carrying S rows per step
    was almost entirely dead HBM traffic.

    `spmm_impl` selects the propagation operator:

    * ``"segment"`` — jnp segment-sum over the edge list
      (sup_src/sup_dst/sup_coef); every row is updated every step.
    * ``"block_ell"`` — the Pallas block-ELL kernel. `ell` is the operand
      triple ``(tiles, tile_col, valid)`` and `step_active` is the
      (T_max, n_rb) static per-step row-block predicate from
      `repro.gnn.packing.step_active_blocks`; it is ANDed with the dynamic
      any-batch-node-still-active flag, so once the whole batch has exited
      every remaining step touches zero tiles. Rows in skipped blocks read
      as zero; by the hop argument in packing.py those values never reach
      a batch output. The exit distance is a separate jnp reduction over
      the propagated features (one extra HBM read per step).
    * ``"fused"`` — the fused step kernel (repro.kernels.nap_step): SpMM
      accumulation, exit distance, per-node exit flags and the next
      step's per-row-block still-active predicate in one grid pass, so
      the propagated block never round-trips through HBM between the
      matmul and the distance check. Same operands as ``block_ell`` plus
      `x_inf_factors=(c, s)` — the rank-1 stationary-state factors
      (x_inf = c ⊗ s, see `support_stationary_factors`) streamed into
      the kernel in place of the dense x_inf — and the squared threshold
      prefetched; requires the packed layout (n_batch a multiple of RB,
      T_min/T_max gating applied by disabling the threshold on un-gated
      steps).

    Per-order classification lives in `make_compiled_infer`, which wraps
    this core in one jitted function.
    """
    S, f = x0.shape
    tmax = nai.t_max

    if spmm_impl == "fused":
        from repro.kernels.nap_step import nap_step_fused
        from repro.kernels.spmm.kernel import CB, RB
        if n_batch % RB or S % CB:
            raise ValueError(
                f"fused path needs packed operands: n_batch {n_batch} "
                f"% RB, rows {S} % CB must be 0 (see repro.gnn.packing)")
        if x_inf_factors is None:
            raise ValueError("fused path needs x_inf_factors=(c, s), the "
                             "rank-1 stationary-state factors")
        c_inf = jnp.asarray(x_inf_factors[0], x0.dtype).reshape(-1, 1)
        s_inf = jnp.asarray(x_inf_factors[1], x0.dtype).reshape(1, -1)
        if c_inf.shape[0] != n_batch or s_inf.shape[1] != f:
            raise ValueError(f"fused path needs factors padded to "
                             f"({n_batch},) and ({f},), got "
                             f"{c_inf.shape} {s_inf.shape}")
        tiles, tile_col, valid = ell
        sa = jnp.asarray(step_active, jnp.int32)
        ts2_val = jnp.float32(nai.t_s) ** 2

        def body(l, carry):
            x, series, exit_order, live = carry
            active = sa[l - 1] * live
            nact = (exit_order == 0).astype(jnp.int32)[:, None]
            # T_min/T_max gating happens inside the kernel: a negative
            # squared threshold on un-gated steps means nobody exits
            ts2 = jnp.where((l >= nai.t_min) & (l < tmax),
                            ts2_val, jnp.float32(-1.0)).reshape(1)
            x, exits, blk_still = nap_step_fused(
                tiles, tile_col, valid, active, x, c_inf, s_inf, nact,
                ts2, interpret=interpret)
            series = series.at[l].set(x[:n_batch])
            exit_order = jnp.where(exits[:, 0] != 0, l, exit_order)
            # the kernel already emitted next step's dynamic predicate
            live = jnp.any(blk_still != 0).astype(jnp.int32)
            return x, series, exit_order, live

        series = jnp.zeros((tmax + 1, n_batch, f),
                           x0.dtype).at[0].set(x0[:n_batch])
        exit_order = jnp.zeros((n_batch,), jnp.int32)
        _, series, exit_order, _ = jax.lax.fori_loop(
            1, tmax + 1, body, (x0, series, exit_order, jnp.int32(1)))
        exit_order = jnp.where(exit_order == 0, tmax, exit_order)
        return exit_order, series

    if spmm_impl == "segment":
        def spmm(x, l, live):
            contrib = sup_coef[:, None] * x[sup_src]
            return jax.ops.segment_sum(contrib, sup_dst, num_segments=S)
    elif spmm_impl == "block_ell":
        from repro.kernels.spmm import spmm_block_ell
        tiles, tile_col, valid = ell
        sa = jnp.asarray(step_active, jnp.int32)

        def spmm(x, l, live):
            active = sa[l - 1] * live
            return spmm_block_ell(tiles, tile_col, valid, active, x,
                                  interpret=interpret)
    else:
        raise ValueError(f"unknown spmm_impl {spmm_impl!r}")

    def body(l, carry):
        x, series, exit_order = carry
        live = jnp.any(exit_order == 0).astype(jnp.int32)
        x = spmm(x, l, live)
        series = series.at[l].set(x[:n_batch])
        # squared comparison (not norm < t_s): the same arithmetic the
        # fused kernel uses, so exit orders stay bit-consistent across
        # the compiled impls even for distances at the threshold
        d2 = jnp.sum((x[:n_batch] - x_inf) ** 2, axis=1)
        can_exit = (exit_order == 0) & (l >= nai.t_min) & (l < tmax) \
            & (d2 < jnp.float32(nai.t_s) ** 2)
        exit_order = jnp.where(can_exit, l, exit_order)
        return x, series, exit_order

    series = jnp.zeros((tmax + 1, n_batch, f),
                       x0.dtype).at[0].set(x0[:n_batch])
    exit_order = jnp.zeros((n_batch,), jnp.int32)
    _, series, exit_order = jax.lax.fori_loop(
        1, tmax + 1, body, (x0, series, exit_order))
    exit_order = jnp.where(exit_order == 0, tmax, exit_order)
    return exit_order, series


def make_compiled_infer(cfg: GNNConfig, nai: NAIConfig, *,
                        spmm_impl: str = "block_ell",
                        interpret: bool = True,
                        donate: Optional[bool] = None):
    """One jitted function: masked NAP propagation + per-order
    classification (unrolled over orders, selected by exit mask).

    The returned callable takes ``(cls_params, operands, x0, x_inf)`` where
    `operands` is a dict — ``tiles/tile_col/valid/step_active`` for
    ``block_ell``, the same plus ``c_inf/s_inf`` (rank-1 stationary-state
    factors) for ``fused``, ``src/dst/coef`` for ``segment`` — and
    returns ``(predictions (nb,), exit_order (nb,))``. All shape
    specialization happens through jax.jit's cache; callers bucket their
    operand shapes (repro.gnn.packing) so repeat batches hit it. The
    number of traced shapes is exposed via the jitted function's
    ``_cache_size()``.

    `donate` hands the per-batch operands (``operands``, ``x0``,
    ``x_inf`` — NOT the classifier params, which persist across batches)
    to XLA as donated buffers, so bucketed repeat batches overwrite the
    previous batch's HBM allocations instead of growing the footprint.
    Default (None) enables donation everywhere except the CPU backend,
    which does not implement donation and would warn per compile. The
    effective donated argnums are exposed as ``run._donate_argnums``.
    """
    if spmm_impl not in ("segment", "block_ell", "fused"):
        raise ValueError(f"unknown spmm_impl {spmm_impl!r}")
    tmax = nai.t_max
    if donate is None:
        donate = jax.default_backend() != "cpu"
    donate_argnums = (1, 2, 3) if donate else ()

    @functools.partial(jax.jit, donate_argnums=donate_argnums)
    def run(cls_params, operands, x0, x_inf):
        nb = x_inf.shape[0]
        if spmm_impl in ("block_ell", "fused"):
            factors = (operands["c_inf"], operands["s_inf"]) \
                if spmm_impl == "fused" else None
            exit_order, series = infer_batch_masked(
                cfg, nai, None, None, None, None, x0, x_inf, nb,
                spmm_impl=spmm_impl,
                ell=(operands["tiles"], operands["tile_col"],
                     operands["valid"]),
                step_active=operands["step_active"],
                x_inf_factors=factors, interpret=interpret)
        else:
            exit_order, series = infer_batch_masked(
                cfg, nai, None, operands["src"], operands["dst"],
                operands["coef"], x0, x_inf, nb, spmm_impl="segment")
        preds = jnp.zeros((nb,), jnp.int32)
        for l in range(1, tmax + 1):
            # series already carries batch rows only (nb == series.shape[1])
            feats = series[:l + 1, :, :cfg.feat_dim]
            z = apply_classifier(cfg, cls_params[l], feats, l)
            preds = jnp.where(exit_order == l,
                              jnp.argmax(z, -1).astype(jnp.int32), preds)
        return preds, exit_order

    run._donate_argnums = donate_argnums
    return run
