"""Distributed propagation (shard_map) vs the host SpMM, on a small faked
multi-device mesh (this file forces 8 host devices; keep it isolated)."""
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.gnn import load_dataset, propagated_series
from repro.gnn.distributed import (distributed_nap_distances,
                                   distributed_series, partition_graph)

# jax 0.4.x: no axis_types / set_mesh — the helpers take the mesh
# explicitly, so no ambient-mesh context is needed
mesh = jax.make_mesh((4, 2), ("data", "model"))
g = load_dataset("pubmed-like", scale=0.02, seed=0)
k = 3
host = propagated_series(g, g.features, k)
dist = distributed_series(mesh, g, k)
for l in range(k + 1):
    d = np.asarray(dist[l])[:g.n]
    err = np.abs(d - host[l]).max()
    assert err < 2e-3, (l, err)

# NAP distance helper agrees with numpy
x = np.asarray(dist[k])
xi = np.zeros_like(x)
dd = np.asarray(distributed_nap_distances(mesh, jnp.asarray(x), jnp.asarray(xi)))
ref = np.linalg.norm(x, axis=1)
assert np.abs(dd - ref).max() < 2e-2, np.abs(dd - ref).max()
print("DISTRIBUTED_OK")
"""


def test_distributed_propagation_matches_host():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=480)
    assert "DISTRIBUTED_OK" in out.stdout, out.stdout + out.stderr
