from repro.kernels.flash_attention.kernel import BK, BQ, flash_attention
from repro.kernels.flash_attention.ops import gqa_flash_attention
from repro.kernels.flash_attention.ref import ref_attention

__all__ = ["BK", "BQ", "flash_attention", "gqa_flash_attention",
           "ref_attention"]
