"""Checkpoint round-trip, data pipeline, serving engine, schedules,
HLO analyzer, adaptive-depth decode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.common import AdaptiveDepthConfig, TrainConfig
from repro.configs import ARCHS, smoke
from repro.data import synthetic_lm_batch, synthetic_stream
from repro.models import decoder_lm as M
from repro.optim import make_schedule


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": (jnp.ones(4, jnp.bfloat16), jnp.zeros((), jnp.float32))}}
    path = str(tmp_path / "ckpt.msgpack")
    save_checkpoint(path, tree, step=7)
    out, step = load_checkpoint(path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_model_params(tmp_path):
    cfg = smoke(ARCHS["gemma-7b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "m.msgpack")
    save_checkpoint(path, params, step=1)
    out, _ = load_checkpoint(path, params)
    assert jax.tree.structure(out) == jax.tree.structure(params)


def test_synthetic_data_learnable_structure():
    rng = np.random.default_rng(0)
    b = synthetic_lm_batch(rng, 4, 64, 512)
    assert b["tokens"].shape == (4, 64)
    assert b["tokens"].max() < 64  # latent alphabet
    # deterministic transition structure: same state pairs recur
    s = synthetic_stream(1, 2, 32, 512)
    b1, b2 = next(s), next(s)
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_schedule_shapes():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                     schedule="cosine")
    sched = make_schedule(tc)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1e-3) < 1e-9
    assert float(sched(100)) < 1e-4
    lin = make_schedule(TrainConfig(schedule="linear", warmup_steps=0,
                                    total_steps=100, learning_rate=1.0))
    assert abs(float(lin(50)) - 0.5) < 1e-6


def test_serving_engine_drains():
    from repro.gnn import DistillConfig, GNNConfig, NAIConfig, load_dataset, train_nai
    from repro.serving import NAIServingEngine
    g = load_dataset("pubmed-like", scale=0.04, seed=0)
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=2,
                    hidden=16, mlp_layers=1, dropout=0.0)
    params, _ = train_nai(cfg, g, DistillConfig(epochs_base=30,
                                                epochs_offline=10,
                                                epochs_online=10))
    eng = NAIServingEngine(cfg, NAIConfig(t_s=20.0, t_min=1, t_max=2,
                                          batch_size=64), params, g)
    eng.submit(g.test_idx[:150])
    stats = eng.run_until_drained()
    assert stats.served == 150
    assert stats.batches >= 3
    s = stats.summary()
    assert s["p95_ms"] >= s["p50_ms"] > 0
    assert 1.0 <= s["mean_exit_order"] <= 2.0


def test_hlo_analyzer_on_jitted_fn():
    from repro.launch.hlo_analysis import analyze
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    st = analyze(comp.as_text())
    assert abs(st.dot_flops - 5 * 2 * 64**3) / (5 * 2 * 64**3) < 1e-6


def test_adaptive_depth_decode():
    import dataclasses
    from repro.core.adaptive_depth import adaptive_decode_step
    base = smoke(ARCHS["granite-34b"])
    cfg = dataclasses.replace(
        base, num_layers=4,
        adaptive=AdaptiveDepthConfig(enabled=True, exit_layers=(0, 1, 2),
                                     t_s=0.9, t_min=0, t_max=2))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 3, 8)
    tok = jnp.asarray([[1], [2], [3]], jnp.int32)
    logits, cache2, info = adaptive_decode_step(cfg, params, cache, tok,
                                                jnp.int32(0))
    assert logits.shape == (3, 1, cfg.vocab_size)
    assert info["exit_block"].shape == (3,)
    assert 0.0 <= float(info["flops_saved_frac"]) <= 1.0
    # very loose threshold -> every token exits at t_min
    cfg2 = dataclasses.replace(
        cfg, adaptive=dataclasses.replace(cfg.adaptive, t_s=1e9))
    logits2, _, info2 = adaptive_decode_step(cfg2, params, cache, tok,
                                             jnp.int32(0))
    assert (np.asarray(info2["exit_block"]) == 0).all()
    assert float(info2["flops_saved_frac"]) > 0.5
    # impossible threshold -> nobody exits, trunk logits used
    cfg3 = dataclasses.replace(
        cfg, adaptive=dataclasses.replace(cfg.adaptive, t_s=0.0))
    logits3, _, info3 = adaptive_decode_step(cfg3, params, cache, tok,
                                             jnp.int32(0))
    assert (np.asarray(info3["exit_block"]) == -1).all()
    ref, _ = M.decode_step(cfg3, params, cache, tok, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(logits3), np.asarray(ref),
                               atol=1e-5)


def test_lm_serving_engine_continuous_batching():
    import dataclasses
    from repro.serving.lm_engine import LMServingEngine
    cfg = smoke(ARCHS["granite-34b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = LMServingEngine(cfg, params, slots=3, max_len=64)
    for i in range(7):                       # more requests than slots
        eng.submit([1 + i, 2, 3], max_new=5)
    stats = eng.run_until_drained()
    assert stats["completed"] == 7
    assert all(len(r.out) == 5 for r in eng.completed)
    # deterministic per-prompt outputs across engines (same params)
    eng2 = LMServingEngine(cfg, params, slots=3, max_len=64)
    eng2.submit([1, 2, 3], max_new=5)
    eng2.run_until_drained()
    first = next(r for r in eng.completed if r.prompt == [1, 2, 3])
    assert first.out == eng2.completed[0].out


def test_lm_serving_engine_adaptive():
    import dataclasses
    from repro.common import AdaptiveDepthConfig
    from repro.serving.lm_engine import LMServingEngine
    base = smoke(ARCHS["granite-34b"])
    cfg = dataclasses.replace(
        base, num_layers=4,
        adaptive=AdaptiveDepthConfig(enabled=True, exit_layers=(0, 1, 2),
                                     t_s=1e9, t_min=0, t_max=2))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = LMServingEngine(cfg, params, slots=2, max_len=32, adaptive=True)
    eng.submit([5], max_new=4)
    stats = eng.run_until_drained()
    assert stats["completed"] == 1
    # loose threshold -> everything exits at block 0 -> big saving
    assert stats["mean_depth_flops_saved"] > 0.5
