"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward + one train step + one decode step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import TrainConfig
from repro.configs import ARCHS, smoke
from repro.models import decoder_lm as M
from repro.optim import adamw_init, adamw_update

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.is_encdec:
        b["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    elif cfg.num_image_tokens:
        b["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = smoke(ARCHS[arch])
    assert cfg.d_model <= 512 and cfg.num_layers <= 8
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    logits, aux, _ = M.forward(cfg, params, b["tokens"],
                               frontend=b.get("frontend"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = smoke(ARCHS[arch])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=0)
    opt = adamw_init(params, tc)
    b = _batch(cfg)

    (loss, metrics), grads = jax.value_and_grad(
        M.loss_fn, argnums=1, has_aux=True)(cfg, params, b)
    assert bool(jnp.isfinite(loss))
    new_params, opt, om = adamw_update(grads, opt, params, tc, 1e-3)
    assert bool(jnp.isfinite(om["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), jax.tree.map(
            lambda a, b_: a - b_, new_params, params), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = smoke(ARCHS[arch])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    cache = M.init_cache(cfg, 2, 16)
    logits, cache2 = M.decode_step(cfg, params, cache, b["tokens"][:, :1],
                                   jnp.int32(0), frontend=b.get("frontend"))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-34b", "recurrentgemma-9b",
                                  "rwkv6-3b", "whisper-small",
                                  "llama-3.2-vision-11b", "dbrx-132b"])
def test_decode_matches_full(arch):
    """Token-by-token decode reproduces the full-sequence forward."""
    import dataclasses
    cfg = smoke(ARCHS[arch])
    if cfg.num_experts:   # avoid routing capacity-drop mismatch (tested above)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b = _batch(cfg, B=2, S=12, seed=3)
    fe = b.get("frontend")
    full, _, _ = M.forward(cfg, params, b["tokens"], frontend=fe)
    c = M.init_cache(cfg, 2, 12)
    if cfg.is_encdec or cfg.num_image_tokens:
        c = M.seed_frontend_cache(cfg, params, c, fe)
    for t in range(12):
        logits, c = M.decode_step(cfg, params, c, b["tokens"][:, t:t + 1],
                                  jnp.int32(t), frontend=fe)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-4)


def test_prefill_matches_forward_last_logits():
    cfg = smoke(ARCHS["granite-34b"])
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b = _batch(cfg, B=2, S=12)
    full, _, _ = M.forward(cfg, params, b["tokens"])
    last, cache = M.prefill_step(cfg, params, b["tokens"])
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=1e-5)
    # prefill cache (padded by 4 slots) continues decode correctly
    def pad_seq(a):
        if a.ndim >= 3 and a.shape[-3] == 12:
            widths = [(0, 0)] * a.ndim
            widths[-3] = (0, 4)
            return jnp.pad(a, widths)
        return a
    ext = jax.tree.map(pad_seq, cache)
    nxt = jnp.zeros((2, 1), jnp.int32)
    logits, _ = M.decode_step(cfg, params, ext, nxt, jnp.int32(12))
    full2, _, _ = M.forward(cfg, params,
                            jnp.concatenate([b["tokens"], nxt], 1))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full2[:, -1]), atol=2e-4)
