"""Logical axis system.

Every parameter / activation dimension gets a *logical* name; a rules table
maps logical names onto mesh axes. Meshes with or without a 'pod' axis reuse
the same rules — missing mesh axes are silently dropped, so a config lowers
unchanged on (16,16) and (2,16,16).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> preferred mesh axes (in order). A logical name mapping to a
# multi-axis tuple shards that dim over the product of those axes.
DEFAULT_RULES: dict[str, Tuple[str, ...]] = {
    "batch":    ("pod", "data"),
    "seq":      (),               # no sequence parallelism in v1 (see §Perf)
    "embed":    (),
    "vocab":    ("model",),
    "mlp":      ("model",),
    "heads":    ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "fsdp":     ("data",),        # parameter sharding over the data axis
    "expert":   (),               # experts replicated (E over 'data' was
                                  # tried and REFUTED: GSPMD lowers the
                                  # dispatch reshard as gather chains, not
                                  # all-to-all — §Perf-2 iteration 5)
    # stacked-scan leading dim: __frozen__ is a sentinel consumed by
    # fit_spec — the dim must NEVER be sharded (nor host fallback axes):
    # scan slices it with the loop index, and a sharded dynamic-slice
    # triggers SPMD "involuntary full rematerialization" (= gathering the
    # whole stacked buffer; measured 5.4 GB/step on rwkv6 decode).
    "layers":   ("__frozen__",),
    "rnn":      ("model",),
    "cache_seq": (),
    "qseq":     ("model",),   # context-parallel attention (§Perf-1)
    "rep":      (),           # EXPLICIT replication in constrain() (§Perf-2)
    "embed_tp": ("model",),   # d_model sharded over TP post-downproj (§Perf-2)
    "cache_hd": ("model",),   # KV-cache head_dim TP when kv_heads don't divide (§Perf-3)
    "exit":     (),
    # GNN side
    "nodes":    ("pod", "data"),
    "feature":  ("model",),
    "classes":  (),
    # packed serving operands: support/batch rows partitioned by CB
    # superblock over the data axis (repro.gnn.backends / repro.gnn.packing)
    "row_shard": ("data",),
    # halo-exchange metadata (pack_support(halo=True)): leading axis is
    # the OWNING shard — same data-axis slice as row_shard, named apart
    # because the payload is per-shard frame/send plans, not rows
    "halo_shard": ("data",),
}


def spec(*logical: Optional[str], mesh: Optional[Mesh] = None,
         rules: Optional[dict] = None) -> P:
    """Build a PartitionSpec from logical dim names. `None` -> replicated."""
    rules = rules or DEFAULT_RULES
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    out = []
    used: set[str] = set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ())
                     if (mesh_axes is None or a in mesh_axes) and a not in used)
        used.update(axes)
        if len(axes) == 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def resolve(spec_: P, mesh: Mesh) -> P:
    """Drop mesh axes a spec references that `mesh` does not have
    (including the __frozen__ sentinel)."""
    names = set(mesh.axis_names)
    out = []
    for entry in spec_:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def _axis_size(mesh: Mesh, axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= sizes[a]
        return out
    return sizes[axis]


def fit_spec(spec_: P, shape: Sequence[int], mesh: Mesh,
             fallback: bool = True) -> P:
    """Make a spec legal for `shape` on `mesh`: axes whose size does not
    divide their dim are dropped, then re-placed (rightmost-first) on any
    unsharded dim they do divide — e.g. whisper's 12 heads can't take the
    16-way model axis, so it moves to head_dim/embed. Keeps every mesh axis
    in use whenever some dim can host it."""
    frozen_dims = {i for i, e in enumerate(spec_)
                   if e == "__frozen__" or (isinstance(e, tuple)
                                            and "__frozen__" in e)}
    spec_ = P(*[None if i in frozen_dims else e
                for i, e in enumerate(spec_)])
    spec_ = resolve(spec_, mesh)
    entries = list(spec_) + [None] * (len(shape) - len(spec_))
    dropped = []
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None:
            continue
        axes = e if isinstance(e, tuple) else (e,)
        kept = []
        for a in axes:
            cur = 1
            for k in kept:
                cur *= _axis_size(mesh, k)
            if d % (cur * _axis_size(mesh, a)) == 0:
                kept.append(a)
            else:
                dropped.append(a)
        entries[i] = tuple(kept) if len(kept) > 1 else (kept[0] if kept else None)
    for a in (dropped if fallback else []):
        # leftmost-first: for weight matrices this prefers the contracting
        # (input) dim -> Megatron-style partial-sum + small all-reduce,
        # instead of sharding head_dim, which would force an all-reduce of
        # the attention-logits tensor (measured 30 TB/chip on deepseek
        # prefill_32k — see EXPERIMENTS.md §Perf-1).
        for i in range(len(shape)):
            if i in frozen_dims:
                continue
            if entries[i] is None and shape[i] % _axis_size(mesh, a) == 0 \
                    and shape[i] > 1:
                entries[i] = a
                break
    return P(*entries)


def named(mesh: Mesh, spec_: P, shape: Optional[Sequence[int]] = None
          ) -> NamedSharding:
    if shape is not None:
        return NamedSharding(mesh, fit_spec(spec_, shape, mesh))
    return NamedSharding(mesh, resolve(spec_, mesh))


def _ambient_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint via logical names, resolved & fitted against
    the ambient `with mesh:` context; no-op outside a mesh.

    Dims that end up unsharded (logical None, or axis dropped by the
    divisibility fit) are left P.UNCONSTRAINED — the constraint pins only
    the dims we actively shard and GSPMD chooses the rest. Forcing
    replication instead measured 13x worse on deepseek prefill
    (EXPERIMENTS.md §Perf-1 iteration 3)."""
    m = _ambient_mesh()
    if m is None or m.size == 1:
        return x
    s = fit_spec(spec(*logical), x.shape, m, fallback=False)
    entries = []
    for name, e in zip(list(logical) + [None] * (x.ndim - len(logical)),
                       list(s) + [None] * (x.ndim - len(s))):
        if e is None:
            entries.append(None if name == "rep" else P.UNCONSTRAINED)
        else:
            entries.append(e)
    return jax.lax.with_sharding_constraint(x, P(*entries))
