"""AdamW with global-norm clipping — pure JAX (optax is not available in the
offline container; this is the framework's own optimizer substrate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import TrainConfig


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params, tc: TrainConfig):
    mdt = jnp.dtype(tc.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, tc: TrainConfig, lr):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-9)) if tc.grad_clip else 1.0

    c = count.astype(jnp.float32)
    bc1 = 1.0 - tc.beta1 ** c
    bc2 = 1.0 - tc.beta2 ** c
    mdt = jnp.dtype(tc.moment_dtype)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32) * scale
        mu2 = tc.beta1 * mu.astype(jnp.float32) + (1 - tc.beta1) * gf
        nu2 = tc.beta2 * nu.astype(jnp.float32) + (1 - tc.beta2) * jnp.square(gf)
        step = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + tc.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + tc.weight_decay * pf)
        return pf.astype(p.dtype), mu2.astype(mdt), nu2.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, metrics
