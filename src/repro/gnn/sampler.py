"""Supporting-node sampling for inductive batches (Algorithm 1 line 3).

BFS from the batch nodes over the in-neighbor CSR up to `hops`, returning
the supporting set partitioned into hop layers plus the induced subgraph
(local ids, per-edge coefficients using GLOBAL degrees, per the paper).

Two implementations with identical output (node order, hop layers, induced
edge order, coefficients):

* `sample_support` — vectorized CSR frontier expansion: one
  `repeat`/`unique` pass per hop, no Python dicts or per-node loops. This
  is the serving-path sampler; on CPU it is the difference between the
  sampler dominating batch latency and it being noise.
* `sample_support_legacy` — the original per-node dict BFS, kept as the
  readable reference for parity testing.

Batch ids must be duplicate-free (the serving engine dedupes per batch);
duplicates make the local-id map ambiguous in both implementations.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.gnn.graph import Graph


@dataclasses.dataclass
class Support:
    nodes: np.ndarray          # (S,) global ids; nodes[:n_batch] == batch
    hop: np.ndarray            # (S,) BFS layer of each supporting node
    n_batch: int
    src: np.ndarray            # (Es,) LOCAL ids
    dst: np.ndarray            # (Es,) LOCAL ids
    coef: np.ndarray           # (Es,) propagation coefficients
    sub_edges: int             # undirected edge count of the subgraph
    def __len__(self):
        return len(self.nodes)


def _flat_neighbors(indptr: np.ndarray, nbr: np.ndarray, nodes: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenated CSR neighbor lists of `nodes`, in `nodes` order.
    Returns (neighbors, counts)."""
    starts = indptr[nodes]
    counts = indptr[nodes + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, nbr.dtype), counts
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    idx = np.arange(total, dtype=np.int64) + np.repeat(starts - offsets,
                                                       counts)
    return nbr[idx], counts


def _first_occurrence(a: np.ndarray) -> np.ndarray:
    """Unique values of `a` ordered by first occurrence (stable dedupe)."""
    _, first = np.unique(a, return_index=True)
    return a[np.sort(first)]


def sample_support(g: Graph, batch: np.ndarray, hops: int, r: float
                   ) -> Support:
    """Vectorized frontier expansion (numpy repeat/unique, no dicts)."""
    indptr, nbr = g.csr()
    batch = np.asarray(batch, np.int64)
    seen = np.zeros(g.n, bool)
    seen[batch] = True
    node_parts: List[np.ndarray] = [batch]
    hop_parts: List[np.ndarray] = [np.zeros(len(batch), np.int32)]
    frontier = batch
    for h in range(1, hops + 1):
        if len(frontier) == 0:
            break
        neigh, _ = _flat_neighbors(indptr, nbr, frontier)
        cand = neigh[~seen[neigh]].astype(np.int64)
        new = _first_occurrence(cand)
        seen[new] = True
        node_parts.append(new)
        hop_parts.append(np.full(len(new), h, np.int32))
        frontier = new
    nodes = np.concatenate(node_parts)
    hop = np.concatenate(hop_parts)

    # induced edges (j -> i), ordered by destination's local id then CSR
    local = np.full(g.n, -1, np.int64)
    local[nodes] = np.arange(len(nodes))
    neigh, counts = _flat_neighbors(indptr, nbr, nodes)
    dst_all = np.repeat(np.arange(len(nodes), dtype=np.int64), counts)
    src_all = local[neigh]
    keep = src_all >= 0
    src = src_all[keep].astype(np.int32)
    dst = dst_all[keep].astype(np.int32)

    coef = _edge_coefs(g, nodes, src, dst, r)
    # count actual self loops (not one-per-node: graphs whose loops were
    # dropped, e.g. a train subgraph, would undercount otherwise)
    sub_edges = (len(src) - int((src == dst).sum())) // 2
    return Support(nodes=nodes, hop=hop, n_batch=len(batch), src=src,
                   dst=dst, coef=coef, sub_edges=max(sub_edges, 0))


def _edge_coefs(g: Graph, nodes: np.ndarray, src: np.ndarray,
                dst: np.ndarray, r: float) -> np.ndarray:
    dt = (g.degrees + 1).astype(np.float64)    # GLOBAL degrees (known)
    gsrc = nodes[src]
    gdst = nodes[dst]
    return (dt[gdst] ** (r - 1.0) * dt[gsrc] ** (-r)).astype(np.float32)


def sample_support_legacy(g: Graph, batch: np.ndarray, hops: int, r: float
                          ) -> Support:
    """Reference per-node dict BFS (original implementation)."""
    indptr, nbr = g.csr()
    seen = {}
    order: List[int] = []
    hop_of: List[int] = []
    for b in batch:
        seen[int(b)] = 0
        order.append(int(b))
        hop_of.append(0)
    frontier = list(batch)
    for h in range(1, hops + 1):
        nxt = []
        for u in frontier:
            for v in nbr[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if v not in seen:
                    seen[v] = h
                    order.append(v)
                    hop_of.append(h)
                    nxt.append(v)
        frontier = nxt
    nodes = np.asarray(order, np.int64)
    local = {u: i for i, u in enumerate(order)}

    # induced edges (j -> i) for i in support whose source j is in support
    srcs, dsts = [], []
    for u in order:
        for v in nbr[indptr[u]:indptr[u + 1]]:
            v = int(v)
            if v in local:
                dsts.append(local[u])
                srcs.append(local[v])
    src = np.asarray(srcs, np.int32)
    dst = np.asarray(dsts, np.int32)

    coef = _edge_coefs(g, nodes, src, dst, r)
    sub_edges = (len(src) - int((src == dst).sum())) // 2
    return Support(nodes=nodes, hop=np.asarray(hop_of, np.int32),
                   n_batch=len(batch), src=src, dst=dst, coef=coef,
                   sub_edges=max(sub_edges, 0))
