"""Tests for the paper's GNN substrate: propagation, stationary state,
NAP (Algorithm 1), distillation plumbing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import (GNNConfig, NAIConfig, infer_all, load_dataset,
                       order_distribution, propagated_series,
                       stationary_weights)
from repro.gnn.graph import Graph, add_self_loops, edge_coefficients, spmm
from repro.gnn.sampler import sample_support
from repro.gnn.store import as_store


def tiny_graph(n=60, seed=0, f=16, c=3):
    rng = np.random.default_rng(seed)
    m = n * 3
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    u, v = u[keep], v[keep]
    eid = np.unique(np.minimum(u, v) * n + np.maximum(u, v))
    u, v = (eid // n).astype(np.int32), (eid % n).astype(np.int32)
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    src, dst = add_self_loops(src, dst, n)
    feats = rng.standard_normal((n, f)).astype(np.float32)
    labels = rng.integers(0, c, n).astype(np.int32)
    perm = rng.permutation(n)
    return Graph(n=n, src=src, dst=dst, features=feats, labels=labels,
                 num_classes=c, train_idx=perm[:20].astype(np.int32),
                 unlabeled_idx=perm[20:40].astype(np.int32),
                 test_idx=perm[40:].astype(np.int32))


def dense_adj(g, r=0.5):
    A = np.zeros((g.n, g.n), np.float64)
    coef = edge_coefficients(g, r)
    np.add.at(A, (g.dst, g.src), coef)
    return A


@pytest.mark.parametrize("r", [0.0, 0.5, 1.0])
def test_spmm_matches_dense(r):
    g = tiny_graph()
    A = dense_adj(g, r)
    coef = edge_coefficients(g, r)
    x = g.features
    np.testing.assert_allclose(spmm(g, coef, x), A @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("r", [0.0, 0.5, 1.0])
def test_stationary_state_is_fixed_point(r):
    """Eq. 7: the rank-1 X∞ must be (numerically) invariant under Â for a
    connected graph — verify via the dense eigen-structure instead: Â^k X
    converges to X∞."""
    g = tiny_graph(n=40, seed=1)
    A = dense_adj(g, r)
    a, b = stationary_weights(g, r)
    x_inf = np.outer(a, b @ g.features)
    # propagate many times from raw features
    x = g.features.astype(np.float64)
    for _ in range(400):
        x = A @ x
    # compare directions on nodes (connected component dominates)
    denom = np.linalg.norm(x) * np.linalg.norm(x_inf)
    cos = float((x * x_inf).sum() / denom)
    assert cos > 0.99, cos


def test_stationary_rank1_equals_dense_formula():
    g = tiny_graph(n=30, seed=2)
    r = 0.5
    dt = (g.degrees + 1).astype(np.float64)
    denom = 2 * g.num_edges + g.n
    Ainf = np.outer(dt ** r, dt ** (1 - r)) / denom
    a, b = stationary_weights(g, r)
    np.testing.assert_allclose(np.outer(a, b), Ainf, rtol=1e-5)


def test_propagation_smooths_distance_monotone():
    """The mean distance to the stationary state shrinks with order."""
    g = load_dataset("pubmed-like", scale=0.05, seed=0)
    series = propagated_series(g, g.features, 6)
    a, b = stationary_weights(g, 0.5)
    x_inf = np.outer(a, b @ g.features)
    dists = [np.linalg.norm(s - x_inf, axis=1).mean() for s in series]
    assert all(d2 < d1 * 1.02 for d1, d2 in zip(dists[1:], dists[2:])), dists


def test_support_sampling_exactness():
    """X^(l) computed on the T_max-hop support equals the full-graph value
    for batch nodes, l <= T_max (DESIGN.md: corruption can't reach V_b)."""
    g = tiny_graph(n=80, seed=3)
    batch = g.test_idx[:10]
    tmax = 3
    sup = sample_support(as_store(g), batch, tmax, 0.5)
    assert np.array_equal(sup.nodes[:10], batch)
    series_full = propagated_series(g, g.features, tmax)
    x = g.features[sup.nodes].astype(np.float32)
    from repro.gnn.nai import _subgraph_spmm
    needed = np.ones(len(sup), bool)
    for l in range(1, tmax + 1):
        x, _ = _subgraph_spmm(sup, x, needed)
        np.testing.assert_allclose(x[:10], series_full[l][batch],
                                   rtol=1e-4, atol=1e-4)


class _StubParams(dict):
    pass


def _trained(g, k=3):
    from repro.gnn import DistillConfig, train_nai
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=k,
                    hidden=32, mlp_layers=2, dropout=0.0)
    dc = DistillConfig(epochs_base=60, epochs_offline=30, epochs_online=30)
    params, _ = train_nai(cfg, g, dc)
    return cfg, params


def test_nai_tmax_respected_and_orders_cover():
    g = load_dataset("pubmed-like", scale=0.05, seed=1)
    cfg, params = _trained(g, k=3)
    nai = NAIConfig(t_s=18.0, t_min=1, t_max=3, batch_size=200)
    res = infer_all(cfg, nai, params, g)
    assert res.orders.min() >= 1 and res.orders.max() <= 3
    assert (res.predictions >= 0).all()
    dist = order_distribution(res, 3)
    assert dist.sum() == len(g.test_idx)


def test_nai_threshold_extremes():
    g = load_dataset("pubmed-like", scale=0.05, seed=1)
    cfg, params = _trained(g, k=3)
    res_hi = infer_all(cfg, NAIConfig(t_s=1e9, t_min=1, t_max=3,
                                      batch_size=200), params, g)
    assert (res_hi.orders == 1).all()          # everyone exits immediately
    res_lo = infer_all(cfg, NAIConfig(t_s=0.0, t_min=1, t_max=3,
                                      batch_size=200), params, g)
    assert (res_lo.orders == 3).all()          # nobody exits early


def test_nai_ts0_matches_vanilla_predictions():
    """With T_s=0 NAP degenerates to fixed k-order propagation — predictions
    must equal the vanilla classifier on full propagated features."""
    from repro.gnn import apply_classifier
    g = load_dataset("pubmed-like", scale=0.05, seed=2)
    cfg, params = _trained(g, k=3)
    nai = NAIConfig(t_s=0.0, t_min=1, t_max=3, batch_size=97)
    res = infer_all(cfg, nai, params, g)
    series = np.stack(propagated_series(g, g.features, cfg.k))
    z = apply_classifier(cfg, params["cls"][3], jnp.asarray(series[:, g.test_idx]), 3)
    vanilla = np.asarray(jnp.argmax(z, -1))
    assert (res.predictions == vanilla).mean() > 0.999


def test_nai_macs_decrease_with_larger_ts():
    g = load_dataset("pubmed-like", scale=0.05, seed=3)
    cfg, params = _trained(g, k=3)
    lo = infer_all(cfg, NAIConfig(t_s=0.0, t_min=1, t_max=3, batch_size=200),
                   params, g)
    hi = infer_all(cfg, NAIConfig(t_s=1e9, t_min=1, t_max=3, batch_size=200),
                   params, g)
    assert hi.fp_macs < lo.fp_macs
    assert hi.total_macs < lo.total_macs


def test_subgraph_edge_count_and_degrees_hand_oracle():
    """PR 6 satellite: num_edges/degrees count ACTUAL self loops. On the
    path 0-1-2-3 (plus one loop per node), inducing on {0, 1} keeps only
    those two loops; the old one-loop-per-node assumption reported
    m = (4 - 4) / 2 = 0 undirected edges and degree -1 for the dropped
    nodes, poisoning the stationary denominator 2m + n."""
    u = np.array([0, 1, 2], np.int32)
    v = np.array([1, 2, 3], np.int32)
    src, dst = add_self_loops(np.concatenate([u, v]),
                              np.concatenate([v, u]), 4)
    g = Graph(n=4, src=src, dst=dst,
              features=np.eye(4, 4, dtype=np.float32),
              labels=np.zeros(4, np.int32), num_classes=2,
              train_idx=np.array([0], np.int32),
              unlabeled_idx=np.array([1], np.int32),
              test_idx=np.array([2, 3], np.int32))
    assert g.num_self_loops == 4
    assert g.num_edges == 3
    np.testing.assert_array_equal(g.degrees, [1, 2, 2, 1])

    sub = g.train_subgraph()               # induced on {0, 1}
    assert sub.n == 4                      # ids are NOT remapped
    assert sub.num_self_loops == 2         # only kept nodes keep theirs
    assert sub.num_edges == 1              # the 0-1 edge
    np.testing.assert_array_equal(sub.degrees, [1, 1, 0, 0])

    a, b = stationary_weights(sub, r=0.5)  # denominator 2m + n = 6
    dt = np.array([2.0, 2.0, 1.0, 1.0])
    np.testing.assert_allclose(a, np.sqrt(dt) / 6.0, rtol=1e-6)
    np.testing.assert_allclose(b, np.sqrt(dt), rtol=1e-6)


def test_sampler_sub_edges_counts_actual_self_loops():
    """Support sampling on a graph whose loops were partially dropped
    must count the subgraph's real undirected edges, not assume one loop
    per supporting node. Path 0-1-2-3 with loops ONLY on {0, 1}: the
    2-hop support of batch [2] is all four nodes, whose induced subgraph
    has 8 directed entries (3 undirected edges twice + 2 loops) — the
    old one-loop-per-node formula reported (8 - 4) / 2 = 2."""
    u = np.array([0, 1, 2, 0, 1], np.int32)    # last two: loops on 0, 1
    v = np.array([1, 2, 3, 0, 1], np.int32)
    g = Graph(n=4, src=np.concatenate([u, v[:3]]),
              dst=np.concatenate([v, u[:3]]),
              features=np.eye(4, 4, dtype=np.float32),
              labels=np.zeros(4, np.int32), num_classes=2,
              train_idx=np.array([0], np.int32),
              unlabeled_idx=np.array([1], np.int32),
              test_idx=np.array([2, 3], np.int32))
    sup = sample_support(as_store(g), np.array([2], np.int64), hops=2, r=0.5)
    assert set(sup.nodes.tolist()) == {0, 1, 2, 3}
    loops = int((sup.src == sup.dst).sum())
    assert loops == 2                          # only 0 and 1 kept theirs
    assert len(sup.src) == 8
    assert sup.sub_edges == 3
