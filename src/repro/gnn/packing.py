"""Support -> block-ELL packing for the compiled serving path.

Converts the induced subgraph of a sampled `Support` into the static-shape
operand set consumed by the Pallas block-ELL SpMM kernel
(`repro.kernels.spmm.spmm_block_ell`) and the fused NAP step kernel
(`repro.kernels.nap_step.nap_step_fused` — same tiles plus the bucketed
`x_inf` and a prefetched squared threshold), padded to *bucket* sizes so
that repeat batches of similar size hit the jit compile cache:

* the batch region is padded from `n_batch` to `nb_bucket` rows (pad rows
  have no edges, zero features, zero stationary state — they exit at T_min
  and are dropped by slicing results to `nb_real`);
* support rows follow at `nb_bucket`, and the total row count is padded to
  an `s_bucket` multiple of CB so feature blocks index cleanly;
* the per-row-block tile budget `max_tb` is padded to `tb_bucket`.

Buckets grow geometrically ({1,2,3}·2^k), bounding padding overshoot to
~33% while keeping the number of distinct compiled shapes logarithmic in
the size range — the bucket policy recorded in ROADMAP.md.

The packer also emits `hop_rb`, the minimum BFS hop per row block, from
which the per-step NAP row-block predicate follows statically: the value
X^(l) at a node of hop h can only reach a batch output if h <= T_max - l,
so row blocks with `hop_rb > T_max - l` are skipped by the kernel at step
l (and everything is skipped once the whole batch has exited — the
dynamic part, ANDed in inside the jitted function).

Sharded packing (``n_shards=D > 1``, consumed by
`repro.gnn.backends.run_propagation` under shard_map): the padded rows
are PERMUTED into shard-major order — CB-row superblocks dealt
round-robin across shards (superblock j -> shard j % D), each shard's
blocks concatenated — so a plain contiguous `PartitionSpec("data")` slice
of every operand hands each shard exactly its round-robin blocks with
identical static shapes. The permutation granularity is deliberately CB
(the SpMM kernel's x-blocking): whole column blocks move, so every
coefficient tile keeps its single-device contents, per-row-block slot
order, and within-tile layout — sharded SpMM is bit-identical to
single-device, not just close. Alignment prices of sharding: the batch
region pads to a multiple of CB*D (each shard must own the same number of
leading batch superblocks) and the total rows to a multiple of CB*D; the
batch therefore has to amortize CB*D rows (the paper's batch 500 on 4
shards pads to 512 — 2% — but tiny batches on many shards pay real
padding). Batch rows land permuted too: `shard_batch_perm` maps original
batch position -> packed position, and results gather back through it.

Halo packing (``halo=True`` with ``n_shards > 1``): each shard's tiles
only ever read the CB column blocks named in its `tile_col`, so the
dense per-step frontier all_gather moves mostly rows nobody reads. The
packer therefore also computes, per shard, the sorted union of global
CB blocks its rows reference — own blocks plus the remote *halo* — and
rewrites `tile_col` (and the segment path's `src`) into indices of that
local **halo frame** instead of global packed coordinates. The frame
layout is the sorted global block order, which groups entries by source
shard; the emitted metadata drives both compiled exchange strategies in
`repro.gnn.backends.run_propagation`:

* ``halo_src_shard`` / ``halo_src_block`` (D, H_pad) — where each frame
  block lives (owner shard, owner-local block); a static gather out of
  the all-gathered frontier (``gather_mode="halo"``).
* ``halo_send_block`` (D, D, B_pad) / ``halo_frame_src`` (D, H_pad) —
  the per-pair send lists and the frame positions of the received
  blocks for the `jax.lax.all_to_all` ragged exchange
  (``gather_mode="alltoall"``), which moves only halo bytes on a real
  interconnect.
* ``halo_count`` (D,) — real frame entries per shard (the rest is
  padding; padded entries point at block 0, which no valid tile or
  real edge ever references).

H_pad and B_pad are bucket-padded to the same {1,2,3}·2^k series as
every other operand (capped at the block counts they index), carried in
`shape_key`, and pooled by the buffer-reuse path, so steady-state
serving stays zero-compile and zero-alloc with halo on. Frame contents
are bit-identical to the corresponding rows of the dense frontier and
tile slot order never moves, so halo-gather propagation preserves the
sharded == single-device bit-identity guarantee.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.gnn.sampler import Support
from repro.kernels.spmm.kernel import CB, FB, RB

_INF_HOP = np.int32(2 ** 30)   # hop assigned to padding rows


def next_bucket(x: int, minimum: int = 1) -> int:
    """Smallest value >= max(x, minimum) in the geometric series
    {1, 2, 3} * 2^k * minimum (ratio <= 1.5)."""
    x = max(int(x), minimum)
    b = minimum
    while True:
        for mult in (1, 2, 3):
            if b * mult >= x:
                return b * mult
        b *= 2


def batch_bucket(n_batch: int, n_shards: int = 1) -> int:
    """Bucketed batch-region size: RB-aligned single-device, CB*D-aligned
    sharded (every shard must own the same number of leading batch
    superblocks)."""
    return next_bucket(n_batch, RB if n_shards == 1 else CB * n_shards)


def shard_block_perm(n_blocks: int, n_shards: int) -> np.ndarray:
    """Shard-major round-robin permutation of CB superblocks: block j
    goes to shard j % D at local slot j // D, i.e. packed position
    (j % D) * (n_blocks // D) + j // D. Requires n_blocks % D == 0."""
    if n_blocks % n_shards:
        raise ValueError(f"{n_blocks} superblocks not divisible by "
                         f"{n_shards} shards")
    j = np.arange(n_blocks, dtype=np.int64)
    return (j % n_shards) * (n_blocks // n_shards) + j // n_shards


def shard_row_perm(n_rows: int, n_shards: int) -> np.ndarray:
    """Per-row packed position under the superblock round-robin (rows
    move with their CB superblock; within-block offsets are preserved,
    which is what keeps tile contents bit-identical)."""
    if n_rows % (CB * n_shards):
        raise ValueError(f"{n_rows} rows not a multiple of CB*D = "
                         f"{CB * n_shards}")
    r = np.arange(n_rows, dtype=np.int64)
    return shard_block_perm(n_rows // CB, n_shards)[r // CB] * CB + r % CB


def shard_batch_perm(n_batch: int, n_shards: int) -> np.ndarray:
    """Packed position of each original batch row inside the (n_batch,)
    batch-region arrays (x_inf, c_inf, exit orders, series rows). Same
    round-robin formula restricted to the batch region: batch superblocks
    are globally first and round-robin preserves relative order, so they
    are the FIRST nb/(CB*D) superblocks of every shard in both the full
    row space and the batch-only space."""
    return shard_row_perm(n_batch, n_shards)


@dataclasses.dataclass
class PackedSupport:
    # block-ELL operands (see repro.kernels.spmm.kernel.spmm_block_ell)
    tiles: np.ndarray        # (n_rb, tb, RB, CB) f32 coefficient tiles
    tile_col: np.ndarray     # (n_rb, tb) int32 column-block per tile
    valid: np.ndarray        # (n_rb, tb) int32 1 = real tile
    hop_rb: np.ndarray       # (n_rb,) int32 min BFS hop per row block
    # padded batch layout
    n_batch: int             # bucket-padded batch region (rows [0, n_batch))
    nb_real: int             # true batch size (rows [0, nb_real) are real)
    n_pad: int               # total padded rows (multiple of CB)
    s_real: int              # true support size
    # padded dense operands
    x0: np.ndarray           # (n_pad, f_pad) f32 features at support rows
    x_inf: np.ndarray        # (n_batch, f_pad) f32 stationary state
    # bucket-padded edge list in padded row ids (for the segment-sum
    # compiled path; pad edges have coef 0 so they contribute nothing).
    # Sharded (n_shards > 1) the arrays carry a leading shard axis
    # (D, e_pad): src holds PACKED global row ids (indexes the gathered
    # frontier), dst holds shard-LOCAL row ids.
    src: np.ndarray          # (e_pad,) int32
    dst: np.ndarray          # (e_pad,) int32
    coef: np.ndarray         # (e_pad,) f32
    # rank-1 stationary-state factors (x_inf = c_inf ⊗ s_inf), padded to
    # the same buckets — the fused step kernel streams these instead of
    # the dense x_inf; None unless pack_support got x_inf_factors
    c_inf: Optional[np.ndarray] = None    # (n_batch,) f32
    s_inf: Optional[np.ndarray] = None    # (f_pad,) f32
    # True when pack_support refilled a caller-provided buffer set in
    # place instead of allocating (the steady-state serving path)
    reused: bool = False
    # row partition over the serving mesh's data axis (1 = unsharded);
    # sharded operands are in shard-major superblock-permuted row order
    n_shards: int = 1
    # halo-frame metadata (halo=True packs only; see module docstring):
    # per shard, the sorted union of global CB blocks its rows reference,
    # bucket-padded to H_pad entries, plus the all_to_all exchange plan
    halo_src_shard: Optional[np.ndarray] = None   # (D, H_pad) int32
    halo_src_block: Optional[np.ndarray] = None   # (D, H_pad) int32
    halo_count: Optional[np.ndarray] = None       # (D,) int32 real entries
    halo_send_block: Optional[np.ndarray] = None  # (D, D, B_pad) int32
    halo_frame_src: Optional[np.ndarray] = None   # (D, H_pad) int32
    # propagated-feature-cache seed operands (seeds= packs only): padded
    # row ids of cache-hit rows (pad entries point one past the local row
    # range — dropped by the `mode="drop"` scatter in the NAP loop) and
    # their per-step series values. Sharded they carry a leading shard
    # axis and shard-LOCAL row ids, like the edge arrays.
    seed_rows: Optional[np.ndarray] = None   # (k_pad,) / (D, k_pad) int32
    seed_vals: Optional[np.ndarray] = None   # (L, k_pad, f_pad) /
    #                                          (D, L, k_pad, f_pad) f32

    @property
    def n_rb(self) -> int:
        return self.tiles.shape[0]

    @property
    def density(self) -> float:
        return float(self.valid.mean()) if self.valid.size else 0.0

    @property
    def n_halo_pad(self) -> int:
        """Bucket-padded halo-frame blocks per shard (0 = dense pack)."""
        return (0 if self.halo_src_shard is None
                else self.halo_src_shard.shape[1])

    @property
    def halo_send_pad(self) -> int:
        """Bucket-padded all_to_all send-list blocks per shard pair."""
        return (0 if self.halo_send_block is None
                else self.halo_send_block.shape[2])

    @property
    def halo_rows(self) -> int:
        """True halo-frame rows of the widest shard (the boundary the
        exchange actually has to move; n_halo_pad * CB is what the
        padded gather materializes)."""
        return (0 if self.halo_count is None
                else int(self.halo_count.max()) * CB)

    @property
    def seed_pad(self) -> int:
        """Bucket-padded cache-seed rows per shard (0 = no-cache pack)."""
        return 0 if self.seed_rows is None else self.seed_rows.shape[-1]

    @property
    def halo_frac(self) -> float:
        """halo_rows / n_pad — 1.0 means the halo set degenerated to the
        full frontier (no communication saving over the dense gather)."""
        return self.halo_rows / self.n_pad if self.halo_count is not None \
            else 1.0

    def shape_key(self, spmm_impl: str = "block_ell") -> tuple:
        """The jit-cache key: exactly the static shapes the compiled
        function specializes on for the given SpMM implementation (the
        other path's operand shapes must not perturb compile counting).
        ``block_ell`` and ``fused`` consume the same operand set — the
        fused kernel additionally prefetches `x_inf` (already bucketed to
        (n_batch, f_pad) here) and the squared threshold (a scalar, no
        shape) — but they compile different programs, so the impl name
        stays in the key. `n_shards` is in the key because the sharded
        runner compiles a different (shard_map) program even at equal
        operand shapes; halo packs append their frame/send pads, which
        size the per-step gather (and distinguish halo from dense)."""
        if spmm_impl in ("block_ell", "fused"):
            key = (spmm_impl, self.n_shards, self.n_batch, self.n_pad,
                   self.tiles.shape[1], self.x0.shape[1])
        else:
            key = ("segment", self.n_shards, self.n_batch, self.n_pad,
                   self.x0.shape[1], self.src.shape[-1])
        if self.halo_src_shard is not None:
            key += ("halo", self.n_halo_pad, self.halo_send_pad)
        if self.seed_rows is not None:
            key += ("seed", self.seed_vals.shape[-3],
                    self.seed_vals.shape[-2])
        return key


def _remap_rows(sup: Support, nb_bucket: int) -> np.ndarray:
    """Local support id -> padded row id (batch region padded to
    nb_bucket)."""
    shift = nb_bucket - sup.n_batch
    ids = np.arange(len(sup), dtype=np.int64)
    return np.where(ids < sup.n_batch, ids, ids + shift)


def pack_support(sup: Support, x0: np.ndarray, x_inf: np.ndarray, *,
                 nb_bucket: Optional[int] = None,
                 s_bucket: Optional[int] = None,
                 tb_bucket: Optional[int] = None,
                 e_bucket: Optional[int] = None,
                 build_tiles: bool = True,
                 build_edges: bool = True,
                 x_inf_factors=None,
                 out: Optional[PackedSupport] = None,
                 n_shards: int = 1,
                 halo: bool = False,
                 h_bucket: Optional[int] = None,
                 hb_bucket: Optional[int] = None,
                 seeds=None,
                 k_bucket: Optional[int] = None) -> PackedSupport:
    """Pack a sampled `Support` (+ its features and per-batch-node
    stationary state) into bucket-padded block-ELL operands.

    x0 (S, f) support-row features; x_inf (n_batch, f) stationary state.
    Explicit buckets are FLOORS (must be legal sizes: s_bucket a CB
    multiple); the packer grows past them when the support needs more.
    The serving engine passes its per-shape high-water marks here so that
    a smaller follow-up batch reuses the previous compiled shape.

    `build_tiles=False` skips tile construction entirely (tiles/tile_col/
    valid come back with a zero tile budget) — the segment-sum path only
    consumes the edge list, and a dense hub row block can push the tile
    tensor to GBs on large supports. Symmetrically `build_edges=False`
    skips the bucket-padded edge list the block-ELL path never reads.

    `x_inf_factors=(c, s)` (the rank-1 stationary-state factors, see
    `repro.gnn.nai.support_stationary_factors`) additionally emits
    bucket-padded `c_inf` (n_batch,) / `s_inf` (f_pad,) — the fused step
    kernel's streamed operands. Padding rows/columns get factor zero,
    matching the zero-padded dense x_inf.

    `out` is a previously packed result whose buffers may be refilled in
    place: when every bucket-padded operand shape matches (the steady
    state, since the engine's high-water marks make bucket shapes
    sticky), the big arrays are cleared and rewritten instead of
    reallocated, and the returned PackedSupport (== `out`, with
    `reused=True`) owns the same buffers. On any shape mismatch a fresh
    set is allocated. Only the bucket-sized operand arrays are pooled;
    O(S)/O(E) scratch (row maps, the tile unique pass) still allocates.
    Callers overlapping host packing with async device compute must
    rotate >= 2 buffer sets so an in-flight batch's operands are never
    overwritten (see NAIServingEngine).

    `n_shards=D > 1` emits the same operand set in the shard-major
    superblock-permuted row order (see the module docstring): equal
    static shapes per shard, tiles bit-identical to a single-device pack
    of the same geometry, edge arrays stacked (D, e_pad) with local dst
    ids. Explicit buckets must respect the sharded alignment (batch and
    rows multiples of CB*D).

    `halo=True` (sharded packs only) additionally computes each shard's
    halo frame — the sorted union of global CB blocks its rows reference
    — emits the `halo_*` metadata, and rewrites `tile_col` / the segment
    `src` ids into FRAME-local coordinates, so the propagation loop can
    gather H_pad·CB frame rows per step instead of the full S_pad
    frontier. `h_bucket` / `hb_bucket` are hwm floors for the frame and
    send-list pads, same contract as the other buckets.

    `seeds=(hit, vals)` (the propagated-feature-cache path, see
    `repro.gnn.propcache`): `hit` is the per-support-row boolean hit
    mask from the sampler, `vals` the (k_hit, L, F) cached series in
    `nodes[hit]` order. Edges INTO hit rows are dropped before tiling —
    their values are not recomputed but scattered from `seed_vals` after
    every SpMM step — while edges FROM hit rows stay (miss rows still
    read them as sources). Hit rows get hop `_INF_HOP` so row blocks
    that are entirely cache-served are skipped by the step-active mask.
    `k_bucket` is the hwm floor for the seed-row pad, same contract as
    the other buckets. Batch rows must never be marked hit (their series
    is the output)."""
    row_align = CB * n_shards
    batch_align = RB if n_shards == 1 else CB * n_shards
    if s_bucket and s_bucket % row_align:
        raise ValueError(f"s_bucket {s_bucket} not a multiple of "
                         f"{row_align} (CB * n_shards)")
    nb, S = sup.n_batch, len(sup)
    nb_bucket = max(batch_bucket(nb, n_shards), nb_bucket or 0)
    if nb_bucket % batch_align:
        raise ValueError(f"nb_bucket {nb_bucket} not a multiple of "
                         f"{batch_align}")
    rows_needed = nb_bucket + (S - nb)
    n_pad = max(next_bucket(-(-rows_needed // row_align), 1) * row_align,
                s_bucket or 0)

    seeds_on = seeds is not None
    if seeds_on:
        hit_mask, seed_series = seeds
        if hit_mask[:nb].any():
            raise ValueError("batch rows must not be cache hits")
    if seeds_on and hit_mask.any():
        # drop edges INTO hit rows (their values are seeded, not
        # recomputed); edges FROM hit rows stay — miss rows read them
        keep_e = ~hit_mask[sup.dst]
        e_src_l, e_dst_l = sup.src[keep_e], sup.dst[keep_e]
        e_coef = sup.coef[keep_e]
        hop_eff = np.where(hit_mask, _INF_HOP, sup.hop)
    else:
        # no hits: skip the edge-mask copies (an all-True fancy index
        # still copies every edge array — measurable at 0% hit rate);
        # seed operands are still emitted below so shapes stay stable
        e_src_l, e_dst_l, e_coef = sup.src, sup.dst, sup.coef
        hop_eff = sup.hop

    row_of = _remap_rows(sup, nb_bucket)
    src = row_of[e_src_l]
    dst = row_of[e_dst_l]

    # --- tile geometry (needed up front so buffer reuse can be decided
    # before anything is written)
    n_rb, n_cb = n_pad // RB, n_pad // CB
    if n_shards > 1:
        # shard-major permutations at every granularity; tile KEYS stay in
        # original coordinates so slot order (and hence accumulation
        # order) matches the single-device pack exactly
        sb_perm = shard_block_perm(n_cb, n_shards)
        spb = CB // RB
        rb_ids = np.arange(n_rb, dtype=np.int64)
        rb_perm = sb_perm[rb_ids // spb] * spb + rb_ids % spb
        row_perm = shard_row_perm(n_pad, n_shards)
        bat_perm = shard_batch_perm(nb_bucket, n_shards)
        row_dest = row_perm[row_of]
        rows_loc = n_pad // n_shards
    else:
        row_dest = row_of
    halo_on = bool(halo) and n_shards > 1
    if n_shards > 1 and (halo_on or build_edges):
        src_p = row_perm[src]
        dst_p = row_perm[dst]
        e_shard = dst_p // rows_loc

    # --- halo frame geometry (sorted union of global CB blocks each
    # shard's rows reference, grouped by source shard because global
    # block ids are shard-major) — needed before the reuse decision
    if halo_on:
        n_cb_loc = n_cb // n_shards
        key_h = e_shard * n_cb + src_p // CB
        uniq_h = np.unique(key_h)
        h_shard = uniq_h // n_cb           # frame OWNER (destination) shard
        h_block = uniq_h % n_cb            # global packed block id
        h_counts = np.bincount(h_shard, minlength=n_shards)
        h_needed = max(int(h_counts.max()) if len(uniq_h) else 1, 1)
        h_pad = min(max(next_bucket(h_needed), h_bucket or 0), n_cb)
        # all_to_all plan: (source shard, destination shard) send lists
        skey = (h_block // n_cb_loc) * n_shards + h_shard
        s_counts = np.bincount(skey, minlength=n_shards * n_shards)
        s_needed = max(int(s_counts.max()), 1)
        hb_pad = min(max(next_bucket(s_needed), hb_bucket or 0), n_cb_loc)
    if build_tiles:
        rb = dst // RB
        cb = src // CB
        key = rb * n_cb + cb
        uniq, inverse = np.unique(key, return_inverse=True)
        tile_rb = (uniq // n_cb).astype(np.int64)
        tile_cb = (uniq % n_cb).astype(np.int32)
        counts = np.bincount(tile_rb, minlength=n_rb)
        tb_needed = max(int(counts.max()) if len(uniq) else 1, 1)
        tb = max(next_bucket(tb_needed, 1), tb_bucket or 0)
    else:
        tb = 0
    f_pad = -(-x0.shape[1] // FB) * FB
    xi_cols = f_pad if x_inf.shape[1] else 0
    if build_edges:
        if n_shards > 1:
            e_counts = np.bincount(e_shard, minlength=n_shards)
            e_pad = max(next_bucket(max(int(e_counts.max()), 1), 1),
                        e_bucket or 0)
        else:
            e_pad = max(next_bucket(len(src), 1), e_bucket or 0)
    else:
        e_pad = 0
    e_shape = ((n_shards, e_pad) if n_shards > 1 and build_edges
               else (e_pad,))

    # --- seed geometry (before the reuse decision, like everything else
    # that sizes a pooled buffer)
    if seeds_on:
        hit_idx = np.flatnonzero(hit_mask)
        seed_len = seed_series.shape[1]
        sd_dest = row_dest[hit_idx]
        if n_shards > 1:
            sd_shard = sd_dest // rows_loc
            sd_counts = np.bincount(sd_shard, minlength=n_shards)
            k_needed = max(int(sd_counts.max()) if len(hit_idx) else 1, 1)
        else:
            k_needed = max(len(hit_idx), 1)
        k_pad = max(next_bucket(k_needed, 1), k_bucket or 0)
        sr_shape = (n_shards, k_pad) if n_shards > 1 else (k_pad,)
        sv_shape = ((n_shards, seed_len, k_pad, f_pad) if n_shards > 1
                    else (seed_len, k_pad, f_pad))

    reuse = (out is not None
             and out.n_shards == n_shards
             and out.tiles.shape == (n_rb, tb, RB, CB)
             and out.x0.shape == (n_pad, f_pad)
             and out.x_inf.shape == (nb_bucket, xi_cols)
             and out.src.shape == e_shape
             and (out.c_inf is not None) == (x_inf_factors is not None)
             and (out.halo_src_shard is not None) == halo_on
             and (not halo_on
                  or (out.halo_src_shard.shape == (n_shards, h_pad)
                      and out.halo_send_block.shape
                      == (n_shards, n_shards, hb_pad)))
             and (out.seed_rows is not None) == seeds_on
             and (not seeds_on
                  or (out.seed_rows.shape == sr_shape
                      and out.seed_vals.shape == sv_shape)))
    if reuse:
        p = out
        p.tiles.fill(0.0)
        p.tile_col.fill(0)
        p.valid.fill(0)
        p.x0.fill(0.0)
        p.x_inf.fill(0.0)
    else:
        p = PackedSupport(
            tiles=np.zeros((n_rb, tb, RB, CB), np.float32),
            tile_col=np.zeros((n_rb, tb), np.int32),
            valid=np.zeros((n_rb, tb), np.int32),
            hop_rb=np.full(n_rb, _INF_HOP, np.int32),
            n_batch=nb_bucket, nb_real=nb, n_pad=n_pad, s_real=S,
            x0=np.zeros((n_pad, f_pad), np.float32),
            x_inf=np.zeros((nb_bucket, xi_cols), np.float32),
            src=np.full(e_shape, 0, np.int32),
            dst=np.full(e_shape, 0, np.int32),
            coef=np.zeros(e_shape, np.float32),
            c_inf=(np.zeros(nb_bucket, np.float32)
                   if x_inf_factors is not None else None),
            s_inf=(np.zeros(f_pad, np.float32)
                   if x_inf_factors is not None else None),
            n_shards=n_shards,
            halo_src_shard=(np.zeros((n_shards, h_pad), np.int32)
                            if halo_on else None),
            halo_src_block=(np.zeros((n_shards, h_pad), np.int32)
                            if halo_on else None),
            halo_count=(np.zeros(n_shards, np.int32) if halo_on else None),
            halo_send_block=(np.zeros((n_shards, n_shards, hb_pad),
                                      np.int32) if halo_on else None),
            halo_frame_src=(np.zeros((n_shards, h_pad), np.int32)
                            if halo_on else None),
            seed_rows=(np.zeros(sr_shape, np.int32) if seeds_on else None),
            seed_vals=(np.zeros(sv_shape, np.float32)
                       if seeds_on else None))
    p.n_batch, p.nb_real, p.n_pad, p.s_real = nb_bucket, nb, n_pad, S
    p.n_shards = n_shards
    p.reused = reuse

    # --- halo metadata + the global-block -> frame-position lookup used
    # to rewrite tile_col/src below. uniq_h is sorted by (owner shard,
    # global block), so each shard's frame entries are contiguous,
    # ascending, and grouped by source shard — the layout both exchange
    # strategies rely on.
    if halo_on:
        for arr in (p.halo_src_shard, p.halo_src_block, p.halo_count,
                    p.halo_send_block, p.halo_frame_src):
            arr.fill(0)
        first_h = np.concatenate([[0], np.cumsum(h_counts)[:-1]])
        h_slot = np.arange(len(uniq_h), dtype=np.int64) - first_h[h_shard]
        h_src = h_block // n_cb_loc        # source shard of each entry
        p.halo_src_shard[h_shard, h_slot] = h_src.astype(np.int32)
        p.halo_src_block[h_shard, h_slot] = \
            (h_block % n_cb_loc).astype(np.int32)
        p.halo_count[:] = h_counts.astype(np.int32)
        # receive slot: rank within the (owner, source) group — entries
        # of one source are contiguous within a frame, ascending block
        g_key = h_shard * n_shards + h_src
        g_first = np.searchsorted(g_key, np.arange(n_shards * n_shards))
        r_slot = np.arange(len(uniq_h), dtype=np.int64) - g_first[g_key]
        p.halo_frame_src[h_shard, h_slot] = \
            (h_src * hb_pad + r_slot).astype(np.int32)
        # send lists, ascending source-local block per (source, dest)
        # pair — exactly the receive order r_slot encodes
        s_sort = np.argsort(skey, kind="stable")
        sk = skey[s_sort]
        s_first = np.searchsorted(sk, np.arange(n_shards * n_shards))
        s_slot = np.arange(len(uniq_h), dtype=np.int64) - s_first[sk]
        p.halo_send_block[sk // n_shards, sk % n_shards, s_slot] = \
            (h_block % n_cb_loc)[s_sort].astype(np.int32)
        pos_lut = np.zeros((n_shards, n_cb), np.int64)
        pos_lut[h_shard, h_block] = h_slot

    # --- vectorized block-ELL build (cf. repro.kernels.spmm.ops, which
    # loops per tile; this path is a handful of numpy passes)
    if build_tiles:
        # slot of each unique tile within its row block: uniq is sorted,
        # so tiles of one rb are contiguous and column-sorted
        first_of_rb = np.concatenate([[0], np.cumsum(counts)[:-1]])
        slot = np.arange(len(uniq), dtype=np.int64) - first_of_rb[tile_rb]
        if n_shards > 1:
            # same tiles, same slots — only the row-block axis moves to
            # its shard position and column ids map to packed superblocks
            # (or, halo, to the owning shard's frame position: every tile
            # exists because of >= 1 edge, so its (shard, block) pair is
            # always in the frame lookup)
            packed_cb = sb_perm[tile_cb]
            if halo_on:
                t_shard = rb_perm[tile_rb] * RB // rows_loc
                p.tile_col[rb_perm[tile_rb], slot] = \
                    pos_lut[t_shard, packed_cb].astype(np.int32)
            else:
                p.tile_col[rb_perm[tile_rb], slot] = \
                    packed_cb.astype(np.int32)
            p.valid[rb_perm[tile_rb], slot] = 1
            np.add.at(p.tiles, (rb_perm[rb], slot[inverse], dst % RB,
                                src % CB), e_coef)
        else:
            p.tile_col[tile_rb, slot] = tile_cb
            p.valid[tile_rb, slot] = 1
            np.add.at(p.tiles, (rb, slot[inverse], dst % RB, src % CB),
                      e_coef)

    # --- per-row hop -> per-row-block min hop; the (n_pad,) scratch is
    # KB-scale and the vectorized scatter + reshape-min beats a buffered
    # ufunc.at by an order of magnitude on large supports
    hop_row = np.full(n_pad, _INF_HOP, np.int32)
    hop_row[row_dest] = hop_eff
    p.hop_rb[:] = hop_row.reshape(n_rb, RB).min(axis=1)

    p.x0[row_dest, :x0.shape[1]] = np.asarray(x0, np.float32)
    # a zero-column x_inf means the caller only needs the batch-row count
    # (fused path: the kernel streams the rank-1 factors instead)
    if n_shards > 1:
        p.x_inf[bat_perm[:nb], :x_inf.shape[1]] = x_inf
    else:
        p.x_inf[:nb, :x_inf.shape[1]] = x_inf

    if x_inf_factors is not None:
        c, s = x_inf_factors
        p.c_inf.fill(0.0)
        if n_shards > 1:
            p.c_inf[bat_perm[:nb]] = np.asarray(c, np.float32)
        else:
            p.c_inf[:nb] = np.asarray(c, np.float32)
        p.s_inf.fill(0.0)
        p.s_inf[:len(s)] = np.asarray(s, np.float32)

    # bucket-padded edge list (segment-sum path): pad with zero-coef
    # self-edges on the last (always padding or hop-max) row
    if build_edges:
        if n_shards > 1:
            # halo: src addresses the shard's frame rows, not the global
            # frontier; padding edges point at the frame's last (padding)
            # row with coef 0
            if halo_on:
                src_x = pos_lut[e_shard, src_p // CB] * CB + src_p % CB
                src_fill = h_pad * CB - 1
            else:
                src_x = src_p
                src_fill = n_pad - 1
            p.src.fill(src_fill)
            p.dst.fill(rows_loc - 1)
            p.coef.fill(0.0)
            # per-shard slices keep the ORIGINAL edge order (all of one
            # row's contributions live in one shard), so segment-sum
            # accumulates each row in the single-device order
            for sh in range(n_shards):
                m = e_shard == sh
                k = int(e_counts[sh])
                p.src[sh, :k] = src_x[m].astype(np.int32)
                p.dst[sh, :k] = (dst_p[m] - sh * rows_loc).astype(np.int32)
                p.coef[sh, :k] = e_coef[m]
        else:
            p.src.fill(n_pad - 1)
            p.dst.fill(n_pad - 1)
            p.coef.fill(0.0)
            p.src[:len(src)] = src
            p.dst[:len(dst)] = dst
            p.coef[:len(e_coef)] = e_coef

    # --- cache-seed operands: padded row ids of hit rows + their series,
    # padded to k_pad (pad ids point one past the [local] row range — the
    # NAP loop's `mode="drop"` scatter ignores them)
    if seeds_on:
        fh = seed_series.shape[2]
        if n_shards > 1:
            p.seed_rows.fill(rows_loc)
            p.seed_vals.fill(0.0)
            for sh in range(n_shards):
                m = sd_shard == sh
                k = int(sd_counts[sh])
                p.seed_rows[sh, :k] = \
                    (sd_dest[m] - sh * rows_loc).astype(np.int32)
                p.seed_vals[sh, :, :k, :fh] = \
                    seed_series[m].transpose(1, 0, 2)
        else:
            p.seed_rows.fill(n_pad)
            p.seed_vals.fill(0.0)
            p.seed_rows[:len(sd_dest)] = sd_dest.astype(np.int32)
            p.seed_vals[:, :len(sd_dest), :fh] = \
                seed_series.transpose(1, 0, 2)
    return p


def step_active_blocks(hop_rb: np.ndarray, t_max: int) -> np.ndarray:
    """(t_max, n_rb) int32: row blocks whose X^(l) value can still reach a
    batch output at step l = 1..t_max (hop <= T_max - l). Row 0 of the
    result is step l=1."""
    ls = np.arange(1, t_max + 1, dtype=np.int64)[:, None]
    return (hop_rb[None, :] <= t_max - ls).astype(np.int32)
