"""Fused NAP propagation step: block-ELL SpMM + exit decision, one kernel.

The two-launch compiled path (`repro.kernels.spmm.spmm_block_ell` followed
by `repro.kernels.nap_exit.nap_exit`) writes the full padded (n_pad, F_pad)
propagated features to HBM and reads the batch region back just to compute
a distance — the VMEM round trip flagged in ROADMAP's "next steps". This
kernel does both in one grid pass: per row block it performs the block-ELL
accumulation, and while the freshly accumulated output block is still
resident in VMEM it folds the squared distance to the stationary state
(paper Eq. 8) into a VMEM scratch accumulator; the final feature block
turns the accumulator into per-node exit flags plus the per-row-block
`any node still active` predicate. The consumer collapses that predicate
to the GLOBAL any-batch-node-live flag before ANDing with the static hop
mask (repro.gnn.nai) — exited batch rows must keep propagating while any
neighbor is live, since their values feed other rows' aggregation, so
per-block gating of batch blocks would corrupt results. The propagated
block never leaves VMEM between the matmul and the distance check, and
Pallas's pipelined grid double-buffers the coefficient tiles exactly as
in the plain SpMM kernel.

The stationary state is rank-1 by construction (Eq. 7: Â^∞X = c ⊗ s), so
the kernel streams its FACTORS — c (nb, 1) per row block and s (1, F) per
feature block — instead of a dense (nb, F) x_inf operand: the stationary
state is never materialized in HBM at all, and the exit check's extra
operand traffic per step drops from nb*F to nb + F.

Grid: (row_blocks, feature_blocks, max_tiles_per_row_block); the tile loop
is innermost so the output block stays resident while accumulating, and
the (RB, 1) distance scratch lives outside the pipeline entirely — row
blocks are visited in order, so it is re-zeroed at each row block's first
cell. Batch blocks (rb < nb_rb) come first and are the only ones that
carry exit state.

Operand contract (all shapes bucket-padded by repro.gnn.packing):
  scalar prefetch: tile_col (n_rb*tb,), active (n_rb,), valid (n_rb*tb,),
                   ts2 (1,) — the SQUARED threshold; pass a negative value
                   to disable exits for this step (l < T_min or l == T_max).
  inputs:  tiles (n_rb, tb, RB, CB) f32; x (n_cb*CB, F) with F % FB == 0;
           c_inf (nb, 1) f32 and s_inf (1, F) f32 — the rank-1 stationary
           state factors (x_inf = c_inf @ s_inf), nb % RB == 0 (the padded
           batch region; row blocks past nb//RB skip the distance section);
           node_active (nb, 1) int32 'not yet exited'.
  outputs: out (n_rb*RB, F); exit (nb, 1) int32;
           blk_still (n_rb, 1) int32 (zero for non-batch row blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spmm.kernel import CB, FB, RB


def _kernel(tile_col_ref, active_ref, valid_ref, ts2_ref,   # scalar prefetch
            tiles_ref, x_ref, c_ref, s_ref, nact_ref,
            out_ref, exit_ref, blk_ref, dist_ref, *, nb_rb):
    rb = pl.program_id(0)
    fb = pl.program_id(1)
    t = pl.program_id(2)
    nfb = pl.num_programs(1)
    ntb = pl.num_programs(2)
    is_batch = rb < nb_rb

    @pl.when(t == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((t == 0) & (fb == 0) & is_batch)
    def _init_dist():
        dist_ref[...] = jnp.zeros_like(dist_ref)

    is_active = active_ref[rb] != 0
    is_valid = valid_ref[rb * ntb + t] != 0

    @pl.when(is_active & is_valid)
    def _acc():
        a = tiles_ref[0, 0]                      # (RB, CB)
        x = x_ref[...]                           # (CB, FB)
        out_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32
                                ).astype(out_ref.dtype)

    # the output block is complete once the tile loop finishes; fold its
    # contribution to ||x - x_inf||^2 while it is still in VMEM, with the
    # x_inf block rebuilt from its rank-1 factors (never read from HBM)
    @pl.when((t == ntb - 1) & is_batch)
    def _dist():
        x_inf = c_ref[...] * s_ref[...]          # (RB, 1) * (1, FB)
        diff = (out_ref[...] - x_inf).astype(jnp.float32)
        dist_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when((t == ntb - 1) & (fb == nfb - 1) & is_batch)
    def _decide():
        was_active = nact_ref[...] != 0
        exits = was_active & (dist_ref[...] < ts2_ref[0])
        still = was_active & ~exits
        exit_ref[...] = exits.astype(jnp.int32)
        blk_ref[0, 0] = jnp.any(still).astype(jnp.int32)

    @pl.when((t == ntb - 1) & (fb == nfb - 1) & ~is_batch)
    def _no_exit_state():
        blk_ref[0, 0] = jnp.int32(0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nap_step_fused(tiles, tile_col, valid, active, x, c_inf, s_inf,
                   node_active, ts2, *, interpret=True):
    """One fused NAP step. See the module docstring for the operand
    contract. `ts2` is a (1,) f32 array holding the squared exit threshold
    (negative disables exits). Returns (out, exit, blk_still)."""
    n_rb, max_tb = tile_col.shape
    n, F = x.shape
    c_inf = c_inf.reshape(-1, 1)
    s_inf = s_inf.reshape(1, -1)
    nb = c_inf.shape[0]
    assert n % CB == 0 and F % FB == 0, (n, F)
    assert nb % RB == 0 and nb >= RB and s_inf.shape[1] == F, (nb, F)
    assert node_active.shape == (nb, 1), node_active.shape
    nb_rb = nb // RB

    grid = (n_rb, F // FB, max_tb)
    flat_cols = tile_col.reshape(-1).astype(jnp.int32)
    flat_valid = valid.reshape(-1).astype(jnp.int32)

    def clamp(rb):
        return jnp.minimum(rb, nb_rb - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, RB, CB), lambda rb, fb, t, *_: (rb, t, 0, 0)),
            pl.BlockSpec((CB, FB),
                         lambda rb, fb, t, cols, *_:
                         (cols[rb * pl.num_programs(2) + t], fb)),
            pl.BlockSpec((RB, 1), lambda rb, fb, t, *_: (clamp(rb), 0)),
            pl.BlockSpec((1, FB), lambda rb, fb, t, *_: (0, fb)),
            pl.BlockSpec((RB, 1), lambda rb, fb, t, *_: (clamp(rb), 0)),
        ],
        out_specs=(
            pl.BlockSpec((RB, FB), lambda rb, fb, t, *_: (rb, fb)),
            pl.BlockSpec((RB, 1), lambda rb, fb, t, *_: (clamp(rb), 0)),
            pl.BlockSpec((1, 1), lambda rb, fb, t, *_: (rb, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((RB, 1), jnp.float32)],
    )
    out_shape = (
        jax.ShapeDtypeStruct((n_rb * RB, F), x.dtype),
        jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        jax.ShapeDtypeStruct((n_rb, 1), jnp.int32),
    )
    fn = pl.pallas_call(functools.partial(_kernel, nb_rb=nb_rb),
                        grid_spec=grid_spec, out_shape=out_shape,
                        interpret=interpret)
    return fn(flat_cols, active.astype(jnp.int32), flat_valid,
              jnp.asarray(ts2, jnp.float32).reshape(1),
              tiles, x, c_inf.astype(x.dtype), s_inf.astype(x.dtype),
              node_active.astype(jnp.int32))
