"""Train a ~100M-class reduced LM for a few hundred steps with the paper's
technique generalized to transformers: early-exit heads trained by Inception
Distillation, then Adaptive-Depth decoding.

    PYTHONPATH=src python examples/train_lm_adaptive.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import AdaptiveDepthConfig, TrainConfig
from repro.configs import ARCHS, smoke
from repro.core.adaptive_depth import adaptive_decode_step
from repro.data import synthetic_stream
from repro.models import decoder_lm as M
from repro.nn.params import count_params
from repro.optim import adamw_init, adamw_update, make_schedule

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# reduced granite with 6 layers + exit heads at blocks 1/3/4
base = smoke(ARCHS["granite-34b"])
cfg = dataclasses.replace(
    base, num_layers=6, d_model=256, d_ff=768, num_heads=8, num_kv_heads=2,
    vocab_size=512,
    adaptive=AdaptiveDepthConfig(enabled=True, exit_layers=(1, 3, 4),
                                 t_s=0.35, t_min=1, t_max=4,
                                 temperature=1.4, lam=0.9, ensemble_r=2))
params = M.init_params(cfg, jax.random.PRNGKey(0))
print(f"[model] {count_params(params):,} params, exits at blocks "
      f"{cfg.adaptive.exit_layers}")

tc = TrainConfig(learning_rate=3e-3, warmup_steps=20, total_steps=args.steps,
                 weight_decay=0.01)
opt = adamw_init(params, tc)
sched = make_schedule(tc)


@jax.jit
def step(params, opt, batch):
    (loss, metrics), grads = jax.value_and_grad(
        M.loss_fn, argnums=1, has_aux=True)(cfg, params, batch)
    params, opt, om = adamw_update(grads, opt, params, tc, sched(opt["count"]))
    return params, opt, {**metrics, **om}


stream = synthetic_stream(0, args.batch, args.seq, cfg.vocab_size)
t0 = time.time()
for i in range(args.steps):
    b = next(stream)
    params, opt, m = step(params, opt, {"tokens": jnp.asarray(b["tokens"])})
    if i % 25 == 0 or i == args.steps - 1:
        print(f"step {i:4d} loss={float(m['loss']):.3f} "
              f"lm={float(m['lm_loss']):.3f} "
              f"inception={float(m.get('inception_loss', 0.0)):.3f} "
              f"({time.time() - t0:.0f}s)", flush=True)

# --- adaptive decode: measure exit behaviour and saved depth
cache = M.init_cache(cfg, args.batch, 64)
tok = jnp.asarray(next(stream)["tokens"][:, :1])
saved, exits = [], []
for t in range(32):
    logits, cache, info = adaptive_decode_step(cfg, params, cache, tok,
                                               jnp.int32(t))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    saved.append(float(info["flops_saved_frac"]))
    exits.append(np.asarray(info["exit_block"]))
    if t == 0:
        print(f"[adaptive decode] step-0 saturation distances: "
              f"{np.round(np.asarray(info['saturation']), 3)}")
exits = np.stack(exits)
print(f"[adaptive decode] mean depth-FLOPs saved: {np.mean(saved):.1%}")
hist = np.bincount(np.where(exits < 0, cfg.pattern_repeats - 1,
                            exits).ravel(), minlength=cfg.pattern_repeats)
print(f"[adaptive decode] exit-block histogram: {list(hist)} "
      f"(-1 -> full depth bucket {cfg.pattern_repeats - 1})")
