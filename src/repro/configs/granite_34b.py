"""granite-34b — dense llama-arch code model [arXiv:2405.04324].
88L, d_model 6144, 48 heads (MQA kv=1), d_ff 24576, vocab 49152."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    source="arXiv:2405.04324",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=10000.0,
)
