from repro.data.tokens import synthetic_lm_batch, synthetic_stream

__all__ = ["synthetic_lm_batch", "synthetic_stream"]
