"""End-to-end serving driver (the paper's deployment scenario).

Simulates a stream of inference requests over unseen nodes arriving in
bursts, served by the batched NAI engine under a latency budget; reports
latency percentiles and the adaptive exit-order histogram for BOTH
serving paths:

* host     — numpy Algorithm 1 per batch (faithful reference)
* compiled — vectorized sampling -> bucket-padded packing -> one jitted
             propagate+classify step (segment-sum SpMM here; pass
             spmm_impl="block_ell" to drive the Pallas kernel, which on
             CPU runs in interpret mode and is an emulation, not a
             timing)

The compiled pass also enables the propagated-feature cache
(``cache_nodes=``, README "Propagated-feature cache") and serves the
burst stream twice: the second pass hits on frontier nodes the first
pass cached, and ``engine.cache_stats`` shows the packed-SpMM rows the
hits removed.

The engine is store-first: graphs are served through a `GraphStore`
(`InMemoryStore` here; `MmapStore` for on-disk graphs that must not be
paged into RAM).

    PYTHONPATH=src python examples/serve_stream.py

Set ``EXAMPLES_SMOKE=1`` for the scaled-down CI shape.
"""
import os
import time

import numpy as np

from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, load_dataset,
                       train_nai)
from repro.gnn.store import InMemoryStore
from repro.serving import NAIServingEngine

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))

g = load_dataset("flickr-like", scale=0.01 if SMOKE else 0.03, seed=1)
cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=4, hidden=64,
                mlp_layers=2)
print(f"[setup] training on {g.name}: n={g.n} m={g.num_edges}")
ep = (20, 10, 10) if SMOKE else (120, 60, 60)
params, _ = train_nai(cfg, g, DistillConfig(epochs_base=ep[0],
                                            epochs_offline=ep[1],
                                            epochs_online=ep[2]))

store = InMemoryStore(g)
nai = NAIConfig(t_s=12.0, t_min=1, t_max=3,
                batch_size=64 if SMOKE else 256)
rng = np.random.default_rng(0)
n_bursts, burst = (4, 100) if SMOKE else (8, 400)
bursts = [rng.choice(g.test_idx, size=burst, replace=False)
          for _ in range(n_bursts)]

for mode, kw in (("host", {}),
                 ("compiled", {"spmm_impl": "segment",
                               "cache_nodes": 4096})):
    engine = NAIServingEngine(cfg, nai, params, store, max_wait_s=0.005,
                              mode=mode, **kw)
    passes = 2 if mode == "compiled" else 1   # pass 2 hits pass 1's fills
    t0 = time.perf_counter()
    for p in range(passes):
        for nodes in bursts:
            engine.submit(nodes)
            while engine.queue:               # a burst spans >1 batch
                engine.step()
        engine.flush()                        # drain the pipeline
    wall = time.perf_counter() - t0
    print(f"[serve:{mode}] {passes}x {n_bursts} bursts x {burst} requests")

    s = engine.stats.summary()
    print(f"[result:{mode}] served={s['served']} batches={s['batches']} "
          f"wall={wall:.2f}s")
    print(f"[result:{mode}] latency p50={s['p50_ms']:.1f}ms "
          f"p95={s['p95_ms']:.1f}ms p99={s['p99_ms']:.1f}ms")
    print(f"[result:{mode}] mean exit order={s['mean_exit_order']:.2f} "
          f"(k={cfg.k} would be vanilla)")
    print(f"[result:{mode}] exit histogram="
          f"{dict(sorted(engine.stats.exit_hist.items()))}")
    if mode == "compiled":
        print(f"[result:{mode}] jit compiles={engine.jit_stats['compiles']} "
              f"cache hits={engine.jit_stats['hits']} "
              f"(shape buckets keep steady-state compiles at 0)")
        cs = engine.cache_stats
        print(f"[result:{mode}] feature cache: hit_rate={cs['hit_rate']:.3f} "
              f"rows_packed={cs['rows_packed']}/{cs['rows_support']} "
              f"(hit frontier rows are dropped from the packed SpMM)")
