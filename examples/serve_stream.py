"""End-to-end serving driver (the paper's deployment scenario).

Simulates a stream of inference requests over unseen nodes arriving in
bursts, served by the batched NAI engine under a latency budget; reports
latency percentiles and the adaptive exit-order histogram.

    PYTHONPATH=src python examples/serve_stream.py
"""
import time

import numpy as np

from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, load_dataset,
                       train_nai)
from repro.serving import NAIServingEngine

g = load_dataset("flickr-like", scale=0.03, seed=1)
cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=4, hidden=64,
                mlp_layers=2)
print(f"[setup] training on {g.name}: n={g.n} m={g.num_edges}")
params, _ = train_nai(cfg, g, DistillConfig(epochs_base=120,
                                            epochs_offline=60,
                                            epochs_online=60))

engine = NAIServingEngine(
    cfg, NAIConfig(t_s=12.0, t_min=1, t_max=3, batch_size=256), params, g,
    max_wait_s=0.005)

rng = np.random.default_rng(0)
n_bursts, burst = 8, 400
print(f"[serve] {n_bursts} bursts x {burst} requests")
for i in range(n_bursts):
    nodes = rng.choice(g.test_idx, size=burst, replace=False)
    engine.submit(nodes)
    while engine.queue:
        engine.step()

s = engine.stats.summary()
print(f"[result] served={s['served']} batches={s['batches']}")
print(f"[result] latency p50={s['p50_ms']:.1f}ms p95={s['p95_ms']:.1f}ms "
      f"p99={s['p99_ms']:.1f}ms")
print(f"[result] mean exit order={s['mean_exit_order']:.2f} "
      f"(k={cfg.k} would be vanilla)")
print(f"[result] exit histogram={dict(sorted(engine.stats.exit_hist.items()))}")
