"""Continuous-batching LM decode engine with Adaptive-Depth Inference.

The LM counterpart of the NAI serving engine: a fixed pool of `slots`
decodes in lock-step (one fused `decode_step`/`adaptive_decode_step` per
tick); finished sequences free their slot, queued requests claim freed
slots mid-flight (their KV range restarts at position 0 per slot — slots
are independent batch lanes). Adaptive depth reports per-tick depth-FLOPs
saved — the paper's latency/accuracy dial generalized to token decoding.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decoder_lm as M


@dataclasses.dataclass
class LMRequest:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    done_s: float = -1.0


@dataclasses.dataclass
class _Slot:
    req: Optional[LMRequest] = None
    pos: int = 0                 # next write position in this lane's cache
    pending: List[int] = dataclasses.field(default_factory=list)


class LMServingEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 adaptive: bool = False, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.slots = [_Slot() for _ in range(slots)]
        self.max_len = max_len
        self.adaptive = adaptive and cfg.adaptive.enabled
        self.eos_id = eos_id
        self.queue: Deque[LMRequest] = deque()
        self.cache = M.init_cache(cfg, slots, max_len)
        self.ticks = 0
        self.flops_saved: List[float] = []
        self.completed: List[LMRequest] = []

        if self.adaptive:
            from repro.core.adaptive_depth import adaptive_decode_step

            def step(params, cache, tok, pos):
                logits, cache, info = adaptive_decode_step(
                    cfg, params, cache, tok, pos)
                return logits, cache, info["flops_saved_frac"]
        else:
            def step(params, cache, tok, pos):
                logits, cache = M.decode_step(cfg, params, cache, tok, pos)
                return logits, cache, jnp.float32(0.0)

        self._step = jax.jit(step)

    # -------------------------------------------------------------- control
    def submit(self, prompt: List[int], max_new: int = 16) -> LMRequest:
        req = LMRequest(rid=len(self.completed) + len(self.queue),
                        prompt=list(prompt), max_new=max_new,
                        submitted_s=time.perf_counter())
        self.queue.append(req)
        return req

    def _fill_slots(self):
        for s in self.slots:
            if s.req is None and self.queue:
                s.req = self.queue.popleft()
                s.pos = 0
                s.pending = list(s.req.prompt)

    @property
    def active(self) -> int:
        return sum(s.req is not None for s in self.slots)

    # ----------------------------------------------------------------- tick
    def tick(self) -> int:
        """One decode step for every live lane. NOTE: lock-step position —
        each lane tracks its own pos, but the fused step uses the max lane
        position for cache writes of idle lanes (masked by sampling)."""
        self._fill_slots()
        if self.active == 0:
            return 0
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            toks[i, 0] = (s.pending.pop(0) if s.pending
                          else (s.req.out[-1] if s.req.out else 0))
        # all live lanes share the tick position = per-engine clock; lanes
        # that joined late waste leading cache slots AND attend to the
        # zeroed entries there (small uniform noise) — per-lane validity
        # masks are the noted production follow-up
        pos = jnp.int32(self.ticks % self.max_len)
        logits, self.cache, saved = self._step(
            self.params, self.cache, jnp.asarray(toks), pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        self.flops_saved.append(float(saved))
        done = 0
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if s.pending:                 # still consuming the prompt
                continue
            s.req.out.append(int(nxt[i]))
            finished = (len(s.req.out) >= s.req.max_new
                        or int(nxt[i]) == self.eos_id
                        or self.ticks >= self.max_len - 2)
            if finished:
                s.req.done_s = time.perf_counter()
                self.completed.append(s.req)
                s.req = None
                done += 1
        self.ticks += 1
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> Dict[str, float]:
        while (self.queue or self.active) and self.ticks < max_ticks:
            self.tick()
        lat = [r.done_s - r.submitted_s for r in self.completed
               if r.done_s > 0]
        return {
            "completed": len(self.completed),
            "ticks": self.ticks,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_depth_flops_saved": float(np.mean(self.flops_saved))
            if self.flops_saved else 0.0,
        }
