import os
import sys

# Tests run on the single real CPU device (the 512-device forcing is ONLY in
# repro.launch.dryrun, per the dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
