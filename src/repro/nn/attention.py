"""Self/cross attention with GQA/MQA, RoPE, sliding windows and KV caches.

Shapes: x (B, S, d); q (B, S, H, hd); k/v (B, S, KV, hd).
All attention math runs in f32 for stability; inputs/outputs keep model dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.basic import rotary
from repro.nn.params import ParamDef
from repro.sharding import constrain

NEG_INF = -2.0e38


def attn_defs(cfg, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    # When kv_heads don't divide the TP axis but q heads do (llama/mistral
    # GQA kv=8), shard wk/wv on head_dim to match the q-head TP layout and
    # the hd-sharded KV cache — the leftmost (contracting-d) fallback here
    # measured 2x worse on llama-vision train_4k (§Perf follow-up).
    kv_nd = cfg.num_kv_heads % 16 != 0
    hd_tp = kv_nd and cfg.num_heads % 16 == 0 and hd % 16 == 0
    # (kv_heads must be absent from the spec when cache_hd is used, or the
    # logical builder's duplicate-axis guard nullifies the hd entry)
    kv_logical = ("embed", None, "cache_hd") if hd_tp \
        else ("embed", "kv_heads", "head_dim")
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, KV, hd), kv_logical),
        "wv": ParamDef((d, KV, hd), kv_logical),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed")),
    }


def _soft_cap(logits, cap):
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


def _sdpa(cfg, q, k, v, mask) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask broadcastable to (B,H,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    # QK dot reads q/k in their stored dtype (bf16) and accumulates f32:
    # the cache IS bf16, so casting it to f32 first adds zero information
    # but round-trips the entire cache through HBM every decode step
    # (measured ~1 TB/chip on mistral decode_32k — §Perf-3 iteration 4).
    qf = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))).astype(q.dtype)
    qf = qf.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qf, k,
                        preferred_element_type=jnp.float32)
    vf = v
    logits = _soft_cap(logits, cfg.attn_logit_softcap)
    if mask is not None:
        # additive mask: one fused add instead of broadcast+select passes
        # over the S^2 buffer (§Perf-1 iteration 6)
        bias = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
        logits = logits + bias[:, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1)
    # PV product reads the S^2 weights in bf16 (halves one full pass over
    # the logits-sized tensor) but accumulates in f32; probabilities are
    # O(1) so the bf16 quantization error is ~1e-3 relative — verified by
    # the decode-vs-full and flash-kernel tests.
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def causal_mask(Sq: int, Sk: int, window: int = 0, offset: int = 0):
    """(1, Sq, Sk) causal (optionally banded) mask. `offset` = absolute
    position of query 0 minus key 0 (for prefill continuation)."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window and window > 0:
        m &= kpos > qpos - window
    return m[None]


# Sequences longer than this are processed in query chunks (flash-style at
# the XLA level): no (S, S) buffer is ever materialized, the per-chunk
# (B, H, Q_CHUNK, S) logits are the only transient. Exact numerics.
CHUNK_THRESHOLD = 8192
Q_CHUNK = 512


def _seq_shard(cfg, x):
    """Context-parallel constraint: shard the seq dim over 'model'. Only for
    flagged configs (non-TP-divisible heads) and production-sized chunks."""
    if cfg.seq_shard_attn and x.shape[1] >= 256 and x.shape[1] % 16 == 0:
        return constrain(x, "batch", "qseq", None, None)
    return x


def _chunked_sdpa(cfg, q, k, v, *, window: int):
    """Causal (optionally banded) attention via lax.scan over query chunks."""
    B, S, H, hd = q.shape
    nq = S // Q_CHUNK
    qc = jnp.moveaxis(q.reshape(B, nq, Q_CHUNK, H, hd), 1, 0)

    def body(_, inp):
        i, qi = inp
        qi = _seq_shard(cfg, qi)
        offset = i * Q_CHUNK
        qpos = offset + jnp.arange(Q_CHUNK)[:, None]
        kpos = jnp.arange(S)[None, :]
        m = kpos <= qpos
        if window and window > 0:
            m &= kpos > qpos - window
        out = _sdpa(cfg, qi, k, v, m[None])
        return None, _seq_shard(cfg, out)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qc))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def self_attention(cfg, p, x, positions, *, window: int = 0,
                   mask: Optional[jax.Array] = None):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v))."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.use_rope:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    S = x.shape[1]
    if mask is None and S > CHUNK_THRESHOLD and S % Q_CHUNK == 0:
        out = _chunked_sdpa(cfg, q, k, v, window=window)
    else:
        if mask is None:
            mask = causal_mask(S, S, window)
        out = _sdpa(cfg, _seq_shard(cfg, q), k, v, mask)
        out = _seq_shard(cfg, out)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return constrain(out, "batch", "seq", "embed"), (k, v)


def cross_attention(cfg, p, x, kv_cache):
    """x (B,Sq,d) attends to precomputed (k, v) from the frontend/encoder."""
    k, v = kv_cache
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])   # no RoPE on cross-attn
    out = _sdpa(cfg, q, k, v, mask=None)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return constrain(out, "batch", "seq", "embed")


def project_kv(cfg, p, y):
    """Project frontend/encoder output y (B,Se,d) to (k, v) for cross-attn."""
    k = jnp.einsum("bsd,dke->bske", y, p["wk"])
    v = jnp.einsum("bsd,dke->bske", y, p["wv"])
    return k, v


# ----------------------------------------------------------------- decoding
def init_kv_cache(cfg, batch: int, length: int, dtype) -> dict:
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    z = jnp.zeros((batch, length, KV, hd), dtype)
    return {"k": z, "v": z}


def decode_self_attention(cfg, p, x, cache, pos, *, window: int = 0):
    """One-token decode. x (B,1,d); cache {'k','v'} (B,L,KV,hd); pos scalar
    int32 = index of the new token. For windowed layers the cache is a ring
    buffer of length `window`."""
    B = x.shape[0]
    L = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.use_rope:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    if cfg.num_kv_heads % 16 != 0 and cfg.resolved_head_dim % 16 == 0:
        # match the hd-sharded KV cache layout (§Perf-3): with q sharded the
        # same way the logits dot becomes partial-sum + a small all-reduce;
        # otherwise GSPMD "involuntarily rematerializes" (= all-gathers) the
        # whole cache every step (measured 94 GB/chip on mistral decode_32k)
        q = constrain(q, "batch", "rep", "rep", "cache_hd")
        k = constrain(k, "batch", "rep", "rep", "cache_hd")
        v = constrain(v, "batch", "rep", "rep", "cache_hd")
    slot = jnp.where(window > 0, pos % jnp.int32(max(window, 1)), pos)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    kpos = jnp.arange(L)[None, :]
    if window > 0:
        # ring buffer: every slot written so far is within the window by
        # construction; RoPE was applied at absolute positions already.
        valid = kpos <= jnp.minimum(pos, L - 1)
    else:
        valid = kpos <= pos
    mask = jnp.broadcast_to(valid[None, :, :], (B, 1, L))
    out = _sdpa(cfg, q, ck, cv, mask)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    out = constrain(out, "batch", "seq", "embed")
    return out, {"k": ck, "v": cv}
