from repro.sharding.logical import DEFAULT_RULES, constrain, named, resolve, spec

__all__ = ["DEFAULT_RULES", "constrain", "named", "resolve", "spec"]
