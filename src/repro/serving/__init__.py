from repro.serving.engine import (EngineConfig, EngineStats,
                                  NAIServingEngine, Request)
from repro.serving.frontend import (ClassStats, ServingFrontend, SLOClass,
                                    default_slo_classes)
from repro.serving.lm_engine import LMRequest, LMServingEngine

__all__ = ["EngineConfig", "EngineStats", "NAIServingEngine", "Request",
           "ClassStats", "ServingFrontend", "SLOClass",
           "default_slo_classes", "LMRequest", "LMServingEngine"]
