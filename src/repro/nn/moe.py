"""Mixture-of-Experts FFN (Switch-style top-k routing with per-row capacity).

TPU adaptation notes (see DESIGN.md §3):
  * dispatch uses a per-sequence-row capacity buffer (B, E, C, d) built with a
    vmapped scatter — static shapes, no ragged segments;
  * expert weights are sharded `expert -> replicated`, `d_ff -> model` (TP) and
    `d_model -> data` (FSDP); tokens never leave their data shard, so routing
    costs no all-to-all (the trade-off vs. expert-parallelism is a §Perf item);
  * dropped tokens (beyond capacity) fall through on the residual path, the
    standard Switch behaviour.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.params import ParamDef
from repro.sharding import constrain


def moe_defs(cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((d, E), ("embed", None), "small"),
        "w_gate": ParamDef((E, d, f), ("expert", "fsdp", "mlp")),
        "w_up": ParamDef((E, d, f), ("expert", "fsdp", "mlp")),
        "w_down": ParamDef((E, f, d), ("expert", "mlp", "fsdp")),
        # NOTE: "expert" -> data axis = expert parallelism; when E doesn't
        # divide the axis, fit_spec falls back to FSDP on d (grok-1).
    }


def _capacity(cfg, tokens_per_row: int) -> int:
    c = int(tokens_per_row * cfg.experts_per_token
            / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def apply_moe(cfg, p, x):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = _capacity(cfg, S)

    gate_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                             p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(gate_logits, axis=-1)                    # (B,S,E)
    gate_w, expert_idx = jax.lax.top_k(probs, k)                    # (B,S,k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # ---- flatten assignments: (B, S*k)
    eid = expert_idx.reshape(B, S * k)
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)                # (B,S*k,E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1    # (B,S*k)
    keep = pos_in_e < C
    slot = jnp.clip(pos_in_e, 0, C - 1)

    x_rep = jnp.repeat(x, k, axis=1)                                # (B,S*k,d)

    def dispatch_row(xb, eb, sb, kb):
        buf = jnp.zeros((E, C, d), x.dtype)
        return buf.at[eb, sb].add(xb * kb[:, None].astype(x.dtype))

    buf = jax.vmap(dispatch_row)(x_rep, eid, slot, keep)            # (B,E,C,d)
    # the batch/replication pins + weight gathers only pay off when the
    # token volume dwarfs the expert weights; for decode-sized inputs the
    # rep-pinned weights were ALL-GATHERED per step (86 GB/chip on grok
    # long_500k — §Perf follow-up), so gate on token count.
    big = B * S >= 8192
    if big:
        buf = constrain(buf, "batch", "rep", "rep", "rep")

    # ---- expert FFN (SwiGLU-family matched to cfg.mlp_kind)
    # FSDP done right: all-gather the (small) weights over 'data' here and
    # contract locally. Without this GSPMD keeps the contracting dim d
    # sharded and all-reduces the activation-sized partials — measured
    # 2.9 TB/chip of the 3.6 TB/chip collective total on dbrx train_4k
    # (§Perf-2 iteration 2).
    w_gate = constrain(p["w_gate"], "rep", "rep", "mlp") if big else p["w_gate"]
    w_up = constrain(p["w_up"], "rep", "rep", "mlp") if big else p["w_up"]
    w_down = constrain(p["w_down"], "rep", "mlp", "rep") if big else p["w_down"]
    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
        lambda z: jax.nn.gelu(z, approximate=True))
    h = act(jnp.einsum("becd,edf->becf", buf, w_gate)) \
        * jnp.einsum("becd,edf->becf", buf, w_up)
    h = (constrain(h, "batch", "rep", "rep", "mlp") if big else h).astype(x.dtype)
    # explicit narrow cast: XLA's excess-precision pass otherwise keeps the
    # TP partial sums in f32 THROUGH the all-reduce — the buffer-sized
    # collectives were all f32 (§Perf-2 iteration 3)
    # keep d sharded over model here: the TP partial becomes a
    # reduce-scatter of the slot-sized buffer instead of a full all-reduce,
    # and only the (5x smaller) token-sized y is gathered after the combine
    # (§Perf-2 iteration 4)
    out_buf = jnp.einsum("becf,efd->becd", h, w_down).astype(x.dtype)
    if big:
        out_buf = constrain(out_buf, "batch", "rep", "rep", "embed_tp")

    # ---- combine: gather each assignment's output and weight it
    def gather_row(ob, eb, sb):
        return ob.reshape(E * C, d)[eb * C + sb]

    y_rep = jax.vmap(gather_row)(out_buf, eid, slot)                # (B,S*k,d)
    y_rep = y_rep * keep[..., None].astype(y_rep.dtype)
    y_rep = y_rep.reshape(B, S, k, d) * gate_w[..., None].astype(y_rep.dtype)
    y = y_rep.sum(axis=2)
    y = constrain(y, "batch", "seq", "embed_tp")

    # ---- load-balance aux loss (Switch): E * sum_e f_e * P_e
    frac = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(frac * mean_prob) * cfg.router_aux_weight
    return y.astype(x.dtype), aux


def apply_moe_decode(cfg, p, x):
    """One-token decode: treat the batch as the routing row. x (B,1,d)."""
    y, aux = apply_moe(cfg, p, x.transpose(1, 0, 2))
    return y.transpose(1, 0, 2), aux
