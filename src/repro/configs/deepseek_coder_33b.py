"""deepseek-coder-33b — dense llama-arch [arXiv:2401.14196].
62L, d_model 7168, 56 heads (GQA kv=8), d_ff 19200, vocab 32256."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    source="arXiv:2401.14196",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    seq_shard_attn=True,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=100000.0,
)
