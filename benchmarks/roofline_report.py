"""§Roofline report: reads the dry-run records (experiments/dryrun/*.json)
and emits the per-(arch x shape x mesh) roofline table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row


def run(dryrun_dir: str = "experiments/dryrun") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(path))
        t = r.get("roofline")
        if not t:
            continue
        bound = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        frac = t["t_compute_s"] / bound if bound else 0.0
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            1e6 * bound,
            f"dominant={t['dominant']};"
            f"t_compute_ms={1e3 * t['t_compute_s']:.2f};"
            f"t_memory_ms={1e3 * t['t_memory_s']:.2f};"
            f"t_collective_ms={1e3 * t['t_collective_s']:.2f};"
            f"roofline_frac={frac:.3f};"
            f"useful_ratio={t['useful_ratio']:.2f};"
            f"per_chip_gb={r['memory']['per_chip_gb']}"))
    if not rows:
        rows.append(csv_row("roofline/missing", 0.0,
                            "run repro.launch.dryrun first"))
    return rows
