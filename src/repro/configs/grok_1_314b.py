"""grok-1-314b — MoE, 8 experts top-2 [hf:xai-org/grok-1].
64L, d_model 6144, 48 heads (GQA kv=8), d_ff 32768 per expert, vocab 131072.
Attention logit soft-cap 30 (grok-1 model card)."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    source="hf:xai-org/grok-1",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    pattern=("attn_moe",),
    mlp_kind="gelu",
    num_experts=8,
    experts_per_token=2,
    attn_logit_softcap=30.0,
    final_logit_softcap=30.0,
    rope_theta=10000.0,
)
