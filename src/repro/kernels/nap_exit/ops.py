"""jit'd wrapper for the fused NAP exit decision."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.nap_exit.kernel import FB, NB, nap_exit


def exit_decision(x, x_inf, active_nodes, t_s, *, interpret: bool = True):
    """Convenience wrapper on unpadded inputs.
    x, x_inf (n, f); active_nodes (n,) bool. Returns (dist (n,), exit (n,)
    bool, blk_active (n_blocks,) int32) on the padded grid."""
    n, f = x.shape
    n_pad = -(-n // NB) * NB
    f_pad = -(-f // FB) * FB
    xp = jnp.zeros((n_pad, f_pad), x.dtype).at[:n, :f].set(x)
    ip = jnp.zeros((n_pad, f_pad), x.dtype).at[:n, :f].set(x_inf)
    ap = jnp.zeros((n_pad, 1), jnp.int32).at[:n, 0].set(
        active_nodes.astype(jnp.int32))
    dist2, exits, blk = nap_exit(xp, ip, ap, t_s, interpret=interpret)
    return jnp.sqrt(dist2[:n, 0]), exits[:n, 0] != 0, blk[:, 0]
