from repro.gnn.graph import Graph, propagated_series, stationary_weights
from repro.gnn.backends import (BACKENDS, PropagationBackend, get_backend,
                                register_backend, run_propagation)
from repro.gnn.datasets import load_dataset, PRESETS
from repro.gnn.models import GNNConfig, apply_classifier, init_classifiers
from repro.gnn.distill import DistillConfig, train_nai, evaluate_classifier
from repro.gnn.nai import (NAIConfig, NAIResult, accuracy, infer_all,
                           make_compiled_infer, order_distribution)
from repro.gnn.packing import (PackedSupport, batch_bucket, next_bucket,
                               pack_support, shard_batch_perm,
                               shard_row_perm, step_active_blocks)
from repro.gnn.sampler import Support, sample_support
from repro.gnn.store import (GraphStore, InMemoryStore, MmapStore,
                             as_store, make_graph, save_graph_store)

__all__ = [
    "Graph", "propagated_series", "stationary_weights", "BACKENDS",
    "PropagationBackend", "get_backend", "register_backend",
    "run_propagation", "load_dataset",
    "PRESETS", "GNNConfig", "apply_classifier", "init_classifiers",
    "DistillConfig", "train_nai", "evaluate_classifier", "NAIConfig",
    "NAIResult", "accuracy", "infer_all", "make_compiled_infer",
    "order_distribution", "PackedSupport", "batch_bucket", "next_bucket",
    "pack_support", "shard_batch_perm", "shard_row_perm",
    "step_active_blocks", "Support", "sample_support",
    "GraphStore", "InMemoryStore", "MmapStore", "as_store",
    "make_graph", "save_graph_store",
]
