"""Architecture registry + reduced smoke variants + input_specs.

`get_config(arch_id)` resolves the exact assigned config; `smoke(cfg)`
returns the reduced same-family variant used by CPU smoke tests (2-ish
layers, d_model <= 512, <= 4 experts)."""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.common import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.sharding import spec as logical_spec

from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL
from repro.configs.gemma_7b import CONFIG as GEMMA_7B
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from repro.configs.mistral_large_123b import CONFIG as MISTRAL_LARGE_123B
from repro.configs.grok_1_314b import CONFIG as GROK_1_314B
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.llama32_vision_11b import CONFIG as LLAMA32_VISION_11B

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        GRANITE_34B, DEEPSEEK_CODER_33B, WHISPER_SMALL, GEMMA_7B,
        RECURRENTGEMMA_9B, MISTRAL_LARGE_123B, GROK_1_314B, RWKV6_3B,
        DBRX_132B, LLAMA32_VISION_11B,
    ]
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: identical pattern/kinds, tiny dims."""
    n_body = len(cfg.pattern)            # one pattern repeat
    kw = dict(
        num_layers=n_body + len(cfg.remainder),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        rnn_width=128 if cfg.rnn_width else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16,
        num_image_tokens=8 if cfg.num_image_tokens else 0,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        dtype="float32",
        param_dtype="float32",
        long_context_window=64,
    )
    if cfg.pattern == ("rwkv",):
        kw.update(num_heads=2, num_kv_heads=2, rwkv_head_dim=64)
    return dataclasses.replace(cfg, **kw)


# --------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape).
    No device allocation; shardable by the dry-run."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    specs: dict = {}
    if shape.mode in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), tok)
    else:  # decode: one new token
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), tok)
    if cfg.is_encdec:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.num_image_tokens:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def input_shardings(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    out = {"tokens": logical_spec("batch", None)}
    if cfg.is_encdec or cfg.num_image_tokens:
        out["frontend"] = logical_spec("batch", None, "embed")
    return out
