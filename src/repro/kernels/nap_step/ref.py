"""Pure-jnp oracle for the fused NAP step kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.spmm.kernel import RB
from repro.kernels.spmm.ref import ref_spmm_tiles


def ref_nap_step(tiles, tile_col, valid, active, x, c_inf, s_inf,
                 node_active, ts2):
    """Two-op reference: predicated tile SpMM, then the exit decision on
    the batch region against the rank-1 stationary state c ⊗ s. Mirrors
    nap_step_fused's outputs exactly."""
    out = ref_spmm_tiles(tiles, tile_col, valid, active, x)
    x_inf = (c_inf.reshape(-1, 1) * s_inf.reshape(1, -1)).astype(x.dtype)
    nb = x_inf.shape[0]
    diff = (out[:nb] - x_inf).astype(jnp.float32)
    dist2 = jnp.sum(diff * diff, axis=1, keepdims=True)
    was_active = node_active != 0
    exits = was_active & (dist2 < jnp.asarray(ts2, jnp.float32).reshape(1))
    still = was_active & ~exits
    n_rb = tile_col.shape[0]
    blk = jnp.zeros((n_rb, 1), jnp.int32).at[:nb // RB, 0].set(
        still.reshape(-1, RB).any(axis=1).astype(jnp.int32))
    return out, exits.astype(jnp.int32), blk
