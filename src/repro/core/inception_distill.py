"""Inception Distillation (paper §3.2), generic over 'multi-exit' models.

Primitives implement Eqs. (2)-(6) of the paper:
  * soft-CE knowledge distillation at temperature T        (Eq. 3)
  * offline loss  (1-λ)·CE + λ·T²·KD(student, teacher)     (Eq. 4)
  * self-attention ensemble teacher over the top-r exits   (Eq. 5)
  * online loss   (1-λ)·CE + λ·T²·KD(student, ensemble)    (Eq. 6)

Used by `repro.gnn.distill` (the faithful GNN reproduction: one classifier
per propagation order) and by `repro.models.decoder_lm` (the generalized
transformer early-exit heads).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def soft_ce(student_logits, teacher_logits, temperature: float):
    """KD loss: CE(softmax(t/T), log softmax(s/T)); mean over rows. (Eq. 3)"""
    t = jax.nn.softmax(teacher_logits.astype(jnp.float32) / temperature, -1)
    ls = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temperature, -1)
    return -jnp.mean(jnp.sum(t * ls, axis=-1))


def hard_ce(logits, labels, mask=None):
    lf = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    m = mask.astype(jnp.float32)
    return -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)


def offline_loss(student_logits, teacher_logits, labels, *, temperature, lam,
                 label_mask=None):
    """(Eq. 4). Teacher is stop-gradiented (pure offline distillation)."""
    kd = soft_ce(student_logits, jax.lax.stop_gradient(teacher_logits),
                 temperature)
    ce = hard_ce(student_logits, labels, label_mask)
    return (1.0 - lam) * ce + lam * temperature**2 * kd


def ensemble_teacher(exit_logits: Sequence[jax.Array], s: jax.Array):
    """Self-attention ensemble over exit predictions (Eq. 5).

    exit_logits: list of (N, C) logits (the top-r classifiers).
    s: (C, 1) learned projection.
    Returns ensemble logits z̄ (N, C) — to be temperature-softmaxed by Eq. 6.
    """
    probs = [jax.nn.softmax(z.astype(jnp.float32), -1) for z in exit_logits]
    scores = [jax.nn.relu(p @ s.astype(jnp.float32))[..., 0] for p in probs]
    m = jnp.stack(scores, axis=-1)                       # (N, r)
    w = jax.nn.softmax(m, axis=-1)                       # (N, r)
    stacked = jnp.stack(probs, axis=-1)                  # (N, C, r)
    mix = jnp.einsum("ncr,nr->nc", stacked, w)
    return jnp.log(mix + 1e-9)                           # back to logit space


def online_loss(student_logits, ens_logits, labels, *, temperature, lam,
                label_mask=None):
    """(Eq. 6). Ensemble teacher is NOT stop-gradiented — teacher and
    students update simultaneously, per the paper."""
    kd = soft_ce(student_logits, ens_logits, temperature)
    ce = hard_ce(student_logits, labels, label_mask)
    return (1.0 - lam) * ce + lam * temperature**2 * kd


# ------------------------------------------------------- transformer flavor
def transformer_inception_loss(cfg, params, states, final_logits, labels):
    """Generalized ID for early-exit LM heads.

    states: (R, B, S, d) per-block hidden states from the trunk scan.
    final_logits: (B, S, V) trunk output.  labels: (B, S-1)."""
    from repro.models.decoder_lm import exit_logits as head

    ad = cfg.adaptive
    exits = []
    for i, blk in enumerate(ad.exit_layers):
        z = head(cfg, params, states[blk][:, :-1], i)
        exits.append(z.reshape(-1, z.shape[-1]))
    teacher = final_logits[:, :-1].reshape(-1, final_logits.shape[-1])
    flat_labels = labels.reshape(-1)

    total = jnp.zeros((), jnp.float32)
    metrics = {}
    for i, z in enumerate(exits):
        total += offline_loss(z, teacher, flat_labels,
                              temperature=ad.temperature, lam=ad.lam)
    # online: ensemble over top-r heads (final + deepest exits)
    pool = (exits + [teacher])[-max(ad.ensemble_r, 1):]
    ens = ensemble_teacher(pool, params["exits"]["ens_s"])
    for i, z in enumerate(exits):
        total += online_loss(z, ens, flat_labels,
                             temperature=ad.temperature, lam=ad.lam)
    total = total / max(len(exits), 1)
    metrics["inception_loss"] = total
    return total, metrics
