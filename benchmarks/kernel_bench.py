"""Kernel micro-benchmarks: interpret-mode timings are NOT TPU performance
(CPU emulation); the derived columns report the structural quantities that
matter on TPU — tiles touched vs skipped (NAP predication saving), VMEM
working set per BlockSpec, and arithmetic intensity."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels.spmm import (CB, FB, RB, active_blocks_from_nodes,
                                build_block_ell, pad_features, spmm)


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    n, deg, f = 1024, 8, 256
    E = n * deg
    src = rng.integers(0, n, E).astype(np.int32)
    dst = rng.integers(0, n, E).astype(np.int32)
    src = np.concatenate([src, np.arange(n, dtype=np.int32)])
    dst = np.concatenate([dst, np.arange(n, dtype=np.int32)])
    coef = rng.random(len(src)).astype(np.float32)
    ell = build_block_ell(src, dst, coef, n)
    x = jnp.asarray(pad_features(rng.standard_normal((n, f)), ell.n_pad))
    n_rb = ell.tile_col.shape[0]

    for frac in (1.0, 0.5, 0.1):
        active = jnp.asarray((rng.random(n_rb) < frac).astype(np.int32))
        t0 = time.perf_counter()
        out = spmm(ell, x, active, interpret=True)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        tiles_total = int(ell.valid.sum())
        tiles_live = int(ell.valid[np.asarray(active) != 0].sum())
        vmem_kb = (RB * CB + CB * FB + RB * FB) * 4 / 1024
        ai = (2 * RB * CB * FB) / ((RB * CB + CB * FB + RB * FB) * 4)
        rows.append(csv_row(
            f"kernels/spmm/active={frac}", 1e6 * dt,
            f"tiles_live={tiles_live}/{tiles_total};"
            f"predicated_saving={1 - tiles_live / tiles_total:.2f};"
            f"vmem_per_step_kb={vmem_kb:.0f};arith_intensity={ai:.1f}"))
    return rows
