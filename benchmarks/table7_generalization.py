"""Table 7: generalization — NAI deployed on S2GC / SIGN / GAMLP (Flickr)."""
from __future__ import annotations

from benchmarks.common import csv_row, dataset, grid_search_ts, trained
from repro.gnn import NAIConfig, accuracy, infer_all
from repro.gnn.baselines import run_glnn, run_quantized, run_vanilla

BASE_MODELS = ["s2gc", "sign", "gamlp"]


def run(name: str = "flickr-like") -> list:
    rows = []
    g = dataset(name)
    for bm in BASE_MODELS:
        cfg, params, _ = trained(name, bm)
        n = len(g.test_idx)
        van = run_vanilla(cfg, g, params)
        glnn = run_glnn(cfg, g, params["cls"][cfg.k], epochs=150)
        quant = run_quantized(cfg, g, params)
        ts = grid_search_ts(name, bm)[3]
        nai = infer_all(cfg, NAIConfig(t_s=ts, t_min=1, t_max=2,
                                       batch_size=500), params, g)
        rows += [
            csv_row(f"table7/{bm}/vanilla", 1e6 * van.time_s / n,
                    f"acc={van.acc:.4f};macs={van.macs:.0f}"),
            csv_row(f"table7/{bm}/GLNN", 1e6 * glnn.time_s / n,
                    f"acc={glnn.acc:.4f};macs={glnn.macs:.0f}"),
            csv_row(f"table7/{bm}/Quantization", 1e6 * quant.time_s / n,
                    f"acc={quant.acc:.4f};macs={quant.macs:.0f}"),
            csv_row(f"table7/{bm}/NAI", 1e6 * nai.wall_time_s / n,
                    f"acc={accuracy(nai, g):.4f};macs={nai.total_macs:.0f};"
                    f"time_speedup={van.time_s / max(nai.wall_time_s, 1e-9):.1f}x"),
        ]
    return rows
