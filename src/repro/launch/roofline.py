"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs  / (chips * peak_FLOP/s)
    memory term     = HLO_bytes  / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

cost_analysis() supplies FLOPs and bytes; collective bytes are parsed from
the HLO text (operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops)."""
from __future__ import annotations

import re
from typing import Dict

from repro.common import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind. Shapes in the HLO are
    per-participant (already sharded), i.e. bytes moved per device."""
    out = {k: 0 for k in _COLLECTIVES}
    seen_start = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
    return out


def roofline_terms(cost: dict, hlo_text: str, *, chips: int,
                   hw=TPU_V5E) -> Dict[str, float]:
    """Three-term roofline from the compiled HLO.

    Primary source is the loop-aware HLO analysis (repro.launch.hlo_analysis)
    because XLA's cost_analysis() counts while bodies once and reports
    per-device numbers — fatal for scan-based trunks. All analyzed
    quantities are PER-DEVICE; `hlo_flops` is reported as the global sum
    (x chips) for comparability with MODEL_FLOPS."""
    from repro.launch.hlo_analysis import analyze
    st = analyze(hlo_text)

    flops_dev = st.dot_flops
    hbm_dev = st.traffic_bytes
    coll_dev = st.collective_total

    t_compute = flops_dev / hw.peak_flops
    t_memory = hbm_dev / hw.hbm_bw
    t_collective = coll_dev / hw.ici_bw
    dominant = max(
        [("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)], key=lambda kv: kv[1])[0]
    return {
        "hlo_flops": flops_dev * chips,
        "hlo_flops_per_chip": flops_dev,
        "hlo_bytes_per_chip": hbm_dev,
        "collective_bytes_per_chip": float(coll_dev),
        "collectives": {k: float(v) for k, v in st.collective_bytes.items()},
        "xla_cost_flops_per_chip_loopless": float(cost.get("flops", 0.0)),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


def model_flops(cfg, shape, n_params: int, n_active_params: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (forward-only), N = active
    params, D = tokens processed this step."""
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active_params * tokens


def active_params(cfg, n_params: int) -> int:
    """Subtract the non-routed share of MoE expert weights."""
    if not cfg.num_experts:
        return n_params
    per_expert = cfg.d_model * cfg.d_ff * (3 if cfg.mlp_kind in
                                           ("swiglu", "geglu") else 2)
    moe_total = cfg.num_layers * cfg.num_experts * per_expert
    moe_active = cfg.num_layers * cfg.experts_per_token * per_expert
    return n_params - moe_total + moe_active
