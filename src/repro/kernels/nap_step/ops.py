"""Wrappers for the fused NAP step kernel.

`fused_step` is the convenience entry point (threshold given unsquared,
like `repro.kernels.nap_exit.exit_decision`). `two_launch_step` is the
reference composition this kernel fuses — `spmm_block_ell` followed by
`nap_exit` — with identical outputs, kept for parity tests and the
benchmark's side-by-side latency comparison. Both take the stationary
state as its rank-1 factors (c_inf, s_inf); the unfused path has to
materialize the dense x_inf = c ⊗ s to feed `nap_exit` (that is half of
what fusing saves), the fused kernel never does.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.nap_exit.kernel import nap_exit
from repro.kernels.nap_step.kernel import nap_step_fused
from repro.kernels.spmm.kernel import RB, spmm_block_ell


def fused_step(tiles, tile_col, valid, active, x, c_inf, s_inf,
               node_active, t_s, *, interpret: bool = True):
    """One fused propagation + exit step; `t_s` is the (unsquared) exit
    threshold. Returns (out, exit, blk_still)."""
    ts2 = jnp.asarray([t_s * t_s], jnp.float32)
    return nap_step_fused(tiles, tile_col, valid, active, x, c_inf, s_inf,
                          node_active, ts2, interpret=interpret)


def two_launch_step(tiles, tile_col, valid, active, x, c_inf, s_inf,
                    node_active, t_s, *, interpret: bool = True):
    """The unfused reference: SpMM kernel launch, propagated features round
    trip through HBM, dense stationary state materialized, then the
    exit-decision kernel launch over the batch region. Output contract
    matches `fused_step`."""
    x_inf = (c_inf.reshape(-1, 1) * s_inf.reshape(1, -1)).astype(x.dtype)
    nb = x_inf.shape[0]
    out = spmm_block_ell(tiles, tile_col, valid, active, x,
                         interpret=interpret)
    _, exits, blk_batch = nap_exit(out[:nb], x_inf,
                                   node_active.astype(jnp.int32), t_s,
                                   interpret=interpret)
    n_rb = tile_col.shape[0]
    blk = jnp.zeros((n_rb, 1), jnp.int32).at[:nb // RB].set(blk_batch)
    return out, exits, blk
