"""Kernel micro-benchmarks: interpret-mode timings are NOT TPU performance
(CPU emulation); the derived columns report the structural quantities that
matter on TPU — tiles touched vs skipped (NAP predication saving), VMEM
working set per BlockSpec, and arithmetic intensity."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.gnn import load_dataset
from repro.gnn.packing import pack_support, step_active_blocks
from repro.gnn.sampler import sample_support
from repro.kernels.spmm import (CB, FB, RB, active_blocks_from_nodes,
                                build_block_ell, pad_features, spmm,
                                spmm_block_ell)


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    n, deg, f = 1024, 8, 256
    E = n * deg
    src = rng.integers(0, n, E).astype(np.int32)
    dst = rng.integers(0, n, E).astype(np.int32)
    src = np.concatenate([src, np.arange(n, dtype=np.int32)])
    dst = np.concatenate([dst, np.arange(n, dtype=np.int32)])
    coef = rng.random(len(src)).astype(np.float32)
    ell = build_block_ell(src, dst, coef, n)
    x = jnp.asarray(pad_features(rng.standard_normal((n, f)), ell.n_pad))
    n_rb = ell.tile_col.shape[0]

    for frac in (1.0, 0.5, 0.1):
        active = jnp.asarray((rng.random(n_rb) < frac).astype(np.int32))
        t0 = time.perf_counter()
        out = spmm(ell, x, active, interpret=True)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        tiles_total = int(ell.valid.sum())
        tiles_live = int(ell.valid[np.asarray(active) != 0].sum())
        vmem_kb = (RB * CB + CB * FB + RB * FB) * 4 / 1024
        ai = (2 * RB * CB * FB) / ((RB * CB + CB * FB + RB * FB) * 4)
        rows.append(csv_row(
            f"kernels/spmm/active={frac}", 1e6 * dt,
            f"tiles_live={tiles_live}/{tiles_total};"
            f"predicated_saving={1 - tiles_live / tiles_total:.2f};"
            f"vmem_per_step_kb={vmem_kb:.0f};arith_intensity={ai:.1f}"))

    # ---- end-to-end serving operand: vectorized sample -> bucket-padded
    # pack -> kernel with the per-step hop mask (what the compiled engine
    # actually runs). Features sliced to one FB block so interpret mode
    # stays a micro-benchmark.
    g = load_dataset("pubmed-like", scale=0.02, seed=0)
    batch = rng.choice(g.test_idx, size=32, replace=False)
    t_max = 2
    t0 = time.perf_counter()
    sup = sample_support(g, batch, t_max, 0.5)
    sample_us = 1e6 * (time.perf_counter() - t0)
    x0 = g.features[sup.nodes][:, :FB].astype(np.float32)
    t0 = time.perf_counter()
    packed = pack_support(sup, x0,
                          np.zeros((sup.n_batch, FB), np.float32))
    pack_us = 1e6 * (time.perf_counter() - t0)
    step_act = step_active_blocks(packed.hop_rb, t_max)
    tiles_total = int(packed.valid.sum())
    rows.append(csv_row(
        "kernels/spmm_support/pack", pack_us,
        f"S={packed.s_real};n_pad={packed.n_pad};"
        f"tb={packed.tiles.shape[1]};density={packed.density:.2f};"
        f"row_overshoot={packed.n_pad / max(packed.s_real, 1):.2f};"
        f"sample_us={sample_us:.0f}"))
    x = jnp.asarray(packed.x0)
    for l in range(1, t_max + 1):
        active = jnp.asarray(step_act[l - 1])
        t0 = time.perf_counter()
        x = spmm_block_ell(jnp.asarray(packed.tiles),
                           jnp.asarray(packed.tile_col),
                           jnp.asarray(packed.valid), active, x,
                           interpret=True)
        x.block_until_ready()
        dt = time.perf_counter() - t0
        live = int(packed.valid[np.asarray(step_act[l - 1]) != 0].sum())
        rows.append(csv_row(
            f"kernels/spmm_support/step={l}", 1e6 * dt,
            f"tiles_live={live}/{tiles_total};"
            f"hop_mask_saving={1 - live / max(tiles_total, 1):.2f}"))
    return rows
