"""Node-Adaptive Inference — Algorithm 1 of the paper.

Two execution paths:

* `infer_batch_host` — the faithful serving path. Real frontier shrinking:
  exited nodes drop out of the supporting set, later propagation steps touch
  fewer edges, and MAC counters track exactly the paper's four procedures
  (stationary state, feature propagation, distance computation,
  classification).

* `infer_batch_masked` — the compiled TPU path. Static shapes, a
  `lax.fori_loop` over orders with per-node active masks; compute saving is
  realized at tile granularity by the Pallas SpMM kernel's block
  predication (repro.kernels.spmm). Numerics match the host path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.backends import get_backend, normalize_mesh, run_propagation
from repro.gnn.graph import Graph
from repro.gnn.models import (GNNConfig, apply_classifier,
                              classification_macs)
from repro.gnn.packing import shard_batch_perm
from repro.gnn.sampler import Support, sample_support
from repro.gnn.store import as_store


@dataclasses.dataclass(frozen=True)
class NAIConfig:
    t_s: float = 0.1        # smoothness threshold T_s
    t_min: int = 1          # minimum propagation order
    t_max: int = 2          # maximum propagation order (<= k)
    batch_size: int = 500   # paper evaluates with batch 500

    def __post_init__(self):
        """Fail loudly on configs that would serve garbage silently:
        t_min > t_max makes `infer_batch_host` return all-(-1)
        predictions with exit order 0 and no error. The serving
        front-end's SLO classes construct these configs programmatically
        (`dataclasses.replace` re-runs this check), so a bad tier
        definition must raise at construction, not at serve time."""
        if self.t_min < 1:
            raise ValueError(f"t_min must be >= 1, got {self.t_min}")
        if self.t_min > self.t_max:
            raise ValueError(
                f"t_min ({self.t_min}) > t_max ({self.t_max}): no "
                f"propagation order would ever classify, every "
                f"prediction would be -1")
        if self.t_s < 0:
            raise ValueError(f"t_s must be >= 0, got {self.t_s}")
        if self.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1, got {self.batch_size}")


@dataclasses.dataclass
class NAIResult:
    predictions: np.ndarray      # (n_test,) argmax class
    orders: np.ndarray           # (n_test,) exit order per node (Table 4)
    macs: Dict[str, float]       # per-node averaged MACs by procedure
    fp_macs: float               # feature-processing MACs per node
    total_macs: float
    wall_time_s: float
    fp_time_s: float


def _subgraph_spmm(sup: Support, x: np.ndarray, active_nodes: np.ndarray
                   ) -> Tuple[np.ndarray, int]:
    """One propagation step restricted to edges whose destination is in
    `active_nodes` (bool mask over support). Returns (new_x, edges_used)."""
    emask = active_nodes[sup.dst]
    src, dst, coef = sup.src[emask], sup.dst[emask], sup.coef[emask]
    out = x.copy()
    acc = np.zeros_like(x)
    np.add.at(acc, dst, coef[:, None] * x[src])
    out[active_nodes] = acc[active_nodes]
    return out, int(emask.sum())


def support_stationary_factors(g, sup: Support, x0: np.ndarray,
                               r: float) -> Tuple[np.ndarray, np.ndarray]:
    """The stationary state Â^∞ X at the batch rows (Eq. 7) is rank-1 by
    construction; return its factors (c (n_batch,), s (f,)) in float64 so
    x_inf = c ⊗ s. The fused step kernel consumes the factors directly
    (it never materializes the dense x_inf). `g` is a `GraphStore` (or a
    raw `Graph`, wrapped) — degrees come from the store-build metadata,
    gathered at the support rows only."""
    store = as_store(g)
    dt = (np.asarray(store.degrees[sup.nodes]) + 1).astype(np.float64)
    denom = 2.0 * sup.sub_edges + len(sup)
    s = ((dt ** (1.0 - r))[:, None] * x0).sum(axis=0)
    c = (dt[:sup.n_batch] ** r) / denom
    return c, s


def support_stationary_state(g, sup: Support, x0: np.ndarray,
                             r: float) -> np.ndarray:
    """Rank-1 stationary state Â^∞ X at the batch rows (Eq. 7) over the
    sampled subgraph, float64. Shared by the host and compiled serving
    paths so their exit distances use the same arithmetic (the compiled
    path then casts to float32; nodes within f32 rounding of T_s may
    exit one order apart across paths)."""
    c, s = support_stationary_factors(g, sup, x0, r)
    return c[:, None] * s[None, :]


def _needed_mask(sup: Support, active_batch: np.ndarray, remaining_hops: int
                 ) -> np.ndarray:
    """Support nodes within `remaining_hops` of any active batch node —
    the only values the next propagation step must produce."""
    S = len(sup)
    dist = np.full(S, np.iinfo(np.int32).max, np.int32)
    dist[:sup.n_batch][active_batch] = 0
    in_frontier = np.zeros(S, bool)
    in_frontier[:sup.n_batch][active_batch] = True
    # reverse BFS over subgraph edges (dst -> src one hop per level); the
    # per-hop edge filter is an O(E) boolean gather over support ids, not
    # an np.isin merge-scan against the frontier list
    for h in range(1, remaining_hops + 1):
        if not in_frontier.any():
            break
        cand = sup.src[in_frontier[sup.dst]]
        new = cand[dist[cand] > h]
        dist[new] = h
        in_frontier[:] = False
        in_frontier[new] = True
    return dist <= remaining_hops


def infer_batch_host(cfg: GNNConfig, nai: NAIConfig, params, g,
                     batch_nodes: np.ndarray):
    """Algorithm 1 for one batch over a `GraphStore` (or raw `Graph`).
    Returns (preds, orders, macs, fp_time_s, wall_s)."""
    store = as_store(g)
    f = store.feat_dim
    t0 = time.perf_counter()
    sup = sample_support(store, batch_nodes, nai.t_max, cfg.r)
    nb = sup.n_batch
    x = store.gather_features(sup.nodes).astype(np.float32)
    macs = {"stationary": 0.0, "propagation": 0.0, "distance": 0.0,
            "classification": 0.0}

    # line 2: stationary state over the sampled subgraph (Eq. 7, rank-1)
    x_inf = support_stationary_state(g, sup, x, cfg.r)
    macs["stationary"] += len(sup) * f + nb * f

    preds = np.full(nb, -1, np.int64)
    orders = np.zeros(nb, np.int64)
    active = np.ones(nb, bool)
    fp_t0 = time.perf_counter()
    fp_elapsed = 0.0

    series = [x]                                           # X^(0..l) at support
    for l in range(1, nai.t_max + 1):
        t_fp = time.perf_counter()
        needed = _needed_mask(sup, active, nai.t_max - l)
        x, edges = _subgraph_spmm(sup, series[-1], needed)
        series.append(x)
        macs["propagation"] += edges * f
        fp_elapsed += time.perf_counter() - t_fp

        if l < nai.t_min:
            continue
        exit_now = np.zeros(nb, bool)
        if l < nai.t_max:
            t_fp = time.perf_counter()
            d = np.linalg.norm(x[:nb][active] - x_inf[active], axis=1)
            macs["distance"] += active.sum() * f
            fp_elapsed += time.perf_counter() - t_fp
            idx = np.flatnonzero(active)
            exit_now[idx[d < nai.t_s]] = True
        else:
            exit_now = active.copy()
        if exit_now.any():
            feats_l = np.stack([s[:nb][exit_now] for s in series])  # (l+1,e,f)
            z = apply_classifier(cfg, params["cls"][l], jnp.asarray(feats_l), l)
            preds[exit_now] = np.asarray(jnp.argmax(z, -1))
            orders[exit_now] = l
            macs["classification"] += exit_now.sum() * classification_macs(cfg, l)
            active &= ~exit_now
        if not active.any():
            break
    wall = time.perf_counter() - t0
    macs = {k: v / nb for k, v in macs.items()}
    return preds, orders, macs, fp_elapsed, wall


def infer_all(cfg: GNNConfig, nai: NAIConfig, params, g: Graph,
              nodes: Optional[np.ndarray] = None) -> NAIResult:
    nodes = g.test_idx if nodes is None else nodes
    preds = np.empty(len(nodes), np.int64)
    orders = np.empty(len(nodes), np.int64)
    macs_sum: Dict[str, float] = {}
    fp_time = 0.0
    wall = 0.0
    for i in range(0, len(nodes), nai.batch_size):
        b = nodes[i:i + nai.batch_size]
        p, o, m, fp, w = infer_batch_host(cfg, nai, params, g, b)
        preds[i:i + len(b)] = p
        orders[i:i + len(b)] = o
        for k, v in m.items():
            macs_sum[k] = macs_sum.get(k, 0.0) + v * len(b)
        fp_time += fp
        wall += w
    n = len(nodes)
    macs = {k: v / n for k, v in macs_sum.items()}
    fp_macs = macs["propagation"] + macs["distance"]
    return NAIResult(
        predictions=preds, orders=orders, macs=macs, fp_macs=fp_macs,
        total_macs=sum(macs.values()), wall_time_s=wall, fp_time_s=fp_time)


def accuracy(result: NAIResult, g: Graph,
             nodes: Optional[np.ndarray] = None) -> float:
    nodes = g.test_idx if nodes is None else nodes
    return float((result.predictions == g.labels[nodes]).mean())


def order_distribution(result: NAIResult, k: int) -> np.ndarray:
    """Node count per exit order 1..k (paper Table 4)."""
    return np.bincount(result.orders, minlength=k + 1)[1:k + 1]


# --------------------------------------------------------------- jax masked
def infer_batch_masked(cfg: GNNConfig, nai: NAIConfig, params,
                       sup_src, sup_dst, sup_coef, x0, x_inf, n_batch: int,
                       *, spmm_impl: str = "segment", ell=None,
                       step_active=None, x_inf_factors=None,
                       interpret: bool = True, mesh=None,
                       halo_operands=None, gather_mode: str = "dense"):
    """Compiled NAP: fori over orders with exit masks (static shapes).

    Returns (exit_order (nb,), stacked BATCH-ROW features
    (T_max+1, n_batch, f)). The propagation state stays (S, f) inside the
    loop — every support row keeps propagating — but the per-step history
    written to the carry holds only the batch region: classification
    (`make_compiled_infer`) never reads support rows, and with T_max-hop
    supports S is routinely 10–50× n_batch, so carrying S rows per step
    was almost entirely dead HBM traffic.

    This is a thin compatibility wrapper over the `PropagationBackend`
    registry (`repro.gnn.backends`): `spmm_impl` names a registered
    backend — ``segment`` (jnp segment-sum over sup_src/sup_dst/sup_coef),
    ``block_ell`` (Pallas block-ELL kernel over ``ell=(tiles, tile_col,
    valid)`` + the static `step_active` row-block predicate from
    `repro.gnn.packing.step_active_blocks`), or ``fused`` (one-kernel
    SpMM + exit decision, streaming `x_inf_factors=(c, s)` instead of the
    dense x_inf) — and the shared masked loop in
    `repro.gnn.backends.run_propagation` drives its ``step``. Exit
    arithmetic (squared f32 distance vs squared threshold, negative
    threshold = gated off) is identical across backends, so exit orders
    stay bit-consistent even for distances at the threshold.

    `mesh` (a mesh with a ``data`` axis, operands packed with
    ``pack_support(n_shards=D)``) runs the same loop under shard_map;
    results come back in the packed shard-major batch order (undo with
    `repro.gnn.packing.shard_batch_perm`). `gather_mode` selects the
    sharded per-step frontier exchange (``dense`` all_gather, or the
    ``halo``/``alltoall`` frame exchange — those need `halo_operands`,
    the ``halo_*`` metadata dict from a ``pack_support(halo=True)``
    pack; see `repro.gnn.backends`).

    Per-order classification lives in `make_compiled_infer`, which wraps
    this core in one jitted function.
    """
    backend = get_backend(spmm_impl)
    ops = dict(halo_operands or {})
    if backend.uses_tiles:
        if ell is None:
            raise ValueError(f"{spmm_impl} path needs ell="
                             f"(tiles, tile_col, valid)")
        ops["tiles"], ops["tile_col"], ops["valid"] = ell
        ops["step_active"] = jnp.asarray(step_active, jnp.int32)
    if backend.uses_edges:
        ops["src"], ops["dst"], ops["coef"] = sup_src, sup_dst, sup_coef
    if backend.uses_factors:
        if x_inf_factors is None:
            raise ValueError("fused path needs x_inf_factors=(c, s), the "
                             "rank-1 stationary-state factors")
        ops["c_inf"] = jnp.asarray(x_inf_factors[0], x0.dtype)
        ops["s_inf"] = jnp.asarray(x_inf_factors[1], x0.dtype)
    if backend.uses_dense_x_inf:
        ops["x_inf"] = x_inf
    return run_propagation(backend, nai, ops, x0, n_batch,
                           interpret=interpret, mesh=mesh,
                           gather_mode=gather_mode)


def make_compiled_infer(cfg: GNNConfig, nai: NAIConfig, *,
                        spmm_impl: str = "block_ell",
                        interpret: bool = True,
                        donate: Optional[bool] = None,
                        mesh=None, gather_mode: str = "dense",
                        return_series: bool = False):
    """One jitted function: masked NAP propagation + per-order
    classification (unrolled over orders, selected by exit mask).

    The returned callable takes ``(cls_params, operands, x0, x_inf)`` where
    `operands` is a dict — ``tiles/tile_col/valid/step_active`` for
    ``block_ell``, the same plus ``c_inf/s_inf`` (rank-1 stationary-state
    factors) for ``fused``, ``src/dst/coef`` for ``segment`` (see the
    backend's ``operand_logical`` keys in `repro.gnn.backends`, plus the
    ``halo_*`` metadata for halo gather modes) — and returns
    ``(predictions (nb,), exit_order (nb,))``. All shape specialization
    happens through jax.jit's cache; callers bucket their operand shapes
    (repro.gnn.packing) so repeat batches hit it. The number of traced
    shapes is exposed via the jitted function's ``_cache_size()``.

    `mesh` (any mesh with a ``data`` axis of size D > 1; operands must
    come from ``pack_support(..., n_shards=D)``) runs the propagation
    loop sharded under shard_map — each device owns its round-robin row
    superblocks, the per-step frontier exchange selected by
    `gather_mode` (``dense`` all_gather / ``halo`` static frame gather /
    ``alltoall`` ragged exchange; halo modes need a
    ``pack_support(halo=True)`` pack). Per-order classification ALSO
    runs inside the sharded region — each shard classifies its own batch
    rows and only the (nb,) argmax class ids and exit orders are
    gathered and un-permuted back to the original batch order, so the
    (T_max+1, nb, f) series and the (nb, C) logits are never
    replicated. Predictions are positionally identical to single-device
    serving.

    `donate` hands the per-batch operands (``operands``, ``x0``,
    ``x_inf`` — NOT the classifier params, which persist across batches)
    to XLA as donated buffers, so bucketed repeat batches overwrite the
    previous batch's HBM allocations instead of growing the footprint.
    Default (None) enables donation everywhere except the CPU backend,
    which does not implement donation and would warn per compile. The
    effective donated argnums are exposed as ``run._donate_argnums``.

    `return_series=True` makes the callable return ``(predictions,
    exit_order, series (T_max+1, nb, f))`` — the batch-row propagation
    history in ORIGINAL batch order, which the serving engine's
    propagated-feature cache fills from (steps 1..T_max of a batch row
    are exact global values, since batch rows always propagate at the
    full budget).
    """
    backend = get_backend(spmm_impl)
    tmax = nai.t_max
    mesh = normalize_mesh(mesh)
    n_shards = int(mesh.shape["data"]) if mesh is not None else 1
    if mesh is None:
        gather_mode = "dense"
    if donate is None:
        donate = jax.default_backend() != "cpu"
    donate_argnums = (1, 2, 3) if donate else ()

    def classify(cls_params, exit_order, series):
        """Per-order classification selected by exit mask — row-wise, so
        it runs unchanged on a shard's local batch rows or the full
        batch."""
        preds = jnp.zeros(exit_order.shape, jnp.int32)
        for l in range(1, tmax + 1):
            # series already carries batch rows only
            feats = series[:l + 1, :, :cfg.feat_dim]
            z = apply_classifier(cfg, cls_params[l], feats, l)
            preds = jnp.where(exit_order == l,
                              jnp.argmax(z, -1).astype(jnp.int32), preds)
        return preds

    @functools.partial(jax.jit, donate_argnums=donate_argnums)
    def run(cls_params, operands, x0, x_inf):
        nb = x_inf.shape[0]
        ops = dict(operands)
        if backend.uses_dense_x_inf:
            ops["x_inf"] = x_inf
        out = run_propagation(
            backend, nai, ops, x0, nb, interpret=interpret, mesh=mesh,
            gather_mode=gather_mode, classify=classify,
            cls_params=cls_params, return_series=return_series)
        if return_series:
            exit_order, preds, series = out
        else:
            (exit_order, preds), series = out, None
        if n_shards > 1:
            # shard-major packed order -> original batch order (a static
            # gather; shard_batch_perm[r] is where batch row r landed)
            unperm = shard_batch_perm(nb, n_shards)
            exit_order = exit_order[unperm]
            preds = preds[unperm]
            if series is not None:
                series = series[:, unperm, :]
        if return_series:
            return preds, exit_order, series
        return preds, exit_order

    run._donate_argnums = donate_argnums
    return run
