"""Pallas kernel demo: the compiled NAP inference path.

Runs the paper's inference loop with the block-ELL SpMM kernel (NAP row-
block predication) + the fused nap_exit kernel, on a synthetic graph batch,
and verifies it against the pure-numpy host path.

    PYTHONPATH=src python examples/kernels_demo.py

Set ``EXAMPLES_SMOKE=1`` for the scaled-down CI shape.
"""
import os

import jax.numpy as jnp
import numpy as np

from repro.gnn import GNNConfig, load_dataset
from repro.gnn.sampler import sample_support
from repro.gnn.store import InMemoryStore
from repro.kernels.nap_exit import exit_decision
from repro.kernels.spmm import (RB, active_blocks_from_nodes, build_block_ell,
                                pad_features, spmm)

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))

g = load_dataset("pubmed-like", scale=0.03 if SMOKE else 0.08, seed=0)
cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=4)
batch = g.test_idx[:64 if SMOKE else 256]
T_MIN, T_MAX, T_S = 1, 4, 16.0

# --- build the supporting subgraph + block-ELL operands (store-first:
# the sampler reads through the GraphStore row-gather API)
sup = sample_support(InMemoryStore(g), batch, T_MAX, cfg.r)
nb = sup.n_batch
ell = build_block_ell(sup.src, sup.dst, sup.coef, len(sup))
x = jnp.asarray(pad_features(g.features[sup.nodes], ell.n_pad))
print(f"support: {len(sup)} nodes -> {ell.n_pad} padded, "
      f"{ell.tiles.shape[0]}x{ell.tiles.shape[1]} tiles "
      f"(block density {ell.density:.2f})")

# stationary state (Eq. 7, rank-1 — never materializes Â^inf)
dt = (g.degrees[sup.nodes] + 1).astype(np.float64)
denom = 2.0 * sup.sub_edges + len(sup)
s_sum = ((dt ** (1 - cfg.r))[:, None] * g.features[sup.nodes]).sum(0)
x_inf_nb = jnp.asarray(((dt[:nb] ** cfg.r) / denom)[:, None] * s_sum[None, :])
x_inf = jnp.zeros((ell.n_pad, x.shape[1])).at[:nb, :g.features.shape[1]].set(
    x_inf_nb)

# --- compiled NAP loop: SpMM (predicated) + fused exit decision
# A support node must stay live at step l iff its BFS hop distance is
# within the remaining propagation budget of some still-active batch node;
# batch rows additionally go dead when the node exits. This is the
# block-level shrinking frontier of DESIGN.md §3.
active_batch = np.ones(nb, bool)
exit_order = np.zeros(nb, np.int64)
tiles_touched, tiles_possible = 0, 0
for l in range(1, T_MAX + 1):
    remaining = T_MAX - l
    needed = np.zeros(ell.n_pad, bool)
    needed[:len(sup)] = sup.hop <= remaining
    needed[:nb] |= active_batch          # batch rows live while active
    needed[:nb] &= active_batch | (sup.hop[:nb] <= remaining)
    live = active_blocks_from_nodes(jnp.asarray(needed), ell.n_pad)
    x = spmm(ell, x, live, interpret=True)
    tiles_possible += int(ell.valid.sum())
    tiles_touched += int(ell.valid[np.asarray(live) != 0].sum())
    if l < T_MIN or l == T_MAX:
        continue
    d, exits, _ = exit_decision(x[:nb], x_inf[:nb],
                                jnp.asarray(active_batch), T_S,
                                interpret=True)
    newly = np.asarray(exits) & (exit_order == 0)
    exit_order[newly] = l
    active_batch &= ~np.asarray(exits)
exit_order[exit_order == 0] = T_MAX

# --- verify against the host path
from repro.gnn.nai import _subgraph_spmm
xh = g.features[sup.nodes].astype(np.float32)
needed = np.ones(len(sup), bool)
for l in range(1, T_MAX + 1):
    xh, _ = _subgraph_spmm(sup, xh, needed)
err = float(np.abs(np.asarray(x)[:nb, :g.features.shape[1]] - xh[:nb]).max())
print(f"kernel-vs-host propagation max err @k={T_MAX}: {err:.2e}")
hist = np.bincount(exit_order, minlength=T_MAX + 1)[1:]
print(f"exit-order histogram (T_s={T_S}): {list(hist)}")
print(f"NOTE: with per-block exits the TPU saving appears once whole row "
      f"blocks exit; here {tiles_touched}/{tiles_possible} tiles touched.")
