"""End-to-end behaviour tests for the paper's system (deliverable c).

The core claim of the paper at reduced scale: NAI trades negligible accuracy
for a large reduction in feature-processing MACs vs the vanilla base model,
while baselines either lose accuracy (GLNN) or save nothing (quantization).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, accuracy,
                       infer_all, load_dataset, train_nai)
from repro.gnn.baselines import (run_glnn, run_quantized, run_tinygnn,
                                 run_vanilla)


@pytest.fixture(scope="module")
def pipeline():
    g = load_dataset("pubmed-like", scale=0.1, seed=0)
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=4,
                    hidden=48, mlp_layers=2, dropout=0.1)
    dc = DistillConfig(epochs_base=120, epochs_offline=60, epochs_online=60)
    params, info = train_nai(cfg, g, dc)
    return g, cfg, params


def test_nai_vs_vanilla_accuracy_and_macs(pipeline):
    g, cfg, params = pipeline
    vanilla = run_vanilla(cfg, g, params)
    nai = infer_all(cfg, NAIConfig(t_s=25.0, t_min=1, t_max=cfg.k,
                                   batch_size=500), params, g)
    acc = accuracy(nai, g)
    # paper Table 3: ACC drop bounded (<= ~2% at reduced scale)
    assert acc >= vanilla.acc - 0.02, (acc, vanilla.acc)
    # and FP MACs reduced substantially
    assert nai.fp_macs < vanilla.fp_macs, (nai.fp_macs, vanilla.fp_macs)


def test_baselines_run(pipeline):
    g, cfg, params = pipeline
    glnn = run_glnn(cfg, g, params["cls"][cfg.k], epochs=80)
    assert glnn.fp_macs == 0.0 and 0.0 <= glnn.acc <= 1.0
    tiny = run_tinygnn(cfg, g, params["cls"][cfg.k], epochs=80)
    assert tiny.fp_macs > 0.0
    quant = run_quantized(cfg, g, params)
    vanilla = run_vanilla(cfg, g, params)
    # quantization cannot reduce feature-processing cost (paper §4.2)
    assert quant.fp_macs == vanilla.fp_macs
    assert quant.acc >= vanilla.acc - 0.05


def test_nai_order_distribution_tracks_threshold(pipeline):
    g, cfg, params = pipeline
    from repro.gnn import order_distribution
    lo = infer_all(cfg, NAIConfig(t_s=8.0, t_min=1, t_max=4, batch_size=200),
                   params, g)
    hi = infer_all(cfg, NAIConfig(t_s=40.0, t_min=1, t_max=4, batch_size=200),
                   params, g)
    mean_lo = float(np.average(np.arange(1, 5), weights=order_distribution(lo, 4)))
    mean_hi = float(np.average(np.arange(1, 5), weights=order_distribution(hi, 4)))
    assert mean_hi <= mean_lo  # larger T_s -> earlier exits (paper §3.3)


def test_lm_training_loss_decreases():
    """The generalized substrate trains: 40 steps on the synthetic Markov
    stream reduce loss measurably."""
    from repro.common import TrainConfig
    from repro.configs import ARCHS, smoke
    from repro.data import synthetic_stream
    from repro.models import decoder_lm as M
    from repro.optim import adamw_init, adamw_update, make_schedule

    cfg = smoke(ARCHS["gemma-7b"])
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40,
                     schedule="cosine", weight_decay=0.01)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, tc)
    sched = make_schedule(tc)

    @jax.jit
    def step(params, opt, tokens):
        (loss, _), grads = jax.value_and_grad(M.loss_fn, argnums=1,
                                              has_aux=True)(
            cfg, params, {"tokens": tokens})
        params, opt, _ = adamw_update(grads, opt, params, tc,
                                      sched(opt["count"]))
        return params, opt, loss

    stream = synthetic_stream(0, 8, 64, cfg.vocab_size)
    losses = []
    for i in range(40):
        b = next(stream)
        params, opt, loss = step(params, opt, jnp.asarray(b["tokens"]))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[::8]
