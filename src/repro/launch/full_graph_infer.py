"""Checkpointed, preemption-tolerant full-graph offline NAI inference.

The serving stack answers per-batch queries; the paper's other product
surface is the InferTurbo-style batch job — classify EVERY node of a
`GraphStore` graph, at scales where one pass is a long-running job that
must survive preemption. This driver is that surface:

* the whole graph is viewed as its own support
  (`repro.gnn.distributed.graph_as_support`) and packed with
  `pack_support(n_shards=D)` — the same shard-major CB-superblock row
  partition, operands, and backends serving uses, with the REAL Eq. 7
  stationary state so Eq. 8 adaptive exits run (`pack_graph(
  stationary=True)`);
* propagation runs as **supersteps** — one jitted NAP step per
  dispatch (`repro.gnn.backends.make_superstep`, bit-identical
  arithmetic to the serving fori-loop body) with
  ``gather_mode="alltoall"`` exchanging only referenced CB blocks
  between shards each step;
* after every superstep the full propagation state (padded feature
  state + exit orders) is committed to a CRC32-checksummed, atomically
  updated checkpoint (`repro.launch.checkpoint.CheckpointManager`);
* a killed/preempted run resumes from the last complete superstep and
  produces **bit-identical** final predictions and exit orders — the
  parity contract tests/test_full_graph_infer.py and
  benchmarks/full_graph_infer_bench.py pin.

Failure model (composes with the PR-8 fault machinery):

* **crash / preemption at any instant** — the atomic manifest commit
  means the directory always names a complete superstep prefix; resume
  replays from the highest complete superstep k (work after k is
  re-done, never re-counted twice — supersteps are pure functions of
  the checkpointed state).
* **corrupt checkpoint** (bit rot, torn write) — CRC verification at
  load raises typed `CheckpointCorruption`; the driver falls back one
  superstep at a time until a verifiable chain 0..k loads (0 = cold
  start), counting the fallbacks in `stats`.
* **checkpoint write failure** — logged and tolerated: the run
  continues (the in-memory state is still good); a later crash simply
  resumes from an earlier superstep.
* **hang / straggler** — a per-superstep watchdog
  (`OfflineConfig.watchdog_s`) polls readiness with a deadline and
  deterministically retries the superstep (same inputs, same result);
  supersteps slower than `straggler_factor`× the median of previous
  steps are recorded as stragglers. The ``superstep_hang`` fault stage
  simulates a hung dispatch through the same retry path.

Deterministic by construction: supersteps are jitted pure functions,
checkpoint payloads round-trip bit-exactly, classifier params come
from a seeded init — so interrupted == uninterrupted is exact
equality, not a tolerance.

CLI (the bench and the CI smoke job drive this; set ``XLA_FLAGS=
--xla_force_host_platform_device_count=D`` in the environment for
multi-shard runs on CPU)::

    PYTHONPATH=src python -m repro.launch.full_graph_infer \\
        --store STORE_DIR --ckpt CKPT_DIR [--shards D] \\
        [--impl segment] [--gather alltoall] [--t-max 3] \\
        [--t-s 6.0 | --t-s-quantile 0.5] [--crash-after K]

Exit code 17 = simulated preemption (``--crash-after``): the run died
on purpose after committing superstep K; rerun the same command to
resume.
"""
from __future__ import annotations

import dataclasses
import json
import statistics
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding

from repro.gnn.backends import (make_superstep, normalize_mesh,
                                operand_logical, pack_operands)
from repro.gnn.distributed import pack_graph
from repro.gnn.models import apply_classifier
from repro.gnn.packing import shard_batch_perm, step_active_blocks
from repro.gnn.store import as_store
from repro.launch.checkpoint import (CheckpointCorruption, CheckpointError,
                                     CheckpointManager)
from repro.serving.faults import InjectedFault, WatchdogTimeout
from repro.sharding.logical import spec

EXIT_PREEMPTED = 17


class PreemptionSimulated(RuntimeError):
    """Raised by ``crash_after``: the run terminated itself right after
    committing that superstep's checkpoint — the deterministic stand-in
    for a SIGKILL the tests and the bench sweep over."""


@dataclasses.dataclass(frozen=True)
class OfflineConfig:
    """Driver knobs (the NAI/model config rides in separately)."""
    ckpt_dir: str
    spmm_impl: str = "segment"
    gather_mode: str = "alltoall"    # collapses to dense at D=1
    interpret: bool = True
    resume: bool = True
    watchdog_s: float = 0.0          # 0 = no per-superstep watchdog
    superstep_retries: int = 2
    straggler_factor: float = 4.0
    crash_after: Optional[int] = None
    cls_chunk: int = 8192            # classification rows per dispatch

    def __post_init__(self):
        if not self.ckpt_dir:
            raise ValueError("ckpt_dir is required: the checkpointed "
                             "driver has no checkpoint-free mode")
        if self.watchdog_s < 0:
            raise ValueError(f"watchdog_s must be >= 0, "
                             f"got {self.watchdog_s}")
        if self.superstep_retries < 0:
            raise ValueError(f"superstep_retries must be >= 0, "
                             f"got {self.superstep_retries}")
        if self.straggler_factor <= 1.0:
            raise ValueError(f"straggler_factor must be > 1, "
                             f"got {self.straggler_factor}")
        if self.cls_chunk < 1:
            raise ValueError(f"cls_chunk must be >= 1, "
                             f"got {self.cls_chunk}")
        if self.crash_after is not None and self.crash_after < 0:
            raise ValueError(f"crash_after must be >= 0, "
                             f"got {self.crash_after}")


@dataclasses.dataclass
class OfflineResult:
    predictions: np.ndarray   # (n,) int32 argmax class ids
    exit_orders: np.ndarray   # (n,) int32 in [t_min, t_max]
    stats: Dict


def first_step_distance_quantile(store, r: float, q: float = 0.5
                                 ) -> float:
    """A data-driven exit threshold: the `q` quantile of the Eq. 8
    distance ||X^(1) - X^inf|| over all nodes, computed with the same
    f32 arithmetic the compiled path uses. Deterministic for a given
    store, so a resumed CLI run recomputes the identical T_s."""
    from repro.gnn.distributed import graph_as_support
    from repro.gnn.nai import support_stationary_factors
    store = as_store(store)
    sup = graph_as_support(store, r)
    x0 = np.asarray(store.features, np.float32)
    c64, s64 = support_stationary_factors(store, sup, x0, r)
    x_inf = (c64[:, None] * s64[None, :]).astype(np.float32)
    contrib = sup.coef[:, None] * x0[sup.src]
    x1 = np.asarray(jax.ops.segment_sum(contrib, sup.dst,
                                        num_segments=store.n))
    d = np.linalg.norm(x1 - x_inf, axis=1)
    return float(np.quantile(d, q))


def _make_classifier(cfg, tmax: int):
    """Jitted per-order classification over one fixed-size row chunk —
    row-wise (each row reads only its own series), so chunking cannot
    perturb the predictions."""

    @jax.jit
    def classify(cls_params, exit_order, series):
        preds = jnp.zeros(exit_order.shape, jnp.int32)
        for l in range(1, tmax + 1):
            feats = series[:l + 1, :, :cfg.feat_dim]
            z = apply_classifier(cfg, cls_params[l], feats, l)
            preds = jnp.where(exit_order == l,
                              jnp.argmax(z, -1).astype(jnp.int32), preds)
        return preds

    return classify


def _resume_chain(mgr: CheckpointManager, stats: Dict):
    """Load the longest verifiable checkpoint chain 0..k (descending
    from the newest committed superstep, falling back one superstep per
    corrupt/unreadable checkpoint). Returns (k, {step: payload}) or
    (None, {}) for a cold start."""
    loaded: Dict[int, Dict[str, np.ndarray]] = {}
    committed = set(mgr.steps())
    bad: set = set()
    for k in sorted(committed, reverse=True):
        if any(b <= k for b in bad):
            continue        # a corrupt ancestor poisons everything above
        ok = True
        for j in range(k + 1):
            if j in loaded:
                continue
            if j not in committed:
                ok = False  # gap in the chain: series not reconstructible
                break
            try:
                loaded[j] = mgr.load_step(j)
            except (CheckpointCorruption, CheckpointError) as e:
                stats["corrupt_steps"] += 1
                stats["fallbacks"].append(
                    {"step": j, "error": f"{type(e).__name__}: {e}"})
                bad.add(j)
                ok = False
                break
        if ok:
            return k, loaded
    return None, {}


def run_full_graph_infer(store, cfg, params, nai, ocfg: OfflineConfig,
                         *, mesh=None, fault_plan=None) -> OfflineResult:
    """Classify every node of `store` with NAI, checkpointing at
    superstep granularity. `cfg`/`params` are the trained classifier
    stack (`repro.gnn.models`), `nai` the `NAIConfig`, `ocfg` the
    driver knobs. Returns predictions/exit orders for the n REAL nodes
    in store order, plus run stats (resume point, fallbacks, straggler
    and watchdog counters, checkpoint overhead)."""
    t_start = time.perf_counter()
    store = as_store(store)
    mesh = normalize_mesh(mesh)
    D = int(mesh.shape["data"]) if mesh is not None else 1
    gather_mode = ocfg.gather_mode if D > 1 else "dense"
    tmax = nai.t_max
    injector = (fault_plan.injector()
                if fault_plan is not None and not fault_plan.empty
                else None)

    t0 = time.perf_counter()
    be, packed = pack_graph(store, D, cfg.r, ocfg.spmm_impl,
                            halo=gather_mode != "dense", stationary=True)
    sa = (step_active_blocks(packed.hop_rb, tmax)
          if be.uses_tiles else None)
    ops_np = pack_operands(be, packed, sa)
    if be.uses_dense_x_inf:
        ops_np["x_inf"] = packed.x_inf
    pack_s = time.perf_counter() - t0
    nb_pad, n_pad = packed.n_batch, packed.n_pad

    fingerprint = {
        "store": store.name, "n": int(store.n),
        "num_edges": int(store.num_edges),
        "mutation_clock": int(store.mutation_clock),
        "feat_dim": int(store.feat_dim), "shards": D,
        "impl": ocfg.spmm_impl, "gather_mode": gather_mode,
        "r": float(cfg.r), "t_s": float(nai.t_s),
        "t_min": int(nai.t_min), "t_max": int(tmax),
        "nb_pad": int(nb_pad), "n_pad": int(n_pad),
        "f_pad": int(packed.x0.shape[1]),
    }
    mgr = CheckpointManager(ocfg.ckpt_dir, fingerprint=fingerprint,
                            injector=injector)

    stats: Dict = {
        "n": int(store.n), "shards": D, "impl": ocfg.spmm_impl,
        "gather_mode": gather_mode, "t_max": tmax,
        "nb_pad": int(nb_pad), "n_pad": int(n_pad),
        "resumed_from": None, "supersteps_run": 0, "corrupt_steps": 0,
        "fallbacks": [], "ckpt_write_failures": 0,
        "watchdog_retries": 0, "stragglers": [],
        "pack_s": pack_s, "compute_s": 0.0, "ckpt_s": 0.0,
        "classify_s": 0.0,
    }

    # ---------------------------------------------------------- resume
    snaps: Dict[int, np.ndarray] = {}   # step -> batch-row state X^(l)
    start = None
    if ocfg.resume:
        start, loaded = _resume_chain(mgr, stats)
        if start is not None:
            for j in range(start + 1):
                snaps[j] = loaded[j]["x"][:nb_pad]
            x_host = loaded[start]["x"]
            eo_host = loaded[start]["exit_order"]
    if start is None:
        x_host = packed.x0
        eo_host = np.zeros(nb_pad, np.int32)
        snaps[0] = x_host[:nb_pad]
        t0 = time.perf_counter()
        try:
            mgr.save_step(0, {"x": x_host, "exit_order": eo_host})
        except (InjectedFault, CheckpointError, OSError) as e:
            stats["ckpt_write_failures"] += 1
            stats["fallbacks"].append(
                {"step": 0, "error": f"write: {type(e).__name__}: {e}"})
        stats["ckpt_s"] += time.perf_counter() - t0
        start = 0
    stats["resumed_from"] = int(start)

    # ------------------------------------------------- superstep loop
    step_fn = make_superstep(be, nai, n_batch=nb_pad, n_rows=n_pad,
                             interpret=ocfg.interpret, mesh=mesh,
                             gather_mode=gather_mode)
    if mesh is not None:
        logical = operand_logical(be, gather_mode)
        ops_dev = {k: jax.device_put(
            v, NamedSharding(mesh, spec(*logical[k], mesh=mesh)))
            for k, v in ops_np.items()}
        row_sh = NamedSharding(mesh, spec("row_shard", None, mesh=mesh))
        eo_sh = NamedSharding(mesh, spec("row_shard", mesh=mesh))

        def _put(x, eo):
            return (jax.device_put(np.asarray(x), row_sh),
                    jax.device_put(np.asarray(eo), eo_sh))
    else:
        ops_dev = {k: jnp.asarray(v) for k, v in ops_np.items()}

        def _put(x, eo):
            return jnp.asarray(x), jnp.asarray(eo)

    x_dev, eo_dev = _put(x_host, eo_host)
    durations: List[float] = []
    if ocfg.crash_after is not None and ocfg.crash_after <= start:
        raise PreemptionSimulated(
            f"simulated preemption after superstep {start} "
            f"(crash_after={ocfg.crash_after} already committed)")

    for l in range(start + 1, tmax + 1):
        t0 = time.perf_counter()
        for attempt in range(ocfg.superstep_retries + 1):
            last = attempt == ocfg.superstep_retries
            if injector is not None \
                    and injector.fire("superstep_hang") is not None:
                # simulated hung dispatch: the watchdog path declares
                # the attempt dead and retries deterministically
                stats["watchdog_retries"] += 1
                if last:
                    raise WatchdogTimeout(
                        f"superstep {l} hung on every attempt "
                        f"({ocfg.superstep_retries + 1})")
                continue
            x_new, eo_new = step_fn(ops_dev, x_dev, eo_dev,
                                    jnp.int32(l))
            if ocfg.watchdog_s > 0:
                deadline = time.monotonic() + ocfg.watchdog_s
                while not (x_new.is_ready() and eo_new.is_ready()):
                    if time.monotonic() > deadline:
                        break
                    time.sleep(1e-4)
                if not (x_new.is_ready() and eo_new.is_ready()):
                    stats["watchdog_retries"] += 1
                    if last:
                        raise WatchdogTimeout(
                            f"superstep {l} exceeded the "
                            f"{ocfg.watchdog_s}s watchdog on every "
                            f"attempt")
                    continue
            jax.block_until_ready((x_new, eo_new))
            break
        dur = time.perf_counter() - t0
        if len(durations) >= 2:
            med = statistics.median(durations)
            if dur > ocfg.straggler_factor * med:
                stats["stragglers"].append(
                    {"step": l, "s": round(dur, 6),
                     "median_s": round(med, 6)})
        durations.append(dur)
        stats["compute_s"] += dur
        stats["supersteps_run"] += 1
        x_dev, eo_dev = x_new, eo_new
        x_host = np.asarray(x_dev)
        eo_host = np.asarray(eo_dev)
        snaps[l] = x_host[:nb_pad]
        t0 = time.perf_counter()
        try:
            mgr.save_step(l, {"x": x_host, "exit_order": eo_host})
        except (InjectedFault, CheckpointError, OSError) as e:
            # tolerated: in-memory state is still good; a crash later
            # simply resumes from an earlier committed superstep
            stats["ckpt_write_failures"] += 1
            stats["fallbacks"].append(
                {"step": l, "error": f"write: {type(e).__name__}: {e}"})
        stats["ckpt_s"] += time.perf_counter() - t0
        if ocfg.crash_after is not None and l >= ocfg.crash_after:
            raise PreemptionSimulated(
                f"simulated preemption after superstep {l}")

    # -------------------------------------------------- classification
    t0 = time.perf_counter()
    eo_final = np.where(eo_host == 0, tmax, eo_host).astype(np.int32)
    f_pad = packed.x0.shape[1]
    series = np.stack([snaps[j] for j in range(tmax + 1)])
    classify = _make_classifier(cfg, tmax)
    chunk = min(ocfg.cls_chunk, nb_pad)
    preds = np.empty(nb_pad, np.int32)
    for lo in range(0, nb_pad, chunk):
        hi = min(lo + chunk, nb_pad)
        s_blk = series[:, lo:hi]
        e_blk = eo_final[lo:hi]
        if hi - lo < chunk:     # pad the tail to the compiled shape
            s_blk = np.concatenate(
                [s_blk, np.zeros((tmax + 1, chunk - (hi - lo), f_pad),
                                 s_blk.dtype)], axis=1)
            e_blk = np.concatenate(
                [e_blk, np.full(chunk - (hi - lo), tmax, np.int32)])
        out = classify(params["cls"], jnp.asarray(e_blk),
                       jnp.asarray(s_blk))
        preds[lo:hi] = np.asarray(out)[:hi - lo]
    if D > 1:
        unperm = shard_batch_perm(nb_pad, D)
        preds = preds[unperm]
        eo_final = eo_final[unperm]
    n = store.n
    predictions = np.ascontiguousarray(preds[:n])
    exit_orders = np.ascontiguousarray(eo_final[:n])
    stats["classify_s"] = time.perf_counter() - t0

    stats["exit_histogram"] = np.bincount(
        exit_orders, minlength=tmax + 1).tolist()
    stats["ckpt_bytes"] = mgr.total_bytes()
    busy = stats["compute_s"] + stats["ckpt_s"]
    stats["ckpt_overhead_frac"] = (stats["ckpt_s"] / busy
                                   if busy > 0 else 0.0)
    stats["node_steps_per_s"] = (n * stats["supersteps_run"]
                                 / stats["compute_s"]
                                 if stats["compute_s"] > 0 else 0.0)
    end_to_end = busy + stats["classify_s"]
    stats["nodes_per_s"] = n / end_to_end if end_to_end > 0 else 0.0
    stats["total_s"] = time.perf_counter() - t_start
    mgr.save_result({"predictions": predictions,
                     "exit_orders": exit_orders})
    if injector is not None:
        stats["injected"] = injector.summary()
    return OfflineResult(predictions=predictions,
                         exit_orders=exit_orders, stats=stats)


# ----------------------------------------------------------------- CLI
def _main(argv=None) -> int:
    import argparse

    from repro.gnn.models import GNNConfig, init_classifiers
    from repro.gnn.nai import NAIConfig
    from repro.gnn.store import MmapStore
    from repro.launch.mesh import make_serving_mesh

    ap = argparse.ArgumentParser(
        description="Offline checkpointed full-graph NAI inference "
                    "over an on-disk GraphStore. Rerun the identical "
                    "command after a crash/preemption to resume from "
                    "the last complete superstep (bit-identical "
                    "results).")
    ap.add_argument("--store", required=True, help="MmapStore directory")
    ap.add_argument("--ckpt", required=True, help="checkpoint directory")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--impl", default="segment",
                    choices=("segment", "block_ell", "fused"))
    ap.add_argument("--gather", default="alltoall",
                    choices=("dense", "halo", "alltoall"))
    ap.add_argument("--t-max", type=int, default=3)
    ap.add_argument("--t-min", type=int, default=1)
    ap.add_argument("--t-s", type=float, default=None)
    ap.add_argument("--t-s-quantile", type=float, default=None,
                    help="derive T_s from this quantile of the "
                         "first-step exit distance (deterministic, so "
                         "resumed runs agree)")
    ap.add_argument("--r", type=float, default=0.5)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0,
                    help="classifier init seed (resume recomputes the "
                         "identical params)")
    ap.add_argument("--crash-after", type=int, default=None,
                    help=f"simulate preemption right after committing "
                         f"this superstep (exit code {EXIT_PREEMPTED})")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore existing checkpoints (fresh run)")
    ap.add_argument("--watchdog-s", type=float, default=0.0)
    ap.add_argument("--out-json", default="",
                    help="also write the run summary JSON here")
    args = ap.parse_args(argv)

    store = MmapStore(args.store)
    if args.t_s is None:
        q = 0.5 if args.t_s_quantile is None else args.t_s_quantile
        t_s = first_step_distance_quantile(store, args.r, q)
    else:
        t_s = args.t_s
    cfg = GNNConfig("sgc", store.feat_dim, store.num_classes,
                    k=args.t_max, r=args.r, hidden=args.hidden,
                    mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(args.seed))}
    nai = NAIConfig(t_s=t_s, t_min=args.t_min, t_max=args.t_max)
    mesh = make_serving_mesh(args.shards) if args.shards > 1 else None
    ocfg = OfflineConfig(ckpt_dir=args.ckpt, spmm_impl=args.impl,
                         gather_mode=args.gather,
                         resume=not args.no_resume,
                         watchdog_s=args.watchdog_s,
                         crash_after=args.crash_after)
    try:
        res = run_full_graph_infer(store, cfg, params, nai, ocfg,
                                   mesh=mesh)
    except PreemptionSimulated as e:
        print(f"PREEMPTED: {e}", flush=True)
        return EXIT_PREEMPTED
    summary = {"t_s": t_s, **res.stats}
    line = json.dumps(summary, sort_keys=True)
    print(f"OFFLINE_SUMMARY {line}", flush=True)
    if args.out_json:
        with open(args.out_json, "w") as fh:
            fh.write(line + "\n")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(_main())
