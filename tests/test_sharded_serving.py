"""Sharded serving acceptance: pipelined × sharded must equal serial ×
single-device — identical completion order, predictions, and exit orders
for every registered backend at multiple shard counts AND for every
frontier exchange (dense all_gather, static halo-frame gather, all_to_all
ragged exchange) — with zero steady-state jit compiles and zero
steady-state pack allocations on the default halo path. Runs in a
subprocess that forces 8 host devices (keep it isolated)."""
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, numpy as np
from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.launch.mesh import make_serving_mesh
from repro.serving import NAIServingEngine

g = load_dataset("pubmed-like", scale=0.02, seed=4)
g = dataclasses.replace(g, features=np.ascontiguousarray(g.features[:, :64]))
cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
rng = np.random.default_rng(0)
stream = [rng.choice(g.test_idx, size=s, replace=False)
          for s in (32, 30, 32, 28)]

def serve(eng):
    done = []
    for nodes in stream:
        eng.submit(nodes)
        done += eng.step()
    done += eng.flush()
    return (np.array([r.node_id for r in done]),
            np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))

from repro.gnn.backends import BACKENDS
for impl in sorted(BACKENDS):
    base = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled", spmm_impl=impl)
    bn, bp, bo = serve(base)
    for D in (2, 4):
        # gather-mode bit-parity: the default halo frame gather, the
        # dense all_gather reference, and (at D=2, bounding runtime) the
        # all_to_all ragged exchange must ALL reproduce single-device
        # predictions and exit orders exactly
        modes = ("halo", "dense") + (("alltoall",) if D == 2 else ())
        for gm in modes:
            eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                                   mode="compiled", spmm_impl=impl,
                                   pipeline_depth=2,
                                   mesh=make_serving_mesh(D),
                                   gather_mode=gm)
            assert eng.n_shards == D and eng.gather_mode == gm
            sn, sp, so = serve(eng)
            assert np.array_equal(sn, bn), (impl, D, gm)  # FIFO completion
            assert np.array_equal(sp, bp), (impl, D, gm)  # predictions
            assert np.array_equal(so, bo), (impl, D, gm)  # exit orders
            assert not eng._inflight
            if gm != "dense":
                # the halo frame must actually shrink the exchange and
                # stay bounded by its own metadata
                assert eng.halo_stats["halo_frac"] < 1.0, (impl, D, gm)
                assert (eng.halo_stats["halo_rows"]
                        <= eng.halo_stats["gather_rows_per_step"]
                        <= eng.halo_stats["s_pad"]), \
                    (impl, D, gm, eng.halo_stats)
            # EVERY gather mode holds the zero-steady-state invariants
            # (halo pads folded into bucket hwm/pool; dense = the PR-4
            # guarantee, must not regress)
            serve(eng)                                 # pool converges
            c0, a0 = eng.jit_stats["compiles"], eng.pack_stats["allocs"]
            serve(eng)                                 # steady state
            assert eng.jit_stats["compiles"] == c0, \
                (impl, D, gm, eng.jit_stats)
            assert eng.pack_stats["allocs"] == a0, \
                (impl, D, gm, eng.pack_stats)
            assert eng.jit_cache_size() == c0, (impl, D, gm)

# a degenerate 1-device mesh falls back to the plain single-device path
eng1 = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                        mode="compiled", spmm_impl="segment",
                        mesh=make_serving_mesh(1))
assert eng1.mesh is None and eng1.n_shards == 1
n1, p1, o1 = serve(eng1)

# mesh validation: host mode, data-axis-free meshes, and unknown gather
# modes are rejected; halo-packed operands can't run dense (and vice
# versa) through run_propagation
import numpy as _np
from jax.sharding import Mesh
try:
    NAIServingEngine(cfg, nai, params, g, mode="host",
                     mesh=make_serving_mesh(2))
    raise SystemExit("host+mesh should have raised")
except ValueError:
    pass
try:
    NAIServingEngine(cfg, nai, params, g, mode="compiled",
                     mesh=Mesh(_np.array(jax.devices()[:2]), ("model",)))
    raise SystemExit("mesh without data axis should have raised")
except ValueError:
    pass
try:
    NAIServingEngine(cfg, nai, params, g, mode="compiled",
                     gather_mode="ragged")
    raise SystemExit("unknown gather_mode should have raised")
except ValueError:
    pass
from repro.gnn.backends import get_backend, run_propagation
from repro.gnn.nai import NAIConfig as _NC
try:
    run_propagation(get_backend("segment"),
                    _NC(t_s=1.0, t_min=1, t_max=2), {}, np.zeros((256, 64)),
                    256, mesh=make_serving_mesh(2), gather_mode="halo")
    raise SystemExit("halo mode without halo operands should have raised")
except ValueError:
    pass
print("SHARDED_SERVING_OK")
"""


def test_sharded_serving_parity_and_steady_state():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert "SHARDED_SERVING_OK" in out.stdout, out.stdout + out.stderr
