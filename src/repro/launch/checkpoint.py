"""Superstep-granular checkpoints for offline full-graph inference.

`repro.launch.full_graph_infer` runs NAI over a whole `GraphStore`
graph as a sequence of supersteps; a run at real graph scale is a
long-lived batch job that must survive preemption. This module is the
durability layer under it: a directory of per-superstep `.npy`
payloads committed behind ONE versioned, CRC32-checksummed manifest,
with atomic write-then-rename commits, so at every instant the
directory either names a complete, verifiable prefix of supersteps or
nothing — a crash at any point can never poison a resume.

Layout::

    <root>/MANIFEST.json          committed state (atomic os.replace)
    <root>/step_00000/x.npy       per-step payload arrays
    <root>/step_00000/exit_order.npy
    <root>/result/predictions.npy final outputs (committed like a step)

Invariants the tests pin:

* **Commit is atomic.** `save_step` writes every payload file, THEN
  rewrites the manifest via tmp-file + fsync + `os.replace`. A crash
  before the replace leaves trailing payload files that no manifest
  entry names — `steps()` never sees them, a resume ignores them.
* **Corruption is detected, typed, and recoverable.** Every payload
  file's CRC32 is recorded at commit; `load_step` re-checks it and
  raises `CheckpointCorruption` on any mismatch, truncation, or
  missing file, so the driver can fall back to the previous complete
  superstep instead of resuming from garbage.
* **A checkpoint is bound to its run.** The manifest records a
  `fingerprint` (graph identity, shard count, backend, NAI config,
  padded geometry); opening the directory with a different
  fingerprint raises `CheckpointMismatch` — resuming a run onto the
  wrong graph or a different partitioning is an error, not a subtly
  wrong answer.
* **Bit-exact round-trip.** Payloads are `np.save`/`np.load` — dtype,
  shape, and every byte of the data come back identical (the
  hypothesis round-trip property in tests/test_checkpoint.py).

Fault injection composes via the PR-8 machinery: an optional
`FaultInjector` is consulted at the `ckpt_write` point (after payloads,
before the manifest commit — exactly the crash-mid-checkpoint window)
and the `ckpt_read` point (a committed checkpoint reading back bad).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.gnn.store import _file_crc32

FORMAT = "repro-offline-ckpt-v1"
MANIFEST = "MANIFEST.json"
RESULT_KEY = "result"


class CheckpointError(RuntimeError):
    """Base class for checkpoint-directory failures."""


class CheckpointCorruption(CheckpointError):
    """A committed checkpoint failed verification (CRC mismatch,
    truncated or missing payload, unparseable manifest). The driver's
    response is to fall back to the previous complete superstep."""


class CheckpointMismatch(CheckpointError):
    """The checkpoint directory belongs to a different run (format or
    fingerprint disagreement) — resuming would be silently wrong."""


def _fsync_dir(path: str) -> None:
    """Durably record a directory entry (the rename) itself."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _canon(obj) -> str:
    """Canonical JSON for fingerprint equality across processes."""
    return json.dumps(obj, sort_keys=True)


class CheckpointManager:
    """One run's checkpoint directory.

    `fingerprint` is any JSON-able dict identifying the run; a fresh
    directory adopts it, an existing one must match it exactly.
    `injector` is an optional `repro.serving.faults.FaultInjector`
    consulted at the ``ckpt_write`` / ``ckpt_read`` stages.
    """

    def __init__(self, root: str, fingerprint: Optional[dict] = None,
                 *, injector=None):
        self.root = root
        self.injector = injector
        os.makedirs(root, exist_ok=True)
        self._manifest = self._read_manifest()
        if self._manifest is None:
            self._manifest = {"format": FORMAT,
                              "fingerprint": fingerprint,
                              "steps": {}, RESULT_KEY: None}
        elif fingerprint is not None:
            have = self._manifest.get("fingerprint")
            if _canon(have) != _canon(fingerprint):
                raise CheckpointMismatch(
                    f"checkpoint at {root} belongs to a different run: "
                    f"manifest fingerprint {have!r} != {fingerprint!r}")

    # ------------------------------------------------------- manifest
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST)

    @property
    def fingerprint(self) -> Optional[dict]:
        return self._manifest.get("fingerprint")

    def _read_manifest(self) -> Optional[dict]:
        path = self.manifest_path
        if not os.path.exists(path):
            return None
        try:
            with open(path) as fh:
                doc = json.load(fh)
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointCorruption(
                f"manifest {path} is not valid JSON ({e}); the commit "
                f"protocol makes this impossible short of external "
                f"damage — refusing to guess") from e
        if not isinstance(doc, dict) or not isinstance(
                doc.get("steps"), dict):
            raise CheckpointCorruption(
                f"manifest {path} has no steps table — damaged or "
                f"foreign file")
        if doc.get("format") != FORMAT:
            raise CheckpointMismatch(
                f"manifest {path} has format {doc.get('format')!r}, "
                f"this build reads {FORMAT!r}")
        return doc

    def _commit(self) -> None:
        """Atomic manifest rewrite: tmp + fsync + rename + dir fsync.
        Readers only ever see the previous or the new complete
        manifest, never a torn one."""
        tmp = self.manifest_path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self._manifest, fh, indent=2, sort_keys=True)
            fh.write("\n")
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.manifest_path)
        _fsync_dir(self.root)

    # ---------------------------------------------------------- steps
    def steps(self) -> List[int]:
        """Committed superstep ids, ascending. Payload directories with
        no manifest entry (a crash before commit) are invisible here."""
        return sorted(int(k) for k in self._manifest["steps"])

    def latest_complete(self, *, verify: bool = False) -> Optional[int]:
        """Highest committed superstep — with ``verify=True``, the
        highest k whose ENTIRE chain 0..k is committed and CRC-clean
        (a resume needs every earlier step's batch snapshot, so one
        corrupt ancestor invalidates everything above it)."""
        steps = self.steps()
        if not steps:
            return None
        if not verify:
            return steps[-1]
        have = set(steps)
        best = None
        for k in range(steps[-1] + 1):
            if k not in have:
                break
            try:
                self.verify_step(k)
            except CheckpointCorruption:
                break
            best = k
        return best

    def _write_payload(self, subdir: str,
                       arrays: Dict[str, np.ndarray]) -> dict:
        d = os.path.join(self.root, subdir)
        os.makedirs(d, exist_ok=True)
        files = {}
        for key, arr in arrays.items():
            path = os.path.join(d, f"{key}.npy")
            with open(path, "wb") as fh:
                np.save(fh, np.asarray(arr))
                fh.flush()
                os.fsync(fh.fileno())
            files[key] = {"crc32": _file_crc32(path),
                          "bytes": os.path.getsize(path)}
        return {"dir": subdir, "files": files}

    def _read_payload(self, entry: dict, what: str,
                      *, verify: bool = True) -> Dict[str, np.ndarray]:
        if self.injector is not None \
                and self.injector.fire("ckpt_read") is not None:
            raise CheckpointCorruption(
                f"injected read corruption on {what} (ckpt_read stage)")
        out = {}
        for key, rec in entry["files"].items():
            path = os.path.join(self.root, entry["dir"], f"{key}.npy")
            if not os.path.exists(path):
                raise CheckpointCorruption(
                    f"{what}: committed payload {path} is missing")
            if verify:
                got = _file_crc32(path)
                if got != rec["crc32"]:
                    raise CheckpointCorruption(
                        f"{what}: CRC mismatch on {path} "
                        f"(manifest {rec['crc32']}, file {got})")
            try:
                out[key] = np.load(path)
            except (ValueError, OSError, EOFError) as e:
                raise CheckpointCorruption(
                    f"{what}: unreadable payload {path}: {e}") from e
        return out

    def save_step(self, step: int,
                  arrays: Dict[str, np.ndarray]) -> None:
        """Write superstep `step`'s payload arrays, then commit the
        manifest. The ``ckpt_write`` injection point sits BETWEEN the
        two — exactly the crash-mid-checkpoint window the atomic commit
        protects against (payloads on disk, manifest never updated)."""
        entry = self._write_payload(f"step_{int(step):05d}", arrays)
        if self.injector is not None \
                and self.injector.fire("ckpt_write") is not None:
            from repro.serving.faults import InjectedFault
            raise InjectedFault(
                f"checkpoint write of superstep {step} crashed before "
                f"the manifest commit (ckpt_write stage)")
        self._manifest["steps"][str(int(step))] = entry
        self._commit()

    def load_step(self, step: int, *, verify: bool = True
                  ) -> Dict[str, np.ndarray]:
        entry = self._manifest["steps"].get(str(int(step)))
        if entry is None:
            raise CheckpointError(
                f"no committed checkpoint for superstep {step} "
                f"(have {self.steps()})")
        return self._read_payload(entry, f"superstep {step}",
                                  verify=verify)

    def verify_step(self, step: int) -> None:
        """CRC-check a committed step without loading the arrays."""
        entry = self._manifest["steps"].get(str(int(step)))
        if entry is None:
            raise CheckpointError(
                f"no committed checkpoint for superstep {step}")
        for key, rec in entry["files"].items():
            path = os.path.join(self.root, entry["dir"], f"{key}.npy")
            if not os.path.exists(path):
                raise CheckpointCorruption(
                    f"superstep {step}: committed payload {path} is "
                    f"missing")
            got = _file_crc32(path)
            if got != rec["crc32"]:
                raise CheckpointCorruption(
                    f"superstep {step}: CRC mismatch on {path} "
                    f"(manifest {rec['crc32']}, file {got})")

    # --------------------------------------------------------- result
    def save_result(self, arrays: Dict[str, np.ndarray]) -> None:
        """Commit the run's final outputs (same protocol as a step)."""
        entry = self._write_payload(RESULT_KEY, arrays)
        self._manifest[RESULT_KEY] = entry
        self._commit()

    def load_result(self, *, verify: bool = True
                    ) -> Optional[Dict[str, np.ndarray]]:
        entry = self._manifest.get(RESULT_KEY)
        if entry is None:
            return None
        return self._read_payload(entry, "result", verify=verify)

    def total_bytes(self) -> int:
        """Committed checkpoint bytes (steps only — the bench's
        checkpoint-overhead column)."""
        return sum(rec["bytes"]
                   for entry in self._manifest["steps"].values()
                   for rec in entry["files"].values())
