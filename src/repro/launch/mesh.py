"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before any jax initialization."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips (one TPU v5e pod) or 2x16x16 = 512 chips (2 pods).
    Axes: data (batch / FSDP) x model (TP); `pod` is pure data parallel."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    dev = jax.devices()[:n_devices]
    return jax.sharding.Mesh(np.array(dev).reshape(1, len(dev)),
                             ("data", "model"))


def make_serving_mesh(n_data: int = 0):
    """1-D ``('data',)`` mesh over the first `n_data` devices (all
    devices when 0) — the GNN serving engine's row-sharding mesh: packed
    support rows partition over ``data`` (repro.gnn.backends), features
    stay unsharded. Device position along ``data`` IS the shard id the
    packer's halo metadata names (``halo_src_shard`` / the `all_to_all`
    send lists address peers by data-axis index), so the mesh must not
    reorder devices between packing and dispatch — one more reason this
    is a constructor, not an ambient global. Raises when fewer than
    `n_data` devices exist — silently serving fewer shards than asked
    for would defeat the memory-capacity reason to shard."""
    avail = jax.devices()
    if n_data > len(avail):
        raise ValueError(f"make_serving_mesh({n_data}): only "
                         f"{len(avail)} devices available")
    dev = avail[:n_data] if n_data else avail
    return jax.sharding.Mesh(np.array(dev), ("data",))
