"""Config dataclasses shared across the framework.

Everything is a frozen dataclass so configs are hashable (usable as static
args to jit) and serializable (asdict -> msgpack).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


# ---------------------------------------------------------------------------
# Layer kinds understood by repro.nn.blocks
#   attn      : global causal self-attention + dense MLP
#   local     : sliding-window causal self-attention + dense MLP
#   attn_moe  : global causal self-attention + MoE MLP
#   rglru     : RG-LRU recurrent mixer + dense MLP (Griffin/RecurrentGemma)
#   rwkv      : RWKV6 time-mix + channel-mix
#   xattn     : cross-attention (to frontend embeddings) + dense MLP (VLM)
#   encdec    : causal self-attn + cross-attn to encoder + dense MLP (whisper)
#   enc       : bidirectional self-attention + dense MLP (encoder side)
# ---------------------------------------------------------------------------
LAYER_KINDS = ("attn", "local", "attn_moe", "rglru", "rwkv", "xattn", "encdec", "enc")


@dataclass(frozen=True)
class AdaptiveDepthConfig:
    """Paper technique (NAI) generalized to depth-adaptive transformer
    inference: early-exit heads + saturation criterion + inception
    distillation. Mirrors (T_s, T_min, T_max, T, lambda, r) of the paper."""
    enabled: bool = False
    exit_layers: Tuple[int, ...] = ()    # block indices carrying exit heads
    t_s: float = 0.05                    # saturation threshold (T_s)
    t_min: int = 1                       # min exit index (T_min)
    t_max: int = -1                      # max exit index; -1 = last (T_max)
    temperature: float = 1.4             # distillation temperature T
    lam: float = 0.9                     # loss mix lambda
    ensemble_r: int = 2                  # online-distillation ensemble size r


@dataclass(frozen=True)
class ModelConfig:
    """One decoder-style (or enc-dec) architecture."""
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                     # citation for the config
    # trunk dims
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    # block pattern: repeated `pattern` + trailing `remainder`
    pattern: Tuple[str, ...] = ("attn",)
    remainder: Tuple[str, ...] = ()
    # MLP / activations
    mlp_kind: str = "swiglu"             # swiglu | geglu | gelu
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # attention
    rope_theta: float = 10000.0
    sliding_window: int = 0              # used by 'local' layers
    use_rope: bool = True
    # context-parallel attention: shard query positions over 'model' when
    # head counts don't divide the TP axis (deepseek 56H, whisper 12H) —
    # beyond-paper optimization, EXPERIMENTS.md §Perf-1
    seq_shard_attn: bool = False
    attn_logit_softcap: float = 0.0
    # recurrent (RG-LRU)
    rnn_width: int = 0                   # 0 -> d_model
    conv1d_width: int = 4
    # rwkv
    rwkv_head_dim: int = 64
    # enc-dec / frontend stubs
    encoder_layers: int = 0
    encoder_seq: int = 1500              # stub audio frames
    num_image_tokens: int = 0            # stub vision patches (VLM)
    # misc
    norm_kind: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    pos_embed: str = "none"              # none | sinusoidal (when no RoPE)
    scale_embed_sqrt_d: bool = False     # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = False
    final_logit_softcap: float = 0.0
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # long-context serving variant (beyond-paper): cap decode KV to a window
    long_context_window: int = 4096
    # paper technique
    adaptive: AdaptiveDepthConfig = field(default_factory=AdaptiveDepthConfig)

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def pattern_repeats(self) -> int:
        body = self.num_layers - len(self.remainder)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {self.num_layers} layers != r*{len(self.pattern)} + "
            f"{len(self.remainder)}")
        return body // len(self.pattern)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return self.pattern * self.pattern_repeats + self.remainder

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> str:
        """'native' (sub-quadratic mixer), 'window' (sliding-window variant),
        used to decide how long_500k is served."""
        kinds = set(self.pattern) | set(self.remainder)
        if kinds <= {"rwkv", "rglru", "local"} or (
                "rglru" in kinds and "attn" not in kinds):
            return "native"
        return "window"

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced variant for smoke tests."""
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                            # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"             # cosine | linear | constant
    remat: bool = True
    moment_dtype: str = "float32"        # bf16 for the >100B dry-runs


# Hardware constants for the roofline model (TPU v5e target).
@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12           # bf16 FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link
    hbm_bytes: float = 16e9              # capacity per chip
    vmem_bytes: float = 128 * 2**20


TPU_V5E = HardwareConfig()
