"""Support -> block-ELL packing for the compiled serving path.

Converts the induced subgraph of a sampled `Support` into the static-shape
operand set consumed by the Pallas block-ELL SpMM kernel
(`repro.kernels.spmm.spmm_block_ell`) and the fused NAP step kernel
(`repro.kernels.nap_step.nap_step_fused` — same tiles plus the bucketed
`x_inf` and a prefetched squared threshold), padded to *bucket* sizes so
that repeat batches of similar size hit the jit compile cache:

* the batch region is padded from `n_batch` to `nb_bucket` rows (pad rows
  have no edges, zero features, zero stationary state — they exit at T_min
  and are dropped by slicing results to `nb_real`);
* support rows follow at `nb_bucket`, and the total row count is padded to
  an `s_bucket` multiple of CB so feature blocks index cleanly;
* the per-row-block tile budget `max_tb` is padded to `tb_bucket`.

Buckets grow geometrically ({1,2,3}·2^k), bounding padding overshoot to
~33% while keeping the number of distinct compiled shapes logarithmic in
the size range — the bucket policy recorded in ROADMAP.md.

The packer also emits `hop_rb`, the minimum BFS hop per row block, from
which the per-step NAP row-block predicate follows statically: the value
X^(l) at a node of hop h can only reach a batch output if h <= T_max - l,
so row blocks with `hop_rb > T_max - l` are skipped by the kernel at step
l (and everything is skipped once the whole batch has exited — the
dynamic part, ANDed in inside the jitted function).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.gnn.sampler import Support
from repro.kernels.spmm.kernel import CB, FB, RB

_INF_HOP = np.int32(2 ** 30)   # hop assigned to padding rows


def next_bucket(x: int, minimum: int = 1) -> int:
    """Smallest value >= max(x, minimum) in the geometric series
    {1, 2, 3} * 2^k * minimum (ratio <= 1.5)."""
    x = max(int(x), minimum)
    b = minimum
    while True:
        for mult in (1, 2, 3):
            if b * mult >= x:
                return b * mult
        b *= 2


@dataclasses.dataclass
class PackedSupport:
    # block-ELL operands (see repro.kernels.spmm.kernel.spmm_block_ell)
    tiles: np.ndarray        # (n_rb, tb, RB, CB) f32 coefficient tiles
    tile_col: np.ndarray     # (n_rb, tb) int32 column-block per tile
    valid: np.ndarray        # (n_rb, tb) int32 1 = real tile
    hop_rb: np.ndarray       # (n_rb,) int32 min BFS hop per row block
    # padded batch layout
    n_batch: int             # bucket-padded batch region (rows [0, n_batch))
    nb_real: int             # true batch size (rows [0, nb_real) are real)
    n_pad: int               # total padded rows (multiple of CB)
    s_real: int              # true support size
    # padded dense operands
    x0: np.ndarray           # (n_pad, f_pad) f32 features at support rows
    x_inf: np.ndarray        # (n_batch, f_pad) f32 stationary state
    # bucket-padded edge list in padded row ids (for the segment-sum
    # compiled path; pad edges have coef 0 so they contribute nothing)
    src: np.ndarray          # (e_pad,) int32
    dst: np.ndarray          # (e_pad,) int32
    coef: np.ndarray         # (e_pad,) f32
    # rank-1 stationary-state factors (x_inf = c_inf ⊗ s_inf), padded to
    # the same buckets — the fused step kernel streams these instead of
    # the dense x_inf; None unless pack_support got x_inf_factors
    c_inf: Optional[np.ndarray] = None    # (n_batch,) f32
    s_inf: Optional[np.ndarray] = None    # (f_pad,) f32
    # True when pack_support refilled a caller-provided buffer set in
    # place instead of allocating (the steady-state serving path)
    reused: bool = False

    @property
    def n_rb(self) -> int:
        return self.tiles.shape[0]

    @property
    def density(self) -> float:
        return float(self.valid.mean()) if self.valid.size else 0.0

    def shape_key(self, spmm_impl: str = "block_ell") -> tuple:
        """The jit-cache key: exactly the static shapes the compiled
        function specializes on for the given SpMM implementation (the
        other path's operand shapes must not perturb compile counting).
        ``block_ell`` and ``fused`` consume the same operand set — the
        fused kernel additionally prefetches `x_inf` (already bucketed to
        (n_batch, f_pad) here) and the squared threshold (a scalar, no
        shape) — but they compile different programs, so the impl name
        stays in the key."""
        if spmm_impl in ("block_ell", "fused"):
            return (spmm_impl, self.n_batch, self.n_pad,
                    self.tiles.shape[1], self.x0.shape[1])
        return ("segment", self.n_batch, self.n_pad, self.x0.shape[1],
                len(self.src))


def _remap_rows(sup: Support, nb_bucket: int) -> np.ndarray:
    """Local support id -> padded row id (batch region padded to
    nb_bucket)."""
    shift = nb_bucket - sup.n_batch
    ids = np.arange(len(sup), dtype=np.int64)
    return np.where(ids < sup.n_batch, ids, ids + shift)


def pack_support(sup: Support, x0: np.ndarray, x_inf: np.ndarray, *,
                 nb_bucket: Optional[int] = None,
                 s_bucket: Optional[int] = None,
                 tb_bucket: Optional[int] = None,
                 e_bucket: Optional[int] = None,
                 build_tiles: bool = True,
                 build_edges: bool = True,
                 x_inf_factors=None,
                 out: Optional[PackedSupport] = None) -> PackedSupport:
    """Pack a sampled `Support` (+ its features and per-batch-node
    stationary state) into bucket-padded block-ELL operands.

    x0 (S, f) support-row features; x_inf (n_batch, f) stationary state.
    Explicit buckets are FLOORS (must be legal sizes: s_bucket a CB
    multiple); the packer grows past them when the support needs more.
    The serving engine passes its per-shape high-water marks here so that
    a smaller follow-up batch reuses the previous compiled shape.

    `build_tiles=False` skips tile construction entirely (tiles/tile_col/
    valid come back with a zero tile budget) — the segment-sum path only
    consumes the edge list, and a dense hub row block can push the tile
    tensor to GBs on large supports. Symmetrically `build_edges=False`
    skips the bucket-padded edge list the block-ELL path never reads.

    `x_inf_factors=(c, s)` (the rank-1 stationary-state factors, see
    `repro.gnn.nai.support_stationary_factors`) additionally emits
    bucket-padded `c_inf` (n_batch,) / `s_inf` (f_pad,) — the fused step
    kernel's streamed operands. Padding rows/columns get factor zero,
    matching the zero-padded dense x_inf.

    `out` is a previously packed result whose buffers may be refilled in
    place: when every bucket-padded operand shape matches (the steady
    state, since the engine's high-water marks make bucket shapes
    sticky), the big arrays are cleared and rewritten instead of
    reallocated, and the returned PackedSupport (== `out`, with
    `reused=True`) owns the same buffers. On any shape mismatch a fresh
    set is allocated. Only the bucket-sized operand arrays are pooled;
    O(S)/O(E) scratch (row maps, the tile unique pass) still allocates.
    Callers overlapping host packing with async device compute must
    rotate >= 2 buffer sets so an in-flight batch's operands are never
    overwritten (see NAIServingEngine)."""
    if s_bucket and s_bucket % CB:
        raise ValueError(f"s_bucket {s_bucket} not a CB multiple")
    nb, S = sup.n_batch, len(sup)
    nb_bucket = max(next_bucket(nb, RB), nb_bucket or 0)
    rows_needed = nb_bucket + (S - nb)
    n_pad = max(next_bucket(-(-rows_needed // CB), 1) * CB, s_bucket or 0)

    row_of = _remap_rows(sup, nb_bucket)
    src = row_of[sup.src]
    dst = row_of[sup.dst]

    # --- tile geometry (needed up front so buffer reuse can be decided
    # before anything is written)
    n_rb, n_cb = n_pad // RB, n_pad // CB
    if build_tiles:
        rb = dst // RB
        cb = src // CB
        key = rb * n_cb + cb
        uniq, inverse = np.unique(key, return_inverse=True)
        tile_rb = (uniq // n_cb).astype(np.int64)
        tile_cb = (uniq % n_cb).astype(np.int32)
        counts = np.bincount(tile_rb, minlength=n_rb)
        tb_needed = max(int(counts.max()) if len(uniq) else 1, 1)
        tb = max(next_bucket(tb_needed, 1), tb_bucket or 0)
    else:
        tb = 0
    f_pad = -(-x0.shape[1] // FB) * FB
    xi_cols = f_pad if x_inf.shape[1] else 0
    e_pad = (max(next_bucket(len(src), 1), e_bucket or 0)
             if build_edges else 0)

    reuse = (out is not None
             and out.tiles.shape == (n_rb, tb, RB, CB)
             and out.x0.shape == (n_pad, f_pad)
             and out.x_inf.shape == (nb_bucket, xi_cols)
             and len(out.src) == e_pad
             and (out.c_inf is not None) == (x_inf_factors is not None))
    if reuse:
        p = out
        p.tiles.fill(0.0)
        p.tile_col.fill(0)
        p.valid.fill(0)
        p.x0.fill(0.0)
        p.x_inf.fill(0.0)
    else:
        p = PackedSupport(
            tiles=np.zeros((n_rb, tb, RB, CB), np.float32),
            tile_col=np.zeros((n_rb, tb), np.int32),
            valid=np.zeros((n_rb, tb), np.int32),
            hop_rb=np.full(n_rb, _INF_HOP, np.int32),
            n_batch=nb_bucket, nb_real=nb, n_pad=n_pad, s_real=S,
            x0=np.zeros((n_pad, f_pad), np.float32),
            x_inf=np.zeros((nb_bucket, xi_cols), np.float32),
            src=np.full(e_pad, 0, np.int32),
            dst=np.full(e_pad, 0, np.int32),
            coef=np.zeros(e_pad, np.float32),
            c_inf=(np.zeros(nb_bucket, np.float32)
                   if x_inf_factors is not None else None),
            s_inf=(np.zeros(f_pad, np.float32)
                   if x_inf_factors is not None else None))
    p.n_batch, p.nb_real, p.n_pad, p.s_real = nb_bucket, nb, n_pad, S
    p.reused = reuse

    # --- vectorized block-ELL build (cf. repro.kernels.spmm.ops, which
    # loops per tile; this path is a handful of numpy passes)
    if build_tiles:
        # slot of each unique tile within its row block: uniq is sorted,
        # so tiles of one rb are contiguous and column-sorted
        first_of_rb = np.concatenate([[0], np.cumsum(counts)[:-1]])
        slot = np.arange(len(uniq), dtype=np.int64) - first_of_rb[tile_rb]
        p.tile_col[tile_rb, slot] = tile_cb
        p.valid[tile_rb, slot] = 1
        np.add.at(p.tiles, (rb, slot[inverse], dst % RB, src % CB),
                  sup.coef)

    # --- per-row hop -> per-row-block min hop; the (n_pad,) scratch is
    # KB-scale and the vectorized scatter + reshape-min beats a buffered
    # ufunc.at by an order of magnitude on large supports
    hop_row = np.full(n_pad, _INF_HOP, np.int32)
    hop_row[row_of] = sup.hop
    p.hop_rb[:] = hop_row.reshape(n_rb, RB).min(axis=1)

    p.x0[row_of, :x0.shape[1]] = np.asarray(x0, np.float32)
    # a zero-column x_inf means the caller only needs the batch-row count
    # (fused path: the kernel streams the rank-1 factors instead)
    p.x_inf[:nb, :x_inf.shape[1]] = x_inf

    if x_inf_factors is not None:
        c, s = x_inf_factors
        p.c_inf.fill(0.0)
        p.c_inf[:nb] = np.asarray(c, np.float32)
        p.s_inf.fill(0.0)
        p.s_inf[:len(s)] = np.asarray(s, np.float32)

    # bucket-padded edge list (segment-sum path): pad with zero-coef
    # self-edges on the last (always padding or hop-max) row
    if build_edges:
        p.src.fill(n_pad - 1)
        p.dst.fill(n_pad - 1)
        p.coef.fill(0.0)
        p.src[:len(src)] = src
        p.dst[:len(dst)] = dst
        p.coef[:len(sup.coef)] = sup.coef
    return p


def step_active_blocks(hop_rb: np.ndarray, t_max: int) -> np.ndarray:
    """(t_max, n_rb) int32: row blocks whose X^(l) value can still reach a
    batch output at step l = 1..t_max (hop <= T_max - l). Row 0 of the
    result is step l=1."""
    ls = np.arange(1, t_max + 1, dtype=np.int64)[:, None]
    return (hop_rb[None, :] <= t_max - ls).astype(np.int32)
