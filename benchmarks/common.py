"""Shared benchmark harness: datasets scaled to CPU, one trained NAI model
per (dataset, base_model), reused across tables."""
from __future__ import annotations

import functools
import time
from typing import Tuple

import numpy as np

from repro.gnn import DistillConfig, GNNConfig, load_dataset, train_nai

# CPU-budget scale factors per paper dataset (Table 2 shapes, scaled)
SCALES = {
    "pubmed-like": 0.15,
    "flickr-like": 0.04,
    "arxiv-like": 0.02,
    "products-like": 0.002,
}
K_FOR = {"pubmed-like": 4, "flickr-like": 4, "arxiv-like": 5,
         "products-like": 5}

_DC = DistillConfig(epochs_base=150, epochs_offline=80, epochs_online=80)


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    return load_dataset(name, scale=SCALES[name], seed=0)


@functools.lru_cache(maxsize=None)
def trained(name: str, base_model: str = "sgc") -> Tuple:
    g = dataset(name)
    k = K_FOR[name] if base_model == "sgc" else 4
    cfg = GNNConfig(base_model, g.features.shape[1], g.num_classes, k=k,
                    hidden=64, mlp_layers=2, dropout=0.1)
    t0 = time.time()
    params, info = train_nai(cfg, g, _DC)
    return cfg, params, {"train_s": time.time() - t0, **info}


def grid_search_ts(name: str, base_model: str = "sgc", t_max=None,
                   quantiles=(0.05, 0.25, 0.5, 0.75, 0.95)):
    """Paper §3.3: users search T_s on validation to match latency. We probe
    distance quantiles of the first propagation step."""
    g = dataset(name)
    cfg, params, _ = trained(name, base_model)
    from repro.gnn.graph import propagated_series, stationary_weights
    series = propagated_series(g, g.features, 1, cfg.r)
    a, b = stationary_weights(g, cfg.r)
    x_inf = np.outer(a, b @ g.features)
    d = np.linalg.norm(series[1] - x_inf, axis=1)
    return [float(np.quantile(d, q)) for q in quantiles]


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def zipf_requests(ids: np.ndarray, n_requests: int, *,
                  exponent: float = 1.0, seed: int = 0) -> np.ndarray:
    """Seeded Zipf(`exponent`) request stream over `ids`.

    Models real serving traffic locality (hub nodes land in nearly every
    request window): a seeded permutation of `ids` assigns popularity
    ranks, then requests are drawn i.i.d. with p(rank k) ∝ k^-exponent.
    `exponent=0` degenerates to uniform traffic (the 0%-overlap control
    the cache bench uses to bound overhead). Deterministic for a given
    (ids, n_requests, exponent, seed) — the contract the cache bench's
    cached-vs-cold parity comparison and the determinism test rely on.
    """
    ids = np.asarray(ids)
    if ids.ndim != 1 or len(ids) == 0:
        raise ValueError(f"ids must be a non-empty 1-D array, got shape "
                         f"{ids.shape}")
    if exponent < 0:
        raise ValueError(f"exponent must be >= 0, got {exponent}")
    rng = np.random.default_rng(seed)
    ranked = rng.permutation(ids)
    p = np.arange(1, len(ids) + 1, dtype=np.float64) ** -exponent
    p /= p.sum()
    return rng.choice(ranked, size=n_requests, p=p)


# Sections that sub-benches merge into the combined BENCH_serving.json.
# serving_bench owns the top-level keys; each sub-bench owns ONE section.
BENCH_SECTIONS = ("frontend", "chaos", "cache", "sharded", "graph_scale",
                  "offline")


def write_bench_json(out_path: str, payload: dict, *,
                     section: str | None = None) -> dict:
    """Write a benchmark record, preserving sibling sections.

    The combined BENCH_serving.json is written by several benches:
    serving_bench owns the top-level document, while frontend/chaos/
    cache/offline benches each own one section key (`BENCH_SECTIONS`).
    Before this helper, each bench re-implemented "read the previous
    file, graft my section, keep everyone else's" with slightly
    different error handling — this is the single copy.

    section=None: `payload` IS the document; any known section present
    in the existing file but absent from `payload` is carried over so
    regenerating the top-level record never drops a sub-bench's data.
    section="x": the existing document (or {} when the file is missing
    or unreadable) gets `doc[section] = payload`.

    Returns the document written.
    """
    import json
    import os
    doc: dict = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as fh:
                doc = json.load(fh)
            if not isinstance(doc, dict):
                doc = {}
        except (json.JSONDecodeError, OSError):
            doc = {}
    if section is None:
        for key in BENCH_SECTIONS:
            if key in doc and key not in payload:
                payload[key] = doc[key]
        doc = payload
    else:
        if section not in BENCH_SECTIONS:
            raise ValueError(f"unknown bench section {section!r} "
                             f"(known: {BENCH_SECTIONS})")
        doc[section] = payload
    with open(out_path, "w") as fh:
        json.dump(doc, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {out_path}")
    return doc
