"""Offline full-graph inference bench: checkpointed superstep driver
under preemption, at graph scale.

Every driver run is a REAL CLI subprocess (`python -m
repro.launch.full_graph_infer`) over an on-disk `MmapStore`, with
``XLA_FLAGS=--xla_force_host_platform_device_count=D`` set per child —
the parent never forces devices. Scenarios:

* **clean** — one uninterrupted run; the reference outputs and the
  throughput/overhead columns (nodes/sec, node-steps/sec, checkpoint
  overhead fraction, checkpoint bytes, exit histogram).
* **kill_sweep** — for every superstep k, a run preempted right after
  committing k (``--crash-after``, exit code 17) then rerun; gates
  ``resumed_from == k`` and bit-parity with clean.
* **sigkill** — a run SIGKILLed mid-flight (the parent polls the
  checkpoint directory and kills as soon as step 0 commits, while the
  superstep compile is still in progress) then rerun; gates that the
  kill landed mid-run and the resume is bit-parity with clean.
* **corrupt** — a committed checkpoint payload byte-flipped between
  preemption and resume; gates typed detection (corrupt_steps >= 1),
  fallback one superstep, and bit-parity.

Usage::

    PYTHONPATH=src python -m benchmarks.full_graph_infer_bench
        [--smoke] [--check] [--shards D] [--n N] [--out F]

Full runs merge the payload under the ``"offline"`` key of
``BENCH_serving.json`` (≥1e5-node store, D≥2, enforced by ``--check``);
``--smoke`` writes a standalone (gitignored)
``BENCH_offline_smoke.json``. Parity is always exact equality of the
result arrays — never a tolerance.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional

if __package__ in (None, ""):   # `python benchmarks/full_graph_infer_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import numpy as np

from benchmarks.common import csv_row, write_bench_json

T_MAX = 3
EXIT_PREEMPTED = 17     # mirrors repro.launch.full_graph_infer


def _env(shards: int) -> Dict[str, str]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{max(shards, 1)}")
    return env


def _gen_store(path: str, n: int, feat: int, classes: int,
               shards: int) -> None:
    code = ("import sys; from repro.gnn.store import make_graph; "
            "make_graph(int(sys.argv[2]), avg_deg=6.0, alpha=2.2, "
            "seed=5, path=sys.argv[1], feat_dim=int(sys.argv[3]), "
            "num_classes=int(sys.argv[4]))")
    subprocess.run([sys.executable, "-c", code, path, str(n),
                    str(feat), str(classes)], env=_env(1), check=True)


def _base_cmd(store: str, shards: int) -> List[str]:
    return [sys.executable, "-m", "repro.launch.full_graph_infer",
            "--store", store, "--shards", str(shards),
            "--gather", "alltoall", "--t-max", str(T_MAX),
            "--t-s-quantile", "0.5"]


def _run_cli(cmd: List[str], shards: int, *,
             expect: int = 0) -> Dict:
    t0 = time.time()
    p = subprocess.run(cmd, env=_env(shards), capture_output=True,
                       text=True, timeout=3600)
    wall = time.time() - t0
    if p.returncode != expect:
        raise RuntimeError(
            f"driver exited {p.returncode} (expected {expect}):\n"
            f"{p.stdout}\n{p.stderr}")
    summary: Optional[Dict] = None
    for line in p.stdout.splitlines():
        if line.startswith("OFFLINE_SUMMARY "):
            summary = json.loads(line[len("OFFLINE_SUMMARY "):])
    return {"wall_s": round(wall, 3), "returncode": p.returncode,
            "summary": summary}


def _result_arrays(ckpt: str) -> Dict[str, np.ndarray]:
    return {name: np.load(os.path.join(ckpt, "result", name + ".npy"))
            for name in ("predictions", "exit_orders")}


def _parity(ckpt_a: str, ckpt_b: str) -> bool:
    a, b = _result_arrays(ckpt_a), _result_arrays(ckpt_b)
    return bool(
        np.array_equal(a["predictions"], b["predictions"])
        and np.array_equal(a["exit_orders"], b["exit_orders"]))


def _sigkill_run(cmd: List[str], ckpt: str, shards: int) -> int:
    """Launch the driver, SIGKILL it as soon as the step-0 payload dir
    appears (the superstep compile still ahead of it), return the
    (negative) returncode. Falls through with the real code if the run
    finished before the kill landed."""
    p = subprocess.Popen(cmd, env=_env(shards),
                         stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL)
    trigger = os.path.join(ckpt, "step_00000")
    deadline = time.time() + 3600
    while p.poll() is None and time.time() < deadline:
        if os.path.isdir(trigger):
            p.send_signal(signal.SIGKILL)
            break
        time.sleep(0.002)
    p.wait(timeout=600)
    return p.returncode


def _flip_byte(path: str) -> None:
    with open(path, "r+b") as fh:
        fh.seek(os.path.getsize(path) // 2)
        b = fh.read(1)
        fh.seek(-1, 1)
        fh.write(bytes([b[0] ^ 0xFF]))


def collect(smoke: bool = False, *, shards: int = 2,
            n: Optional[int] = None) -> Dict:
    n = n or (4000 if smoke else 100_000)
    feat, classes = (24, 5) if smoke else (32, 10)
    payload: Dict = {"smoke": bool(smoke), "n": n, "shards": shards,
                     "t_max": T_MAX, "feat_dim": feat,
                     "impl": "segment", "gather_mode": "alltoall",
                     "scenarios": {}}
    with tempfile.TemporaryDirectory() as d:
        store = os.path.join(d, "store")
        _gen_store(store, n, feat, classes, shards)
        base = _base_cmd(store, shards)

        # ------------------------------------------------------- clean
        ck_clean = os.path.join(d, "ck_clean")
        clean = _run_cli(base + ["--ckpt", ck_clean], shards)
        payload["scenarios"]["clean"] = clean
        print(f"# clean: wall={clean['wall_s']}s nodes_per_s="
              f"{clean['summary']['nodes_per_s']:.0f}", flush=True)

        # -------------------------------------------------- kill sweep
        sweep = []
        for k in range(T_MAX):
            ck = os.path.join(d, f"ck_kill{k}")
            _run_cli(base + ["--ckpt", ck, "--crash-after", str(k)],
                     shards, expect=EXIT_PREEMPTED)
            res = _run_cli(base + ["--ckpt", ck], shards)
            sweep.append({
                "crash_after": k, "wall_s": res["wall_s"],
                "resumed_from": res["summary"]["resumed_from"],
                "supersteps_run": res["summary"]["supersteps_run"],
                "parity": _parity(ck, ck_clean)})
            print(f"# kill_sweep k={k}: resumed_from="
                  f"{sweep[-1]['resumed_from']} "
                  f"parity={sweep[-1]['parity']}", flush=True)
        payload["scenarios"]["kill_sweep"] = sweep

        # ----------------------------------------------------- sigkill
        ck = os.path.join(d, "ck_sigkill")
        rc = _sigkill_run(base + ["--ckpt", ck], ck, shards)
        res = _run_cli(base + ["--ckpt", ck], shards)
        payload["scenarios"]["sigkill"] = {
            "killed_returncode": rc, "killed_mid_run": rc != 0,
            "resume_wall_s": res["wall_s"],
            "resumed_from": res["summary"]["resumed_from"],
            "parity": _parity(ck, ck_clean)}
        print(f"# sigkill: rc={rc} resumed_from="
              f"{res['summary']['resumed_from']} "
              f"parity={payload['scenarios']['sigkill']['parity']}",
              flush=True)

        # ----------------------------------------------------- corrupt
        ck = os.path.join(d, "ck_corrupt")
        _run_cli(base + ["--ckpt", ck, "--crash-after", "2"], shards,
                 expect=EXIT_PREEMPTED)
        _flip_byte(os.path.join(ck, "step_00002", "x.npy"))
        res = _run_cli(base + ["--ckpt", ck], shards)
        payload["scenarios"]["corrupt"] = {
            "wall_s": res["wall_s"],
            "resumed_from": res["summary"]["resumed_from"],
            "corrupt_steps": res["summary"]["corrupt_steps"],
            "parity": _parity(ck, ck_clean)}
        print(f"# corrupt: resumed_from="
              f"{res['summary']['resumed_from']} corrupt_steps="
              f"{res['summary']['corrupt_steps']} "
              f"parity={payload['scenarios']['corrupt']['parity']}",
              flush=True)
    return payload


# ------------------------------------------------------------- gating
def check(payload: Dict) -> List[str]:
    errs: List[str] = []
    sc = payload["scenarios"]
    s = sc["clean"]["summary"]
    if s is None:
        errs.append("clean: no OFFLINE_SUMMARY line in driver output")
        return errs
    if s["supersteps_run"] != payload["t_max"]:
        errs.append(f"clean: ran {s['supersteps_run']} supersteps, "
                    f"expected {payload['t_max']}")
    hist = s["exit_histogram"]
    if sum(hist) != payload["n"]:
        errs.append(f"clean: exit histogram sums to {sum(hist)}, "
                    f"not n={payload['n']}")
    if s["ckpt_bytes"] <= 0:
        errs.append("clean: no checkpoint bytes recorded")
    for rec in sc["kill_sweep"]:
        if rec["resumed_from"] != rec["crash_after"]:
            errs.append(f"kill_sweep k={rec['crash_after']}: resumed "
                        f"from {rec['resumed_from']}, not the committed "
                        f"superstep")
        if rec["supersteps_run"] != payload["t_max"] - rec["crash_after"]:
            errs.append(f"kill_sweep k={rec['crash_after']}: recomputed "
                        f"{rec['supersteps_run']} supersteps instead of "
                        f"{payload['t_max'] - rec['crash_after']}")
        if not rec["parity"]:
            errs.append(f"kill_sweep k={rec['crash_after']}: resumed "
                        f"run diverged from the uninterrupted one")
    sk = sc["sigkill"]
    if not sk["killed_mid_run"]:
        errs.append("sigkill: the run finished before the kill landed "
                    "— nothing was exercised")
    if not sk["parity"]:
        errs.append("sigkill: resumed run diverged from the "
                    "uninterrupted one")
    co = sc["corrupt"]
    if co["corrupt_steps"] < 1:
        errs.append("corrupt: the flipped payload was never detected")
    if co["resumed_from"] >= 2:
        errs.append(f"corrupt: resume did not fall back past the "
                    f"corrupt superstep (resumed_from="
                    f"{co['resumed_from']})")
    if not co["parity"]:
        errs.append("corrupt: resumed run diverged from the "
                    "uninterrupted one")
    if not payload["smoke"]:
        if payload["n"] < 100_000:
            errs.append(f"full mode requires a >=1e5-node store, "
                        f"got n={payload['n']}")
        if payload["shards"] < 2:
            errs.append(f"full mode requires >=2 shards, got "
                        f"{payload['shards']}")
    return errs


def _rows(payload: Dict) -> List[str]:
    s = payload["scenarios"]["clean"]["summary"]
    rows = [csv_row(
        f"offline/clean_n{payload['n']}_d{payload['shards']}",
        1e6 * payload["scenarios"]["clean"]["wall_s"],
        f"nodes_per_s={s['nodes_per_s']:.0f};"
        f"node_steps_per_s={s['node_steps_per_s']:.0f};"
        f"ckpt_overhead_frac={s['ckpt_overhead_frac']:.4f};"
        f"ckpt_bytes={s['ckpt_bytes']};"
        f"exit_histogram={'/'.join(map(str, s['exit_histogram']))}")]
    for rec in payload["scenarios"]["kill_sweep"]:
        rows.append(csv_row(
            f"offline/kill_after_{rec['crash_after']}",
            1e6 * rec["wall_s"],
            f"resumed_from={rec['resumed_from']};"
            f"supersteps_run={rec['supersteps_run']};"
            f"parity={rec['parity']}"))
    sk = payload["scenarios"]["sigkill"]
    rows.append(csv_row(
        "offline/sigkill", 1e6 * sk["resume_wall_s"],
        f"killed_mid_run={sk['killed_mid_run']};"
        f"resumed_from={sk['resumed_from']};parity={sk['parity']}"))
    co = payload["scenarios"]["corrupt"]
    rows.append(csv_row(
        "offline/corrupt", 1e6 * co["wall_s"],
        f"resumed_from={co['resumed_from']};"
        f"corrupt_steps={co['corrupt_steps']};parity={co['parity']}"))
    return rows


def run() -> list:
    return _rows(collect(smoke=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small store / short runs (CI smoke job)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on a parity/resume/detection "
                         "gate failure")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--n", type=int, default=None,
                    help="store size (default 4000 smoke / 100000 full)")
    ap.add_argument("--out", default="",
                    help="JSON output path (default: merge under the "
                         "'offline' key of BENCH_serving.json; with "
                         "--smoke, standalone BENCH_offline_smoke.json)")
    args = ap.parse_args()
    payload = collect(smoke=args.smoke, shards=args.shards, n=args.n)
    print("name,us_per_call,derived")
    for r in _rows(payload):
        print(r, flush=True)
    if args.out:
        out_path, merge = args.out, args.out == "BENCH_serving.json"
    elif args.smoke:
        out_path, merge = "BENCH_offline_smoke.json", False
    else:
        out_path, merge = "BENCH_serving.json", True
    write_bench_json(out_path, payload,
                     section="offline" if merge else None)
    if args.check:
        errs = check(payload)
        for e in errs:
            print(f"CHECK FAIL: {e}", file=sys.stderr)
        if errs:
            sys.exit(1)
        print("# all offline gates passed")


if __name__ == "__main__":
    main()
