"""Training launcher.

Two modes:
  * LM:   train any assigned architecture (reduced or full) on the synthetic
          token stream with pjit over the available mesh, AdamW, remat,
          checkpointing.
  * GNN:  the paper's training procedure (base model + Inception
          Distillation) on a synthetic graph dataset.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \
        --steps 200 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --gnn pubmed-like --k 4
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.common import TrainConfig
from repro.configs import ARCHS, get_config, smoke
from repro.data import synthetic_stream
from repro.models import decoder_lm as M
from repro.nn.params import count_params
from repro.optim import adamw_init, adamw_update, make_schedule


def train_lm(args) -> None:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                     total_steps=args.steps, weight_decay=0.01)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"[train] {cfg.name} params={count_params(params):,}")
    opt = adamw_init(params, tc)
    sched = make_schedule(tc)

    step_count = 0
    if args.resume and os.path.exists(args.ckpt):
        state, step_count = load_checkpoint(args.ckpt,
                                            {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"[train] resumed from step {step_count}")

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, argnums=1, has_aux=True)(cfg, params, batch)
        params, opt, om = adamw_update(grads, opt, params, tc,
                                       sched(opt["count"]))
        metrics.update(om)
        return params, opt, metrics

    stream = synthetic_stream(args.seed, args.batch, args.seq,
                              cfg.vocab_size, cfg)
    t0 = time.time()
    for i in range(step_count, args.steps):
        raw = next(stream)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, metrics = step(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"lm={float(metrics['lm_loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if args.ckpt and i > 0 and i % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {"params": params, "opt": opt}, i)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt}, args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")


def train_gnn(args) -> None:
    from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, accuracy,
                          infer_all, load_dataset, train_nai)
    g = load_dataset(args.gnn, scale=args.scale, seed=args.seed)
    cfg = GNNConfig(args.base_model, g.features.shape[1], g.num_classes,
                    k=args.k, hidden=args.hidden, mlp_layers=2, dropout=0.1)
    dc = DistillConfig(epochs_base=args.epochs, epochs_offline=args.epochs // 2,
                       epochs_online=args.epochs // 2)
    print(f"[train-gnn] {args.gnn} n={g.n} m={g.num_edges} "
          f"base={args.base_model} k={cfg.k}")
    t0 = time.time()
    params, info = train_nai(cfg, g, dc)
    print(f"[train-gnn] done in {time.time() - t0:.1f}s: "
          f"{ {k: round(v, 4) for k, v in info.items()} }")
    res = infer_all(cfg, NAIConfig(t_s=args.t_s, t_min=1, t_max=cfg.k // 2 + 1,
                                   batch_size=500), params, g)
    print(f"[train-gnn] NAI acc={accuracy(res, g):.4f} "
          f"fp_macs/node={res.fp_macs:.0f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, args.epochs)
        print(f"[train-gnn] checkpoint -> {args.ckpt}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--gnn", default=None)
    ap.add_argument("--base-model", default="sgc")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--t-s", type=float, default=16.0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    if args.gnn:
        train_gnn(args)
    elif args.arch:
        train_lm(args)
    else:
        ap.error("need --arch or --gnn")


if __name__ == "__main__":
    main()
