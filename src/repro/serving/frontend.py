"""Deadline-aware serving front-end with SLO classes.

Production traffic is a Poisson stream of single requests with
heterogeneous deadlines — not the pre-formed fixed-size batches the
engine's closed-loop benchmarks feed it. The front-end turns the former
into the latter:

* **Request queue with backpressure** — each SLO class owns a bounded
  lane (`queue_depth`); a submit beyond the bound is rejected (shed)
  immediately instead of queued into a certain deadline miss. Shedding
  keeps the queueing delay of every ACCEPTED request bounded by
  roughly `queue_depth / service_rate`, which is what lets goodput track
  throughput under overload instead of collapsing.

* **Deadline-aware batch former** — dispatch rides the engine's
  `form_batch`: a batch closes on size OR age, whichever fires first
  (a full `batch_size` immediately; a partial batch once its oldest
  request has waited the class's `max_wait_s` — unconditionally, with
  no minimum-fill guard). `step(now)` polls every lane; quiet ticks
  advance the engine pipelines non-blockingly, so `pipeline_depth=2`
  engines keep their host/device overlap under bursty arrivals.

* **SLO classes** — the paper's deployment claim is that "the trade-off
  between accuracy and inference latency can be flexibly controlled by
  simple hyper-parameters to match different latency constraints of
  application scenarios": T_max/T_min are those hyper-parameters, and
  the front-end turns them into per-request latency tiers. Each class
  (e.g. ``gold`` / ``best_effort``) routes to its own
  `NAIServingEngine` compiled at the class's `NAIConfig` — gold at a
  high T_max (full accuracy, more propagation), best-effort at a low
  one (cheap, fast) — while the {1,2,3}·2^k bucket policy keeps each
  engine's compiled-shape set small. A request's class picks its
  engine; its deadline (class default or per-request override) is
  carried on the `Request` and scored at completion.

**Goodput** — answers delivered within their deadline — is the
front-end's currency: `ClassStats` counts offered / accepted / rejected
/ completed / deadline hits+misses per class, and `summary()` merges
those with the per-engine latency percentiles. `benchmarks/
frontend_bench.py` sweeps offered load open-loop and records the
goodput-vs-load curve into BENCH_serving.json.

Every method takes an optional ``now`` so the whole front-end can run on
a virtual clock: batch formation then depends only on the submitted
timestamps, making runs deterministic — the property the parity tests
(front-end == direct engine serving, pipelined == serial) and the
zero-steady-state-compile gates are built on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.gnn.nai import NAIConfig
from repro.serving.engine import (EngineConfig, EngineStats, LatencyRing,
                                  NAIServingEngine, Request)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One latency tier: a name, the engine config it compiles
    (the T_max knob), its default per-request latency budget, the batch
    former's age bound, and the backpressure depth of its lane.

    ``engine`` optionally pins a full per-class `EngineConfig` (e.g. a
    different spmm_impl or pipeline depth per tier); classes that leave
    it None inherit the front-end's base config. Either way the class's
    ``max_wait_s`` overrides the config's age bound — the SLO class owns
    its latency knobs."""
    name: str
    nai: NAIConfig
    deadline_s: float            # default latency budget per request
    max_wait_s: float            # close a partial batch at this age
    queue_depth: int = 256       # reject (shed) submits beyond this
    engine: Optional[EngineConfig] = None   # per-class engine override

    def __post_init__(self):
        if not self.name:
            raise ValueError("SLO class needs a non-empty name")
        if self.deadline_s <= 0:
            raise ValueError(f"{self.name}: deadline_s must be > 0, "
                             f"got {self.deadline_s}")
        if self.max_wait_s < 0:
            raise ValueError(f"{self.name}: max_wait_s must be >= 0, "
                             f"got {self.max_wait_s}")
        if self.queue_depth < 1:
            raise ValueError(f"{self.name}: queue_depth must be >= 1, "
                             f"got {self.queue_depth}")


def default_slo_classes(base: NAIConfig, *, gold_deadline_s: float = 0.5,
                        best_effort_deadline_s: float = 0.2,
                        gold_max_wait_s: float = 0.05,
                        best_effort_max_wait_s: float = 0.02,
                        queue_depth: Optional[int] = None
                        ) -> Sequence[SLOClass]:
    """The two-tier default: ``gold`` serves at the base config's full
    T_max (accuracy tier), ``best_effort`` at T_max = T_min (cheapest
    compiled shape, fastest answer). Both reuse the base batch size so
    their bucket series coincide."""
    qd = queue_depth if queue_depth is not None else 4 * base.batch_size
    return (
        SLOClass("gold", base, deadline_s=gold_deadline_s,
                 max_wait_s=gold_max_wait_s, queue_depth=qd),
        SLOClass("best_effort",
                 dataclasses.replace(base, t_max=base.t_min),
                 deadline_s=best_effort_deadline_s,
                 max_wait_s=best_effort_max_wait_s, queue_depth=qd),
    )


@dataclasses.dataclass
class ClassStats:
    offered: int = 0          # every submit attempt
    accepted: int = 0         # made it past backpressure
    rejected: int = 0         # shed at submit (lane full)
    completed: int = 0
    deadline_hits: int = 0    # completed within budget (goodput)
    deadline_misses: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "offered": self.offered, "accepted": self.accepted,
            "rejected": self.rejected, "completed": self.completed,
            "deadline_hits": self.deadline_hits,
            "deadline_misses": self.deadline_misses,
            "goodput_frac": self.deadline_hits / max(self.offered, 1),
        }


class ServingFrontend:
    """Routes single requests into per-SLO-class `NAIServingEngine`s.

    ``classes`` is an ordered sequence of `SLOClass`; the first is the
    default routing target. The base engine configuration comes either
    as one ``engine=EngineConfig(...)`` or as the legacy keyword
    arguments (``mode=``, ``spmm_impl=``, ``mesh=``, ...) — not both.
    Each class engine gets the base config (or the class's own
    ``engine`` override) with the class's `NAIConfig` and `max_wait_s`
    substituted in, so per-SLO-class engine configs are declarative.
    """

    def __init__(self, cfg, params, graph,
                 classes: Sequence[SLOClass], *,
                 engine: Optional[EngineConfig] = None,
                 mode: str = "compiled", pipeline_depth: int = 1,
                 latency_window: int = 4096, **engine_kwargs):
        if not classes:
            raise ValueError("need at least one SLO class")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO class names: {names}")
        if engine is not None and engine_kwargs:
            raise ValueError(
                f"pass either engine=EngineConfig(...) or engine kwargs, "
                f"not both (got kwargs {sorted(engine_kwargs)})")
        base = engine if engine is not None else EngineConfig(
            mode=mode, pipeline_depth=pipeline_depth,
            latency_window=latency_window, **engine_kwargs)
        self.classes: Dict[str, SLOClass] = {c.name: c for c in classes}
        self.default_class = classes[0].name
        self.engine_config = base
        self.pipeline_depth = base.pipeline_depth
        self.engines: Dict[str, NAIServingEngine] = {
            c.name: NAIServingEngine(
                cfg, c.nai, params, graph,
                config=dataclasses.replace(
                    c.engine if c.engine is not None else base,
                    max_wait_s=c.max_wait_s))
            for c in classes}
        self.stats: Dict[str, ClassStats] = {
            c.name: ClassStats() for c in classes}

    # ---------------------------------------------------------- ingress
    def submit(self, node_id: int, slo_class: Optional[str] = None,
               now: Optional[float] = None,
               budget_s: Optional[float] = None) -> Optional[Request]:
        """Route one request into its class lane. Returns the `Request`
        if accepted, None if shed by backpressure (lane at
        `queue_depth`). ``budget_s`` overrides the class's default
        latency budget; the absolute deadline is stamped on the request
        as ``arrival + budget``."""
        name = self.default_class if slo_class is None else slo_class
        if name not in self.classes:
            raise KeyError(f"unknown SLO class {name!r} "
                           f"(one of {sorted(self.classes)})")
        c, eng, st = self.classes[name], self.engines[name], self.stats[name]
        st.offered += 1
        if len(eng.queue) >= c.queue_depth:
            st.rejected += 1
            return None
        now = time.perf_counter() if now is None else now
        budget = c.deadline_s if budget_s is None else budget_s
        req = Request(int(node_id), now, deadline_s=now + budget,
                      slo_class=name)
        eng.submit_request(req)
        st.accepted += 1
        return req

    # ----------------------------------------------------------- egress
    def _account(self, completed: List[Request]) -> List[Request]:
        for r in completed:
            st = self.stats[r.slo_class]
            st.completed += 1
            if r.within_deadline:
                st.deadline_hits += 1
            else:
                st.deadline_misses += 1
        return completed

    def step(self, now: Optional[float] = None) -> List[Request]:
        """Poll every class lane once: dispatch batches the former has
        closed (size or age), advance pipelines non-blockingly
        otherwise. Returns newly completed requests across classes."""
        done: List[Request] = []
        for eng in self.engines.values():
            done += self._account(eng.poll(now))
        return done

    def flush(self) -> List[Request]:
        """Explicit drain: force-close every partial batch still queued,
        then sync every in-flight batch. The end-of-stream path — never
        called on the hot serving loop."""
        done: List[Request] = []
        for eng in self.engines.values():
            while eng.queue:
                done += self._account(eng.step())
            done += self._account(eng.flush())
        return done

    # ------------------------------------------------------------ stats
    def pending(self) -> int:
        """Requests accepted but not yet completed (queued + in flight)."""
        return sum(len(eng.queue)
                   + sum(len(fl.requests) for fl in eng._inflight)
                   for eng in self.engines.values())

    def reset_stats(self) -> None:
        """Zero the per-class counters and per-engine latency stats
        (bench warm-up boundary). Compile caches, pack pools, and
        high-water marks are deliberately kept — steady state is the
        point of resetting."""
        for name, eng in self.engines.items():
            eng.stats = EngineStats(
                latencies=LatencyRing(eng.stats.latencies.capacity))
            eng.batch_timings.clear()
            self.stats[name] = ClassStats()

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-class goodput counters merged with the class engine's
        latency percentiles and structural counters."""
        out: Dict[str, Dict[str, float]] = {}
        for name, eng in self.engines.items():
            s = self.stats[name].summary()
            es = eng.stats.summary()
            s.update(p50_ms=es["p50_ms"], p95_ms=es["p95_ms"],
                     p99_ms=es["p99_ms"], batches=es["batches"],
                     jit_compiles=eng.jit_stats["compiles"],
                     pack_allocs=eng.pack_stats["allocs"])
            out[name] = s
        return out
