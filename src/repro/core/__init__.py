"""The paper's contribution, as composable modules:

* `repro.gnn.nai`            — Node-Adaptive Inference (Algorithm 1), faithful
* `repro.gnn.distill`        — Inception Distillation for propagation-order
                               classifiers (Eqs. 2-6), faithful
* `repro.core.inception_distill` — the distillation primitives, shared
* `repro.core.adaptive_depth`    — the technique generalized to early-exit
                               transformer inference (beyond-paper)
"""
from repro.core import inception_distill

__all__ = ["inception_distill"]
