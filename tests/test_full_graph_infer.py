"""Offline full-graph inference driver tests.

The contract under test: `run_full_graph_infer` classifies every node
BIT-IDENTICALLY to the serving compiled path over the same full-graph
pack (the superstep chain is the fori-loop body, one dispatch per
step), and a run killed after ANY superstep resumes to the exact same
predictions and exit orders. Fault stages (ckpt_write / ckpt_read /
superstep_hang) exercise the tolerate/fallback/retry paths without
breaking parity. The sharded (D=2) CLI kill/resume runs in a
subprocess so the forced host-device count stays isolated."""
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from repro.gnn.backends import pack_operands
from repro.gnn.distributed import pack_graph
from repro.gnn.models import GNNConfig, init_classifiers
from repro.gnn.nai import NAIConfig, make_compiled_infer
from repro.gnn.store import make_graph
from repro.launch.full_graph_infer import (OfflineConfig,
                                           PreemptionSimulated,
                                           first_step_distance_quantile,
                                           run_full_graph_infer)
from repro.serving.faults import FaultPlan, FaultSpec, WatchdogTimeout

T_MAX = 3


@pytest.fixture(scope="module")
def setup():
    store = make_graph(800, avg_deg=6.0, alpha=2.2, seed=3, path=None,
                       feat_dim=24, num_classes=5)
    t_s = first_step_distance_quantile(store, 0.5, 0.5)
    cfg = GNNConfig("sgc", store.feat_dim, store.num_classes, k=T_MAX,
                    r=0.5, hidden=16, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=t_s, t_min=1, t_max=T_MAX)
    with tempfile.TemporaryDirectory() as d:
        ref = run_full_graph_infer(store, cfg, params, nai,
                                   OfflineConfig(ckpt_dir=d + "/ck"))
    # a useful reference exercises BOTH early and late exits
    hist = ref.stats["exit_histogram"]
    assert hist[1] > 0 and hist[T_MAX] > 0, hist
    return store, cfg, params, nai, ref


def _run(setup, tmp, **kw):
    store, cfg, params, nai, _ = setup
    plan = kw.pop("fault_plan", None)
    return run_full_graph_infer(store, cfg, params, nai,
                                OfflineConfig(ckpt_dir=tmp, **kw),
                                fault_plan=plan)


def _assert_parity(res, ref):
    np.testing.assert_array_equal(res.predictions, ref.predictions)
    np.testing.assert_array_equal(res.exit_orders, ref.exit_orders)


# --------------------------------------------------- oracle bit-parity
def test_bit_identical_to_serving_compiled_path(setup):
    """The acceptance oracle: the checkpointed superstep chain must
    equal make_compiled_infer (the serving path) on the identical
    full-graph pack — exact equality, not a tolerance."""
    import jax.numpy as jnp
    store, cfg, params, nai, ref = setup
    be, packed = pack_graph(store, 1, cfg.r, "segment", stationary=True)
    ops = {k: jnp.asarray(v)
           for k, v in pack_operands(be, packed).items()}
    run = make_compiled_infer(cfg, nai, spmm_impl="segment",
                              interpret=True)
    preds, eo = run(params["cls"], ops, jnp.asarray(packed.x0),
                    jnp.asarray(packed.x_inf))
    np.testing.assert_array_equal(ref.predictions,
                                  np.asarray(preds)[:store.n])
    np.testing.assert_array_equal(ref.exit_orders,
                                  np.asarray(eo)[:store.n])


@pytest.mark.parametrize("impl", ["block_ell", "fused"])
def test_tile_backends_match(setup, impl, tmp_path):
    res = _run(setup, str(tmp_path / "ck"), spmm_impl=impl)
    _assert_parity(res, setup[4])


# ------------------------------------------------- kill/resume parity
def test_kill_at_every_superstep_resumes_bit_identical(setup, tmp_path):
    """The tentpole property: for every superstep k, a run preempted
    right after committing k and then rerun produces exactly the
    uninterrupted run's outputs, resuming from k (no recompute of the
    committed prefix)."""
    ref = setup[4]
    for k in range(T_MAX):
        ck = str(tmp_path / f"kill{k}")
        with pytest.raises(PreemptionSimulated):
            _run(setup, ck, crash_after=k)
        res = _run(setup, ck)
        assert res.stats["resumed_from"] == k
        assert res.stats["supersteps_run"] == T_MAX - k
        _assert_parity(res, ref)


def test_repeated_preemption_and_completed_rerun(setup, tmp_path):
    """Die after every single superstep in sequence (the worst
    preemption schedule), then once more on the completed directory —
    the final rerun resumes at t_max, runs zero supersteps, and still
    emits the exact outputs."""
    ck = str(tmp_path / "ck")
    for k in range(T_MAX):
        with pytest.raises(PreemptionSimulated):
            _run(setup, ck, crash_after=k)
    res = _run(setup, ck)
    _assert_parity(res, setup[4])
    again = _run(setup, ck)
    assert again.stats["resumed_from"] == T_MAX
    assert again.stats["supersteps_run"] == 0
    _assert_parity(again, setup[4])


def test_no_resume_ignores_existing_checkpoints(setup, tmp_path):
    ck = str(tmp_path / "ck")
    with pytest.raises(PreemptionSimulated):
        _run(setup, ck, crash_after=1)
    res = _run(setup, ck, resume=False)
    assert res.stats["resumed_from"] == 0
    assert res.stats["supersteps_run"] == T_MAX
    _assert_parity(res, setup[4])


# ------------------------------------------------------- fault stages
def test_corrupt_checkpoint_falls_back_one_superstep(setup, tmp_path):
    ck = str(tmp_path / "ck")
    with pytest.raises(PreemptionSimulated):
        _run(setup, ck, crash_after=2)
    path = os.path.join(ck, "step_00002", "x.npy")
    with open(path, "r+b") as fh:
        fh.seek(os.path.getsize(path) // 2)
        b = fh.read(1)
        fh.seek(-1, 1)
        fh.write(bytes([b[0] ^ 0xFF]))
    res = _run(setup, ck)
    assert res.stats["resumed_from"] == 1
    assert res.stats["corrupt_steps"] == 1
    assert res.stats["fallbacks"]
    _assert_parity(res, setup[4])


def test_ckpt_write_fault_is_tolerated_and_resume_falls_back(
        setup, tmp_path):
    """A failed checkpoint write (payloads on disk, manifest never
    committed) must not kill the run; a subsequent crash resumes from
    the last step that DID commit — with intact parity."""
    ck = str(tmp_path / "ck")
    plan = FaultPlan([FaultSpec("ckpt_write", at=(2,))])
    with pytest.raises(PreemptionSimulated):
        _run(setup, ck, crash_after=T_MAX, fault_plan=plan)
    res = _run(setup, ck)
    assert res.stats["resumed_from"] < T_MAX
    _assert_parity(res, setup[4])


def test_ckpt_read_fault_at_resume_falls_back(setup, tmp_path):
    ck = str(tmp_path / "ck")
    with pytest.raises(PreemptionSimulated):
        _run(setup, ck, crash_after=2)
    plan = FaultPlan([FaultSpec("ckpt_read", at=(0,))])
    res = _run(setup, ck, fault_plan=plan)
    assert res.stats["corrupt_steps"] >= 1
    _assert_parity(res, setup[4])


def test_superstep_hang_retries_deterministically(setup, tmp_path):
    plan = FaultPlan([FaultSpec("superstep_hang", at=(0,),
                                max_fires=1)])
    res = _run(setup, str(tmp_path / "ck"), fault_plan=plan)
    assert res.stats["watchdog_retries"] == 1
    assert res.stats["injected"]["superstep_hang"]["fired"] == 1
    _assert_parity(res, setup[4])


def test_superstep_hang_every_attempt_times_out(setup, tmp_path):
    plan = FaultPlan([FaultSpec("superstep_hang", rate=1.0)])
    with pytest.raises(WatchdogTimeout):
        _run(setup, str(tmp_path / "ck"), fault_plan=plan)


def test_config_validation():
    with pytest.raises(ValueError, match="ckpt_dir"):
        OfflineConfig(ckpt_dir="")
    with pytest.raises(ValueError, match="watchdog_s"):
        OfflineConfig(ckpt_dir="x", watchdog_s=-1)
    with pytest.raises(ValueError, match="straggler_factor"):
        OfflineConfig(ckpt_dir="x", straggler_factor=1.0)
    with pytest.raises(ValueError, match="crash_after"):
        OfflineConfig(ckpt_dir="x", crash_after=-1)


# ------------------------------------------- sharded CLI kill/resume
SCRIPT = r"""
import os, sys, subprocess, tempfile
import numpy as np

root = os.getcwd()
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(root, "src")
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

with tempfile.TemporaryDirectory() as d:
    store = os.path.join(d, "store")
    subprocess.run([sys.executable, "-c",
        "from repro.gnn.store import make_graph; import sys; "
        "make_graph(4000, avg_deg=6.0, alpha=2.2, seed=5, "
        "path=sys.argv[1], feat_dim=24, num_classes=7)", store],
        env=env, check=True)
    base = [sys.executable, "-m", "repro.launch.full_graph_infer",
            "--store", store, "--shards", "2", "--gather", "alltoall",
            "--t-max", "3", "--t-s-quantile", "0.5"]

    ck_a = os.path.join(d, "ck_clean")
    subprocess.run(base + ["--ckpt", ck_a], env=env, check=True)

    ck_b = os.path.join(d, "ck_kill")
    p = subprocess.run(base + ["--ckpt", ck_b, "--crash-after", "1"],
                       env=env)
    assert p.returncode == 17, p.returncode
    subprocess.run(base + ["--ckpt", ck_b], env=env, check=True)

    for name in ("predictions", "exit_orders"):
        a = np.load(os.path.join(ck_a, "result", name + ".npy"))
        b = np.load(os.path.join(ck_b, "result", name + ".npy"))
        assert np.array_equal(a, b), name
print("SHARDED_OFFLINE_OK")
"""


def test_sharded_cli_kill_resume_parity():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=900)
    assert "SHARDED_OFFLINE_OK" in out.stdout, out.stdout + out.stderr
