"""Sharded serving acceptance: pipelined × sharded must equal serial ×
single-device — identical completion order, predictions, and exit orders
for every registered backend at multiple shard counts — with zero
steady-state jit compiles and zero steady-state pack allocations. Runs
in a subprocess that forces 8 host devices (keep it isolated)."""
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, numpy as np
from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.launch.mesh import make_serving_mesh
from repro.serving import NAIServingEngine

g = load_dataset("pubmed-like", scale=0.02, seed=4)
g = dataclasses.replace(g, features=np.ascontiguousarray(g.features[:, :64]))
cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
rng = np.random.default_rng(0)
stream = [rng.choice(g.test_idx, size=s, replace=False)
          for s in (32, 30, 32, 28)]

def serve(eng):
    done = []
    for nodes in stream:
        eng.submit(nodes)
        done += eng.step()
    done += eng.flush()
    return (np.array([r.node_id for r in done]),
            np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))

from repro.gnn.backends import BACKENDS
for impl in sorted(BACKENDS):
    base = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled", spmm_impl=impl)
    bn, bp, bo = serve(base)
    for D in (2, 4):
        eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                               mode="compiled", spmm_impl=impl,
                               pipeline_depth=2, mesh=make_serving_mesh(D))
        assert eng.n_shards == D
        sn, sp, so = serve(eng)
        assert np.array_equal(sn, bn), (impl, D)       # FIFO completion
        assert np.array_equal(sp, bp), (impl, D)       # predictions
        assert np.array_equal(so, bo), (impl, D)       # exit orders
        assert not eng._inflight
        serve(eng)                                     # pool converges
        c0, a0 = eng.jit_stats["compiles"], eng.pack_stats["allocs"]
        serve(eng)                                     # steady state
        assert eng.jit_stats["compiles"] == c0, (impl, D, eng.jit_stats)
        assert eng.pack_stats["allocs"] == a0, (impl, D, eng.pack_stats)
        assert eng.jit_cache_size() == c0, (impl, D)

# a degenerate 1-device mesh falls back to the plain single-device path
eng1 = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                        mode="compiled", spmm_impl="segment",
                        mesh=make_serving_mesh(1))
assert eng1.mesh is None and eng1.n_shards == 1
n1, p1, o1 = serve(eng1)

# mesh validation: host mode and data-axis-free meshes are rejected
import numpy as _np
from jax.sharding import Mesh
try:
    NAIServingEngine(cfg, nai, params, g, mode="host",
                     mesh=make_serving_mesh(2))
    raise SystemExit("host+mesh should have raised")
except ValueError:
    pass
try:
    NAIServingEngine(cfg, nai, params, g, mode="compiled",
                     mesh=Mesh(_np.array(jax.devices()[:2]), ("model",)))
    raise SystemExit("mesh without data axis should have raised")
except ValueError:
    pass
print("SHARDED_SERVING_OK")
"""


def test_sharded_serving_parity_and_steady_state():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=600)
    assert "SHARDED_SERVING_OK" in out.stdout, out.stdout + out.stderr
