"""whisper-small — audio encoder-decoder [arXiv:2212.04356].
12L decoder (+12L encoder), d_model 768, 12 heads, d_ff 3072, vocab 51865.
The mel-spectrogram + conv frontend is a STUB: input_specs provides
precomputed frame embeddings (B, 1500, d_model)."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    seq_shard_attn=True,
    pattern=("encdec",),
    mlp_kind="gelu",
    norm_kind="layernorm",
    use_rope=False,
    pos_embed="sinusoidal",
    encoder_layers=12,
    encoder_seq=1500,
)
