from repro.kernels.nap_exit.kernel import FB, NB, nap_exit
from repro.kernels.nap_exit.ops import exit_decision
from repro.kernels.nap_exit.ref import ref_nap_exit

__all__ = ["FB", "NB", "nap_exit", "exit_decision", "ref_nap_exit"]
