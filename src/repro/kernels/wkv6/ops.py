"""jit'd wrapper: multi-head RWKV6 time-mix core via the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.wkv6.kernel import CHUNK, wkv6


def wkv6_heads(r, k, v, logw, u, *, interpret: bool = True):
    """r/k/v/logw (B, T, H, hd) f32; u (H, hd). Pads T to CHUNK; returns
    (B, T, H, hd). Padding steps use logw=0 (no decay), k=0 — state-neutral,
    matching repro.nn.rwkv's masking."""
    B, T, H, hd = r.shape
    pad = (-T) % CHUNK
    def prep(x, neutral=0.0):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=neutral)
        return x.transpose(0, 2, 1, 3).reshape(B * H, T + pad, hd)
    rf, kf, vf, lwf = prep(r), prep(k), prep(v), prep(logw)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    out = wkv6(rf, kf, vf, lwf, uf, interpret=interpret)
    out = out.reshape(B, H, T + pad, hd).transpose(0, 2, 1, 3)
    return out[:, :T]
