"""NAI training procedure (paper Fig. 1 left): base-model training followed
by Inception Distillation (offline Eq. 2-4, then online Eq. 5-6)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import TrainConfig
from repro.core.inception_distill import (ensemble_teacher, hard_ce,
                                          offline_loss, soft_ce)
from repro.gnn.graph import Graph, propagated_series
from repro.gnn.models import GNNConfig, apply_classifier, init_classifiers
from repro.nn.params import ParamDef, init_tree
from repro.optim import adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class DistillConfig:
    epochs_base: int = 300
    epochs_offline: int = 200
    epochs_online: int = 200
    lr: float = 0.01
    weight_decay: float = 1e-4
    temperature: float = 1.2      # T   (paper: [1, 2])
    lam: float = 0.9              # λ   (paper: online best in [0.8, 1])
    lam_off: float = 0.5          # λ for offline (paper: balance carefully)
    ensemble_r: int = 2           # r
    seed: int = 0


def _tc(dc: DistillConfig) -> TrainConfig:
    return TrainConfig(learning_rate=dc.lr, weight_decay=dc.weight_decay,
                       grad_clip=0.0, warmup_steps=0,
                       total_steps=max(dc.epochs_base, 1), schedule="constant")


def _fit(loss_fn, params, steps, tc, key):
    state = adamw_init(params, tc)

    @jax.jit
    def step(params, state, key):
        key, sub = jax.random.split(key)
        loss, grads = jax.value_and_grad(loss_fn)(params, sub)
        params, state, _ = adamw_update(grads, state, params, tc,
                                        tc.learning_rate)
        return params, state, key, loss

    loss = jnp.inf
    for _ in range(steps):
        params, state, key, loss = step(params, state, key)
    return params, float(loss)


def train_nai(cfg: GNNConfig, g: Graph, dc: DistillConfig = DistillConfig()
              ) -> Tuple[Dict, Dict]:
    """Returns (params, info). params = {'cls': {l: tree}, 'ens_s': (c,1)}."""
    key = jax.random.PRNGKey(dc.seed)
    g_train = g.train_subgraph()
    series = propagated_series(g_train, g.features, cfg.k, cfg.r)
    feats = jnp.asarray(np.stack(series))                    # (k+1, n, f)
    labels = jnp.asarray(g.labels)
    vl = jnp.asarray(g.train_idx)                            # labeled V_l
    vtrain = jnp.asarray(np.concatenate([g.train_idx, g.unlabeled_idx]))
    tc = _tc(dc)

    key, k_init, k_base = jax.random.split(key, 3)
    cls = init_classifiers(cfg, k_init)
    info: Dict = {}

    feats_vl = feats[:, vl]
    feats_vt = feats[:, vtrain]
    y_vl = labels[vl]

    # ---- 1. base model f^(k) (Eq. 2)
    def base_loss(p, rng):
        z = apply_classifier(cfg, p, feats_vl, cfg.k, key=rng)
        return hard_ce(z, y_vl)

    cls[cfg.k], l0 = _fit(base_loss, cls[cfg.k], dc.epochs_base, tc, k_base)
    info["base_loss"] = l0

    # ---- 2. offline distillation into f^(l), l < k (Eqs. 3-4)
    teacher_vt = apply_classifier(cfg, cls[cfg.k], feats_vt, cfg.k)
    teacher_vl = apply_classifier(cfg, cls[cfg.k], feats_vl, cfg.k)
    for l in range(1, cfg.k):
        key, k_off = jax.random.split(key)

        def off_loss(p, rng, l=l):
            z_vt = apply_classifier(cfg, p, feats_vt, l, key=rng)
            z_vl = apply_classifier(cfg, p, feats_vl, l)
            kd = offline_loss(z_vt, teacher_vt, labels[vtrain],
                              temperature=dc.temperature, lam=1.0)
            ce = hard_ce(z_vl, y_vl)
            return (1 - dc.lam_off) * ce + dc.lam_off * kd

        cls[l], li = _fit(off_loss, cls[l], dc.epochs_offline, tc, k_off)
        info[f"offline_loss_{l}"] = li

    # ---- 3. online distillation with the self-attention ensemble (Eqs. 5-6)
    ens_s = init_tree(key, ParamDef((cfg.num_classes, 1), (None, None),
                                    "small"), "float32")
    joint = {"cls": cls, "ens_s": ens_s}
    r = min(dc.ensemble_r, cfg.k)

    def on_loss(p, rng):
        zs = {l: apply_classifier(cfg, p["cls"][l], feats_vt, l)
              for l in range(1, cfg.k + 1)}
        pool = [zs[l] for l in range(cfg.k - r + 1, cfg.k + 1)]
        ens = ensemble_teacher(pool, p["ens_s"])
        total = 0.0
        for l in range(1, cfg.k):
            # L_on = (1-λ)·L_c(V_l, hard labels) + λ·T²·L_e(V_train, ensemble)
            kd = soft_ce(zs[l], ens, dc.temperature)
            z_vl = apply_classifier(cfg, p["cls"][l], feats_vl, l, key=rng)
            total += dc.lam * dc.temperature**2 * kd \
                + (1 - dc.lam) * hard_ce(z_vl, y_vl)
        return total / max(cfg.k - 1, 1)

    key, k_on = jax.random.split(key)
    joint, lo = _fit(on_loss, joint, dc.epochs_online, tc, k_on)
    info["online_loss"] = lo
    return joint, info


def evaluate_classifier(cfg: GNNConfig, params, feats, labels, idx, l) -> float:
    z = apply_classifier(cfg, params, jnp.asarray(feats)[:, idx], l)
    pred = jnp.argmax(z, -1)
    return float(jnp.mean((pred == jnp.asarray(labels)[idx]).astype(jnp.float32)))
