"""Loop-aware analysis of compiled (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE and reports
per-device numbers — useless for scan-based models (an 88-layer trunk scan
under-counts 88x). This module parses the optimized HLO, builds the
computation call graph, multiplies while bodies by their trip counts, and
derives:

  * dot_flops        — exact MXU FLOPs (2 * prod(result) * contracted dim)
  * collective_bytes — per collective kind, result-shape bytes (per device)
  * traffic_bytes    — HBM traffic proxy: every top-level (unfused) op
                       result is written once + read once (2x result bytes);
                       entry parameters add their size once.

All values are PER-DEVICE (post-SPMD shapes are per-participant).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-_]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?(%?[\w.\-_]+)\s*=\s*((?:\([^=]*?\))|(?:[\w\[\],{}\s]+?))\s*"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    dims = m.group(2)
    size = 1
    if dims:
        for d in dims.split(","):
            size *= int(d)
    return size


@dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    is_fusion_body: bool = False


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        m = _COMP_RE.match(line)
        if m:
            name = m.group(1).lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1).lstrip("%"), m.group(2).strip(),
                              m.group(3), m.group(4)))
    return comps


def _callee(rest: str, attr: str) -> Optional[str]:
    m = re.search(attr + r"=(%?[\w.\-_]+)", rest)
    return m.group(1).lstrip("%") if m else None


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.kind in ("compare", "constant"):
            for c in _CONST_CMP_RE.findall(op.shape + "(" + op.rest):
                best = max(best, int(c))
        for c in re.findall(r"constant\((\d+)\)", op.rest):
            best = max(best, int(c))
    # also scan raw constants defined in the condition
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def computation_multiplicities(comps: Dict[str, Computation],
                               entry: str) -> Dict[str, float]:
    """DFS from entry; while bodies multiply by trip count."""
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op in comps[name].ops:
            if op.kind == "while":
                body = _callee(op.rest, "body")
                cond = _callee(op.rest, "condition")
                tm = re.search(r'known_trip_count..:..n.:.(\d+)', op.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, m * trip)
                if cond:
                    visit(cond, m * (trip + 1))
            elif op.kind in ("fusion", "call", "custom-call", "map",
                             "reduce", "reduce-window", "scatter", "sort",
                             "all-reduce", "reduce-scatter", "select-and-scatter"):
                c = _callee(op.rest, "calls") or _callee(op.rest, "to_apply")
                if c:
                    visit(c, m)
            elif op.kind == "conditional":
                for attr in ("true_computation", "false_computation"):
                    c = _callee(op.rest, attr)
                    if c:
                        visit(c, m)
                for c in re.findall(r"branch_computations=\{([^}]*)\}", op.rest):
                    for b in c.split(","):
                        visit(b.strip().lstrip("%"), m)
    visit(entry, 1.0)
    return mult


def _find_entry(text: str, comps: Dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+(%?[\w.\-_]+)", text, re.M)
    if m:
        return m.group(1).lstrip("%")
    return next(iter(comps))


def _dot_flops(comps: Dict[str, Computation], comp: Computation,
               name_shape: Dict[str, str]) -> float:
    total = 0.0
    for op in comp.ops:
        if op.kind not in ("dot",):
            continue
        out_elems = _shape_elems(op.shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        contract = 1
        if m:
            operand_region = op.rest.split(")")[0]
            # newer HLO text inlines operand shapes: dot(f32[64,64]{1,0}
            # %lhs, ...) — take the first inline shape as the lhs shape,
            # falling back to the defining op's shape by operand name
            sm = _SHAPE_RE.search(operand_region)
            if sm is None:
                args = re.findall(r"%?([\w.\-_]+)", operand_region)
                lhs_shape = name_shape.get(args[0], "") if args else ""
                sm = _SHAPE_RE.search(lhs_shape)
            if sm and m.group(1):
                dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
                for ci in m.group(1).split(","):
                    ci = int(ci)
                    if ci < len(dims):
                        contract *= dims[ci]
        total += 2.0 * out_elems * contract
    return total


@dataclass
class HloStats:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: Dict[str, float]
    param_bytes: float
    mults: Dict[str, float] = field(default_factory=dict)

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloStats:
    comps = parse_hlo(text)
    entry = _find_entry(text, comps)
    mult = computation_multiplicities(comps, entry)

    # global name -> shape map (names are unique module-wide)
    name_shape: Dict[str, str] = {}
    for comp in comps.values():
        for op in comp.ops:
            name_shape[op.name] = op.shape

    # mark fusion bodies (their interior ops don't hit HBM)
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                c = _callee(op.rest, "calls")
                if c and c in comps:
                    comps[c].is_fusion_body = True

    flops = 0.0
    traffic = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    param_bytes = 0.0
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        flops += m * _dot_flops(comps, comp, name_shape)
        if comp.is_fusion_body:
            continue
        for op in comp.ops:
            kind = op.kind.replace("-start", "").replace("-done", "")
            base = op.kind.rstrip("0123456789.")
            if op.kind in ("parameter",):
                if comp.name == entry:
                    param_bytes += _shape_bytes(op.shape)
                continue
            if op.kind in ("constant", "get-tuple-element", "tuple",
                           "bitcast", "copy-start", "copy-done",
                           "after-all", "partition-id"):
                continue
            b = _shape_bytes(op.shape)
            for ck in _COLLECTIVES:
                if kind == ck or kind == ck + "-start":
                    coll[ck] += m * b
                    break
            if op.kind == "dynamic-update-slice":
                # in-place update: traffic = the update operand (2nd arg),
                # not the whole buffer (a KV-cache token write is ~1/32768
                # of the buffer) — §Perf-3 model refinement
                args = re.findall(r"%([\w.\-_]+)", op.rest.split(")")[0])
                if len(args) >= 2 and args[1] in name_shape:
                    b = _shape_bytes(name_shape[args[1]])
            elif op.kind == "fusion":
                # a fusion whose root is a DUS is an in-place updating
                # fusion (XLA aliases it on TPU): count the updated slice,
                # not the whole buffer — scan-ys collection otherwise looks
                # like a full re-materialization per iteration
                callee = _callee(op.rest, "calls")
                body = comps.get(callee) if callee else None
                if body and body.ops and body.ops[-1].kind == "dynamic-update-slice":
                    root = body.ops[-1]
                    args = re.findall(r"%([\w.\-_]+)", root.rest.split(")")[0])
                    if len(args) >= 2 and args[1] in name_shape:
                        b = _shape_bytes(name_shape[args[1]])
            traffic += m * 2.0 * b
    traffic += param_bytes
    return HloStats(dot_flops=flops, traffic_bytes=traffic,
                    collective_bytes=coll, param_bytes=param_bytes,
                    mults=mult)
