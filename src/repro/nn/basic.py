"""Norms, rotary embeddings, dense MLPs — shared primitives."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.params import ParamDef
from repro.sharding import constrain


# --------------------------------------------------------------------- norms
def norm_defs(cfg, dim=None):
    d = dim or cfg.d_model
    if cfg.norm_kind == "layernorm":
        return {"scale": ParamDef((d,), ("embed",), "ones"),
                "bias": ParamDef((d,), ("embed",), "zeros")}
    # rmsnorm applies (1 + scale) gemma-style -> zero init = unit gain
    return {"scale": ParamDef((d,), ("embed",), "zeros")}


def apply_norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(x.dtype)


# -------------------------------------------------------------------- rotary
def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq          # (..., S, half)
    ang = ang[..., None, :]                                        # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- dense MLP
def mlp_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {"w_gate": ParamDef((d, f), ("embed", "mlp")),
                "w_up": ParamDef((d, f), ("embed", "mlp")),
                "w_down": ParamDef((f, d), ("mlp", "embed"))}
    return {"w_up": ParamDef((d, f), ("embed", "mlp")),
            "w_down": ParamDef((f, d), ("mlp", "embed"))}


def apply_mlp(cfg, p, x):
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    h = constrain(h, "batch", "seq", "mlp")
    return h @ p["w_down"]
