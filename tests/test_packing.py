"""Block-ELL packing round-trip: a packed `Support` pushed through the
Pallas kernel must match the host `_subgraph_spmm` and a COO-materialized
reference, including the all-exited row-block skip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import GNNConfig, load_dataset
from repro.gnn.nai import NAIConfig, _subgraph_spmm, infer_batch_masked
from repro.gnn.packing import (next_bucket, pack_support,
                               step_active_blocks)
from repro.gnn.sampler import sample_support
from repro.kernels.spmm import spmm_block_ell
from repro.gnn.store import as_store


@pytest.fixture(scope="module")
def packed_case():
    g = load_dataset("pubmed-like", scale=0.03, seed=1)
    rng = np.random.default_rng(0)
    batch = rng.choice(g.test_idx, size=37, replace=False)
    sup = sample_support(as_store(g), batch, 2, 0.5)
    x0 = g.features[sup.nodes][:, :64].astype(np.float32)
    x_inf = np.zeros((sup.n_batch, 64), np.float32)
    packed = pack_support(sup, x0, x_inf)
    return g, sup, x0, packed


def _real_rows(sup, packed):
    """Padded row ids of the real support rows, in support order."""
    nb = sup.n_batch
    return np.concatenate([np.arange(nb),
                           np.arange(packed.n_batch,
                                     packed.n_batch + len(sup) - nb)])


def _coo_dense_step(sup, packed, x0):
    """Scipy-style COO reference: materialize the padded subgraph operator
    and multiply."""
    rows = _real_rows(sup, packed)
    A = np.zeros((packed.n_pad, packed.n_pad), np.float32)
    A[rows[sup.dst], rows[sup.src]] = sup.coef
    xp = np.zeros((packed.n_pad, x0.shape[1]), np.float32)
    xp[rows] = x0
    return A @ xp, rows


def test_roundtrip_matches_host_and_coo(packed_case):
    g, sup, x0, packed = packed_case
    out = np.asarray(spmm_block_ell(
        jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
        jnp.asarray(packed.valid), jnp.ones(packed.n_rb, jnp.int32),
        jnp.asarray(packed.x0), interpret=True))
    host, _ = _subgraph_spmm(sup, x0, np.ones(len(sup), bool))
    coo, rows = _coo_dense_step(sup, packed, x0)
    np.testing.assert_allclose(out[rows][:, :x0.shape[1]], host,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out[:, :x0.shape[1]], coo,
                               rtol=1e-4, atol=1e-4)
    # padding rows and padding feature columns stay exactly zero
    pad_rows = np.setdiff1d(np.arange(packed.n_pad), rows)
    assert np.abs(out[pad_rows]).max(initial=0.0) == 0.0
    assert np.abs(out[:, x0.shape[1]:]).max(initial=0.0) == 0.0


def test_segment_operands_match_host(packed_case):
    """The bucket-padded edge list (segment-sum path) reproduces the same
    step: pad edges carry coefficient zero. build_tiles=False (what the
    segment-mode engine uses) must skip the tile tensor entirely while
    keeping the same edge operands."""
    g, sup, x0, packed = packed_case
    lean = pack_support(sup, x0, np.zeros((sup.n_batch, 64), np.float32),
                        build_tiles=False)
    assert lean.tiles.shape[1] == 0 and lean.valid.size == 0
    assert lean.n_pad == packed.n_pad and lean.n_batch == packed.n_batch
    np.testing.assert_array_equal(lean.src, packed.src)
    np.testing.assert_array_equal(lean.coef, packed.coef)
    assert lean.shape_key("segment") == packed.shape_key("segment")
    acc = np.zeros_like(lean.x0)
    np.add.at(acc, lean.dst, lean.coef[:, None] * lean.x0[lean.src])
    host, _ = _subgraph_spmm(sup, x0, np.ones(len(sup), bool))
    rows = _real_rows(sup, packed)
    np.testing.assert_allclose(acc[rows][:, :x0.shape[1]], host,
                               rtol=1e-4, atol=1e-4)


def test_all_exited_row_block_skip(packed_case):
    """active == 0 everywhere (the whole batch has exited) must touch zero
    tiles: the kernel output is exactly zero."""
    g, sup, x0, packed = packed_case
    out = spmm_block_ell(
        jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
        jnp.asarray(packed.valid), jnp.zeros(packed.n_rb, jnp.int32),
        jnp.asarray(packed.x0), interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


def test_masked_block_ell_skips_after_batch_exit(packed_case):
    """With T_s huge everyone exits at T_min=1; the dynamic live flag then
    deactivates every block, so later series entries are exactly zero
    while exit orders remain 1."""
    g, sup, x0, packed = packed_case
    cfg = GNNConfig("sgc", 64, g.num_classes, k=3)
    nai = NAIConfig(t_s=1e9, t_min=1, t_max=3)
    step_active = step_active_blocks(packed.hop_rb, nai.t_max)
    orders, series = infer_batch_masked(
        cfg, nai, None, None, None, None, jnp.asarray(packed.x0),
        jnp.asarray(packed.x_inf), packed.n_batch,
        spmm_impl="block_ell",
        ell=(jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
             jnp.asarray(packed.valid)),
        step_active=jnp.asarray(step_active), interpret=True)
    o = np.asarray(orders)
    assert (o == 1).all()
    assert float(jnp.abs(series[2]).max()) == 0.0
    assert float(jnp.abs(series[3]).max()) == 0.0
    # step 1 itself did run
    assert float(jnp.abs(series[1]).max()) > 0.0


def test_bucket_floors_are_respected(packed_case):
    """Explicit buckets act as floors (the engine's high-water marks): the
    packed shapes equal the floor when it exceeds the need."""
    g, sup, x0, packed = packed_case
    bigger = pack_support(sup, x0, np.zeros((sup.n_batch, 64), np.float32),
                          s_bucket=packed.n_pad * 2,
                          tb_bucket=packed.tiles.shape[1] * 2,
                          e_bucket=len(packed.src) * 2)
    assert bigger.n_pad == packed.n_pad * 2
    assert bigger.tiles.shape[1] == packed.tiles.shape[1] * 2
    assert len(bigger.src) == len(packed.src) * 2
    # and the padded operator is unchanged on real rows
    out_a = np.asarray(spmm_block_ell(
        jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
        jnp.asarray(packed.valid), jnp.ones(packed.n_rb, jnp.int32),
        jnp.asarray(packed.x0), interpret=True))
    out_b = np.asarray(spmm_block_ell(
        jnp.asarray(bigger.tiles), jnp.asarray(bigger.tile_col),
        jnp.asarray(bigger.valid), jnp.ones(bigger.n_rb, jnp.int32),
        jnp.asarray(bigger.x0), interpret=True))
    rows_a = _real_rows(sup, packed)
    rows_b = _real_rows(sup, bigger)
    np.testing.assert_allclose(out_a[rows_a], out_b[rows_b],
                               rtol=1e-5, atol=1e-5)


def test_next_bucket_series():
    assert [next_bucket(x) for x in (1, 2, 3, 4, 5, 7, 9, 13, 25)] == \
        [1, 2, 3, 4, 6, 8, 12, 16, 32]
    assert next_bucket(37, 8) == 48      # {1,2,3}*2^k multiples of 8
    assert next_bucket(1, 8) == 8
    # ratio bound: never more than 1.5x overshoot (above the minimum)
    for x in range(1, 2000):
        b = next_bucket(x)
        assert x <= b < 2 * x
