"""Batched NAI serving engine (the paper's deployment scenario: streaming
inference over unseen nodes with latency constraints).

Requests (node ids) arrive on a queue; the batch former (`form_batch`)
closes a batch on size OR age — a full `batch_size` immediately, a
partial batch once its oldest request has waited `max_wait_s` — and each
batch runs Algorithm 1. Two stepping entry points: `step()` is the
closed-loop path (serve whatever is queued now; benchmarks submit
pre-formed batches), `poll(now)` is the open-loop path driven by the
deadline-aware front-end (`repro.serving.frontend`) — it respects the
batch former's triggers and advances the pipeline non-blockingly on
quiet ticks. Latency percentiles and the exit-order histogram are
tracked per engine — the quantities a production deployment would alarm
on. Requests carry optional absolute deadlines and an SLO class tag;
the engine itself is deadline-agnostic (goodput accounting lives in the
front-end).

Two serving modes:

* ``mode="host"`` — the faithful numpy path (`infer_batch_host`), with
  real frontier shrinking and MAC accounting.
* ``mode="compiled"`` — the end-to-end compiled path, structured as an
  explicit two-stage software pipeline:

  - **host stage** (`_host_stage`): vectorized support sampling ->
    bucket-padded block-ELL packing into a rotating pool of preallocated
    buffer sets (`pack_support(out=...)`), so the steady state allocates
    no fresh bucket-sized numpy arrays;
  - **device stage** (`_device_stage`): operand transfer plus ONE jitted
    function (Pallas-SpMM masked NAP + per-order classification),
    dispatched asynchronously — the call returns device futures without
    blocking.

  With ``pipeline_depth=1`` the two stages run back to back per batch
  (serial serving, the pre-pipeline behavior). With ``pipeline_depth=2``
  the engine keeps one batch in flight: batch N+1's sampling/packing
  (host stage) overlaps batch N's device compute, and batch N's results
  are only synced (`np.asarray`) once batch N+1 has been submitted.
  `step()` then returns the *previous* batch's completed requests (and
  `[]` while the pipe fills); `flush()` drains what remains in flight.
  Completion order stays FIFO, so predictions/exit orders are identical
  to serial serving on the same request stream.

  Operand shapes are bucketed and held at per-batch-size high-water
  marks, so repeat batches hit the jit compile cache; `jit_stats` counts
  compiles vs hits (alarm on compiles in steady state) and `pack_stats`
  counts pooled-buffer reuses vs allocations (steady state allocates
  zero). The pool rotates ``pipeline_depth + 1`` buffer sets per batch
  bucket, so a buffer refilled by the host stage is never one an
  in-flight batch still reads.

Compiled-mode `spmm_impl` names a registered `PropagationBackend`
(`repro.gnn.backends`): ``"segment"`` (jnp segment-sum), ``"block_ell"``
(Pallas SpMM kernel + separate jnp exit distance), or ``"fused"`` (one
Pallas kernel doing the SpMM, the exit distance, and the next step's
row-block predicate in a single grid pass — no HBM round trip between
matmul and distance check). The backend's declared needs drive both
stages — which operands the host stage packs and which arrays the device
stage ships — so adding an implementation is one registry entry, not
three new dispatch branches. The jitted runner donates its per-batch
operand buffers on backends that implement donation (see
`make_compiled_infer`), so bucketed repeat batches reuse HBM instead of
growing the footprint.

``mesh=`` (any mesh with a ``data`` axis, e.g.
`repro.launch.mesh.make_serving_mesh`) turns on **sharded serving**: the
host stage packs row-partitioned shards (`pack_support(n_shards=D)` —
same static shapes per shard, shard-major superblock round-robin), the
device stage places each operand with its backend-declared
NamedSharding, and the jitted runner executes the NAP loop under
shard_map (live flag psum-reduced) before un-permuting results to the
original batch order. Supports larger than one device's memory split
their packed tiles and rows across the mesh; predictions and exit
orders are bit-identical to single-device serving, and the
pipeline/pool/bucketing machinery is unchanged (zero steady-state
compiles and pack allocations still hold per shard count).

``gather_mode=`` picks the sharded per-step frontier exchange (see
`repro.gnn.backends`): ``"halo"`` (default) packs per-shard halo frames
— each shard's tiles read a (H_pad·CB, f) frame holding exactly the
column blocks they reference, assembled by a static gather — with
``"alltoall"`` the `jax.lax.all_to_all` ragged-exchange variant for
real meshes, and ``"dense"`` the PR-4 full-frontier all_gather
reference. All three are bit-identical; `halo_stats` records the
per-step gathered rows and the halo fraction (halo rows / S_pad) the
benchmark's structural columns are accountable for. Per-order
classification stays row-sharded too: only argmax class ids and exit
orders are gathered off the mesh.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding

from repro.gnn.backends import (BACKENDS, GATHER_MODES, get_backend,
                                normalize_mesh, operand_logical,
                                pack_operands)
from repro.gnn.models import GNNConfig
from repro.gnn.nai import (NAIConfig, infer_batch_host, make_compiled_infer,
                           support_stationary_factors)
from repro.gnn.packing import (CB, PackedSupport, batch_bucket,
                               pack_support, step_active_blocks)
from repro.gnn.propcache import PropCache
from repro.gnn.sampler import sample_support
from repro.gnn.store import as_store
from repro.serving.faults import (InjectedFault, NaNGuardError,
                                  WatchdogTimeout, poison_results)
from repro.sharding.logical import spec


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Validated serving-engine configuration.

    Consolidates what used to be a sprawl of `NAIServingEngine` keyword
    arguments into one declarative object (construction-time checks,
    mirroring `NAIConfig.__post_init__`), so per-SLO-class engine
    configs in the front-end are data, not call-site argument lists.
    `NAIServingEngine(..., config=EngineConfig(...))` and the legacy
    kwargs form are equivalent — the kwargs path builds an EngineConfig
    internally, so both get identical validation.
    """
    mode: str = "host"               # "host" (numpy) | "compiled"
    spmm_impl: str = "block_ell"     # registered PropagationBackend name
    gather_mode: str = "halo"        # sharded frontier exchange
    pipeline_depth: int = 1          # 1 = serial, 2 = one batch in flight
    max_wait_s: float = 0.01         # batch former age bound
    interpret: bool = True           # Pallas interpret mode (CPU CI)
    donate: Optional[bool] = None    # operand donation (None = backend)
    latency_window: int = 4096       # LatencyRing capacity
    mesh: object = None              # mesh with a "data" axis, or None
    # --- propagated-feature cache (repro.gnn.propcache; 0 = off) ---
    cache_nodes: int = 0             # LRU capacity in cached nodes
    cache_fill: bool = True          # insert batch-row series after serving
    # --- failure-domain isolation (all default off / no-op) ---
    faults: object = None            # FaultPlan schedule, or None
    watchdog_s: Optional[float] = None   # device-sync deadline, None = off
    retry_failed: bool = False       # retry a failed batch once (host path)
    nan_guard: bool = True           # finite/range check on synced results

    def __post_init__(self):
        if self.mode not in ("host", "compiled"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.spmm_impl not in BACKENDS:
            raise ValueError(f"unknown spmm_impl {self.spmm_impl!r} "
                             f"(one of {sorted(BACKENDS)})")
        if self.gather_mode not in GATHER_MODES:
            raise ValueError(f"unknown gather_mode {self.gather_mode!r} "
                             f"(one of {GATHER_MODES})")
        if self.pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got "
                             f"{self.pipeline_depth}")
        if self.pipeline_depth > 1 and self.mode != "compiled":
            raise ValueError("pipelining overlaps host pack with device "
                             "compute; mode='host' has no device stage")
        if self.mesh is not None and self.mode != "compiled":
            raise ValueError("sharded serving (mesh=) requires "
                             "mode='compiled'")
        if self.cache_nodes < 0:
            raise ValueError(f"cache_nodes must be >= 0, got "
                             f"{self.cache_nodes}")
        if self.cache_nodes and self.mode != "compiled":
            raise ValueError("the propagated-feature cache fills from the "
                             "compiled runner's series output; mode='host' "
                             "has none")
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got "
                             f"{self.max_wait_s}")
        if self.latency_window < 1:
            raise ValueError(f"latency_window must be >= 1, got "
                             f"{self.latency_window}")
        if self.watchdog_s is not None and self.watchdog_s <= 0:
            raise ValueError(f"watchdog_s must be > 0 (or None to "
                             f"disable), got {self.watchdog_s}")
        if self.faults is not None and not callable(
                getattr(self.faults, "injector", None)):
            raise ValueError("faults must be a FaultPlan "
                             "(repro.serving.faults) or None")


@dataclasses.dataclass
class Request:
    node_id: int
    arrival_s: float
    deadline_s: float = float("inf")   # ABSOLUTE completion deadline
    slo_class: str = ""                # routing tier (serving front-end)
    done_s: float = -1.0
    prediction: int = -1
    exit_order: int = -1
    batch_id: int = -1                 # engine batch this completed in
    # terminal lifecycle: every accepted request ends EXACTLY once as
    # "completed" or "failed" (shedding happens before acceptance, at
    # the front-end) — the conservation invariant chaos_bench gates
    status: str = "pending"            # "pending" | "completed" | "failed"
    error: str = ""                    # typed failure cause when failed
    retried: bool = False              # recovered via the reference path
    degraded: bool = False             # demoted by an open circuit breaker
    probe: bool = False                # half-open breaker probe request

    @property
    def within_deadline(self) -> bool:
        """Completed in time (the goodput numerator). False while the
        request is still pending."""
        return 0.0 <= self.done_s <= self.deadline_s


class LatencyRing:
    """Fixed-capacity ring of the most recent request latencies.

    Long-running engines append one latency per request forever; an
    unbounded list is a slow memory leak. The ring keeps the latest
    `capacity` samples — enough for stable p50/p95/p99 — at constant
    memory. For short runs (fewer than `capacity` appends) percentiles
    are computed over exactly the same samples an unbounded list would
    hold, so `EngineStats.summary()` is unchanged there.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf = np.zeros(capacity, np.float64)
        self.total_appended = 0

    def append(self, value: float) -> None:
        self._buf[self.total_appended % self.capacity] = value
        self.total_appended += 1

    def __len__(self) -> int:
        return min(self.total_appended, self.capacity)

    def values(self) -> np.ndarray:
        """Current window (order not meaningful once the ring has
        wrapped; percentiles don't care)."""
        return self._buf[:len(self)].copy()

    def __iter__(self):
        return iter(self.values())


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    batches: int = 0
    failed: int = 0        # requests that ended status="failed"
    retried: int = 0       # requests recovered on the reference path
    latencies: LatencyRing = dataclasses.field(default_factory=LatencyRing)
    exit_hist: Dict[int, int] = dataclasses.field(default_factory=dict)

    def percentile(self, q: float) -> float:
        vals = self.latencies.values()
        return float(np.percentile(vals, q)) if len(vals) else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "served": self.served,
            "batches": self.batches,
            "failed": self.failed,
            "retried": self.retried,
            "p50_ms": 1e3 * self.percentile(50),
            "p95_ms": 1e3 * self.percentile(95),
            "p99_ms": 1e3 * self.percentile(99),
            "mean_exit_order": (
                sum(k * v for k, v in self.exit_hist.items())
                / max(self.served, 1)),
        }


@dataclasses.dataclass
class _Inflight:
    """One submitted batch whose device results have not been synced."""
    requests: List[Request]
    inv: np.ndarray          # dedupe inverse map (batch -> unique row)
    nb_real: int             # unique node count (real rows of the result)
    preds_dev: object        # device array futures from the jitted runner
    orders_dev: object
    host_s: float            # sample + pack wall time
    dispatch_s: float        # operand transfer + async dispatch wall time
    t_submit: float = 0.0    # wall clock at dispatch (watchdog anchor)
    series_dev: object = None   # (T_max+1, nb, f) batch-row series future
    fill: object = None      # cache fill record (nodes, deps, gv) or None


class NAIServingEngine:
    def __init__(self, cfg: GNNConfig, nai: NAIConfig, params, graph,
                 *, config: Optional[EngineConfig] = None, **kwargs):
        """`graph` is a `GraphStore` (or a raw `Graph`, wrapped via
        `as_store`). Engine options come either as one validated
        ``config=EngineConfig(...)`` or as the legacy keyword arguments
        (``mode=``, ``spmm_impl=``, ...) — never both; the kwargs path
        just builds an `EngineConfig`, so validation is identical."""
        if config is not None and kwargs:
            raise ValueError(
                f"pass either config=EngineConfig(...) or engine kwargs, "
                f"not both (got kwargs {sorted(kwargs)})")
        ec = config if config is not None else EngineConfig(**kwargs)
        mesh = normalize_mesh(ec.mesh) if ec.mesh is not None else None
        mode, gather_mode = ec.mode, ec.gather_mode
        spmm_impl, pipeline_depth = ec.spmm_impl, ec.pipeline_depth
        self.config = ec
        self.cfg = cfg
        self.nai = nai
        self.params = params
        self.store = as_store(graph)
        self.graph = graph
        self.max_wait_s = ec.max_wait_s
        self.mode = mode
        self.spmm_impl = spmm_impl
        self.mesh = mesh
        self.n_shards = int(mesh.shape["data"]) if mesh is not None else 1
        # the frontier exchange only exists across shards — a degenerate
        # mesh serves the plain single-device path
        self.gather_mode = gather_mode if self.n_shards > 1 else "dense"
        # per-step exchange footprint of the worst batch seen (sharded
        # engines only; serving_bench's structural halo columns)
        self.halo_stats: Dict[str, float] = {
            "gather_rows_per_step": 0, "halo_rows": 0, "s_pad": 0,
            "halo_frac": 0.0}
        self.pipeline_depth = pipeline_depth
        self.queue: Deque[Request] = deque()
        self.stats = EngineStats(latencies=LatencyRing(ec.latency_window))
        # propagated-feature cache (sharded: partitioned so each shard's
        # cache holds rows its shard owns — see PropCache.n_shards)
        self.cache: Optional[PropCache] = (
            PropCache(ec.cache_nodes, nai.t_max, n_shards=self.n_shards)
            if ec.cache_nodes else None)
        self.cache_fill = ec.cache_fill
        # SpMM row accounting: support rows sampled vs rows actually
        # packed for device propagation (the cache's compute saving)
        self.row_stats: Dict[str, int] = {"rows_support": 0,
                                          "rows_packed": 0}
        # failure-domain isolation knobs (EngineConfig, all off by default)
        self.watchdog_s = ec.watchdog_s
        self.retry_failed = ec.retry_failed
        self.nan_guard = ec.nan_guard
        self._faults = (ec.faults.injector()
                        if ec.faults is not None else None)
        # compiled-path state: jitted runner + bucket high-water marks
        # keyed by padded batch size
        # -> (s_bucket, tb_bucket, e_bucket, h_bucket, hb_bucket, k_bucket)
        self.jit_stats: Dict[str, int] = {"compiles": 0, "hits": 0}
        self.pack_stats: Dict[str, int] = {"allocs": 0, "reuses": 0}
        # per-batch stage breakdown (host/dispatch/sync seconds), bounded
        self.batch_timings: Deque[Dict[str, float]] = deque(maxlen=1024)
        self._runner = None
        self._bucket_hwm: Dict[int, Tuple[int, ...]] = {}
        self._seen_keys: set = set()
        self._inflight: Deque[_Inflight] = deque()
        # rotating pack-buffer pool: bucket -> pipeline_depth + 1 slots
        self._pack_pool: Dict[int, List[Optional[PackedSupport]]] = {}
        self._pool_idx: Dict[int, int] = {}
        self._backend = None
        self._shardings = None
        if mode == "compiled":
            self._backend = get_backend(spmm_impl)
            if self.mesh is not None:
                # backend, mesh, gather mode, and operand keys are fixed
                # for the engine's lifetime — build the per-operand
                # NamedShardings once, off the per-batch dispatch path
                logical = dict(operand_logical(self._backend,
                                               self.gather_mode,
                                               seeds=self.cache
                                               is not None),
                               x0=("row_shard", None),
                               x_inf=("row_shard", None))
                self._shardings = {
                    name: NamedSharding(self.mesh,
                                        spec(*dims, mesh=self.mesh))
                    for name, dims in logical.items()}
            self._runner = make_compiled_infer(
                cfg, nai, spmm_impl=spmm_impl, interpret=ec.interpret,
                donate=ec.donate, mesh=self.mesh,
                gather_mode=self.gather_mode,
                return_series=self.cache is not None)
            self._cls_params = {
                l: {k: jnp.asarray(v) for k, v in p.items()}
                for l, p in params["cls"].items()}

    def jit_cache_size(self) -> int:
        """Shapes traced by the compiled runner (0 in host mode)."""
        return self._runner._cache_size() if self._runner is not None else 0

    @property
    def fault_stats(self) -> Optional[Dict]:
        """Per-stage injected-fault tallies (None without a FaultPlan)."""
        return self._faults.summary() if self._faults is not None else None

    @property
    def cache_stats(self) -> Dict[str, float]:
        """Propagated-feature-cache counters (hits/misses/stale/fills/
        evictions/hit_rate) merged with the engine's SpMM row accounting.
        With the cache off only the row counters appear (and
        rows_packed == rows_support)."""
        d: Dict[str, float] = dict(self.row_stats)
        if self.cache is not None:
            d.update(self.cache.stats())
        return d

    def reset_stats(self) -> None:
        """Zero the serving counters — request stats, per-batch timings,
        row accounting, and the cache's hit/miss/fill counters — without
        touching serving state (cache CONTENTS, pack pools, high-water
        marks, and jit/pack structural counters all survive, so a warm
        engine stays warm and steady-state compile accounting stays
        meaningful across a reset)."""
        self.stats = EngineStats(
            latencies=LatencyRing(self.config.latency_window))
        self.batch_timings.clear()
        self.row_stats = {"rows_support": 0, "rows_packed": 0}
        if self.cache is not None:
            self.cache.reset_stats()

    def close(self) -> None:
        """Drain in-flight work, then release the store's OS resources
        (fd/maps for `MmapStore`). Idempotent — front-ends sharing one
        store across per-class engines close it once per engine."""
        self.flush()
        self.store.close()

    @property
    def donate_argnums(self) -> tuple:
        """Argnums the jitted runner donates (empty in host mode or on
        backends without donation support)."""
        return (self._runner._donate_argnums
                if self._runner is not None else ())

    # ------------------------------------------------------- host stage
    def _host_stage(self, nodes: np.ndarray):
        """Sample the support and pack it into a pooled buffer set,
        plus the static per-step row-block predicate for the Pallas
        impls. `nodes` must be duplicate-free. Pure host work — no jax
        calls, and no full-graph arrays: everything reads through the
        store's row-gather view API, so an `MmapStore` only pages in the
        support's rows.

        Returns ``(packed, step_active, fill)``: `fill` is the
        propagated-feature-cache fill record (batch nodes, dependency
        node set, mutation clock at sample time) for `_finalize_oldest`
        to insert once the batch's series has synced — or None with the
        cache off."""
        store, cfg, nai = self.store, self.cfg, self.nai
        be = self._backend
        sup = sample_support(store, nodes, nai.t_max, cfg.r,
                             cache=self.cache)
        nb = sup.n_batch
        n_hit = int(sup.hit.sum()) if sup.hit is not None else 0
        self.row_stats["rows_support"] += len(sup)
        self.row_stats["rows_packed"] += len(sup) - n_hit
        x0 = store.gather_features(sup.nodes).astype(np.float32)
        # dense x_inf is built from the f32 factors so the fused kernel
        # (which streams the factors and multiplies in f32) is
        # bit-consistent with the dense block_ell/segment distance; in
        # fused mode the dense matrix is never materialized at all —
        # a zero-column placeholder carries just the batch-row count
        c_inf, s_inf = support_stationary_factors(store, sup, x0, cfg.r)
        c_inf = c_inf.astype(np.float32)
        s_inf = s_inf.astype(np.float32)
        if be.uses_dense_x_inf:
            x_inf = c_inf[:, None] * s_inf[None, :]
        else:
            x_inf = np.zeros((nb, 0), np.float32)

        nb_bucket = batch_bucket(nb, self.n_shards)
        hwm = self._bucket_hwm.get(nb_bucket, (0, 0, 0, 0, 0, 0))
        slots = self._pack_pool.setdefault(
            nb_bucket, [None] * (self.pipeline_depth + 1))
        idx = self._pool_idx.get(nb_bucket, 0)
        packed = pack_support(sup, x0, x_inf, nb_bucket=nb_bucket,
                              s_bucket=hwm[0], tb_bucket=hwm[1],
                              e_bucket=hwm[2],
                              build_tiles=be.uses_tiles,
                              build_edges=be.uses_edges,
                              x_inf_factors=(c_inf, s_inf)
                              if be.uses_factors else None,
                              out=slots[idx], n_shards=self.n_shards,
                              halo=self.gather_mode != "dense",
                              h_bucket=hwm[3], hb_bucket=hwm[4],
                              seeds=(sup.hit, sup.seed_vals)
                              if self.cache is not None else None,
                              k_bucket=hwm[5])
        slots[idx] = packed
        self._pool_idx[nb_bucket] = (idx + 1) % len(slots)
        self.pack_stats["reuses" if packed.reused else "allocs"] += 1
        self._bucket_hwm[nb_bucket] = (
            max(hwm[0], packed.n_pad), max(hwm[1], packed.tiles.shape[1]),
            max(hwm[2], packed.src.shape[-1]),
            max(hwm[3], packed.n_halo_pad),
            max(hwm[4], packed.halo_send_pad),
            max(hwm[5], packed.seed_pad))
        if self.mesh is not None:
            # per-step exchange footprint (structural: what the compiled
            # gather materializes vs the true boundary vs dense S_pad)
            halo_on = packed.halo_src_shard is not None
            grows = (packed.n_halo_pad * CB if halo_on else packed.n_pad)
            hrows = packed.halo_rows if halo_on else packed.n_pad
            hs = self.halo_stats
            hs["gather_rows_per_step"] = max(hs["gather_rows_per_step"],
                                             grows)
            hs["halo_rows"] = max(hs["halo_rows"], hrows)
            hs["s_pad"] = max(hs["s_pad"], packed.n_pad)
            hs["halo_frac"] = max(hs["halo_frac"],
                                  packed.halo_frac if halo_on else 1.0)

        key = packed.shape_key(self.spmm_impl)
        if key in self._seen_keys:
            self.jit_stats["hits"] += 1
        else:
            self._seen_keys.add(key)
            self.jit_stats["compiles"] += 1
        step_active = (step_active_blocks(packed.hop_rb, nai.t_max)
                       if be.uses_tiles else None)
        fill = None
        if self.cache is not None and self.cache_fill:
            # the full support node set is the conservative dependency
            # cone of every batch row's series (see PropCache.fill)
            fill = (nodes, sup.nodes, sup.graph_version)
        return packed, step_active, fill

    # ----------------------------------------------------- device stage
    def _device_stage(self, packed: PackedSupport,
                      step_active: Optional[np.ndarray]):
        """Transfer operands and dispatch the jitted runner. Returns
        device futures (predictions, exit orders) WITHOUT blocking —
        jax dispatch is asynchronous, so host work for the next batch can
        proceed while the device computes.

        Operand construction is backend-driven (`pack_operands`): no
        per-impl branches. Sharded (mesh set), every operand is placed
        with its backend-declared NamedSharding, so each device receives
        only its row shard — the point at which a support larger than one
        device's memory becomes servable."""
        operands = pack_operands(self._backend, packed, step_active)
        if self.mesh is not None:
            sh = self._shardings

            def put(name, a):
                return jax.device_put(np.asarray(a), sh[name])

            operands = {k: put(k, v) for k, v in operands.items()}
            x0 = put("x0", packed.x0)
            x_inf = put("x_inf", packed.x_inf)
        else:
            operands = {k: jnp.asarray(v) for k, v in operands.items()}
            x0 = jnp.asarray(packed.x0)
            x_inf = jnp.asarray(packed.x_inf)
        out = self._runner(self._cls_params, operands, x0, x_inf)
        # with the cache on, the runner also returns the batch-row series
        # (the fill source); pad the cache-off path to the same arity
        return out if self.cache is not None else (*out, None)

    def _watchdog_sync(self, fl: _Inflight) -> None:
        """Bound the device sync: poll `is_ready` until the results are
        complete or `watchdog_s` has elapsed since dispatch, then raise
        `WatchdogTimeout` — the batch is declared hung and failed, and
        the pipeline slot it held is free again (re-armed). With the
        watchdog off (None) this returns immediately and the sync
        blocks, exactly the pre-watchdog behavior."""
        wd = self.watchdog_s
        if wd is None:
            return
        deadline = fl.t_submit + wd
        for dev in (fl.preds_dev, fl.orders_dev):
            ready = getattr(dev, "is_ready", None)
            if ready is None:
                continue
            while not ready():
                if time.perf_counter() >= deadline:
                    raise WatchdogTimeout(
                        f"device sync not ready {wd * 1e3:.0f} ms after "
                        f"dispatch; batch of {len(fl.requests)} declared "
                        f"hung")
                time.sleep(1e-4)

    def _guard_results(self, preds: np.ndarray, orders: np.ndarray,
                       nb_real: int) -> None:
        """Fail the batch if the device returned garbage: non-finite
        values (NaN/Inf logits surviving to the argmax) or out-of-range
        class ids / exit orders. Guards VALUES only — a passing batch's
        results are byte-identical to the unguarded path."""
        if not self.nan_guard:
            return
        p, o = preds[:nb_real], orders[:nb_real]
        for what, a in (("predictions", p), ("exit orders", o)):
            if a.dtype.kind == "f" and not np.all(np.isfinite(a)):
                raise NaNGuardError(
                    f"non-finite {what} from the device stage")
        if p.size:
            lo, hi = int(p.min()), int(p.max())
            if lo < 0 or hi >= self.cfg.num_classes:
                raise NaNGuardError(
                    f"prediction ids [{lo}, {hi}] outside "
                    f"[0, {self.cfg.num_classes})")
            olo, ohi = int(o.min()), int(o.max())
            if olo < 1 or ohi > self.nai.t_max:
                raise NaNGuardError(
                    f"exit orders [{olo}, {ohi}] outside "
                    f"[1, {self.nai.t_max}]")

    def _fail_batch(self, batch: List[Request], err: Exception
                    ) -> List[Request]:
        """Terminal handling for a batch whose stage raised: the failure
        domain is THIS batch only — nothing here touches the queue, the
        pipeline, or other in-flight batches. With `retry_failed` the
        batch gets one graceful-degradation attempt on the reference
        host path (`infer_batch_host`, the numpy `segment` semantics —
        always available, never compiled) before being declared failed."""
        if self.retry_failed and not any(r.retried for r in batch):
            for r in batch:
                r.retried = True
            try:
                nodes = np.asarray([r.node_id for r in batch])
                uniq, inv = np.unique(nodes, return_inverse=True)
                p_u, o_u, _, _, _ = infer_batch_host(
                    self.cfg, self.nai, self.params, self.store, uniq)
            except Exception as retry_err:   # noqa: BLE001 — isolation
                err = retry_err
            else:
                self.stats.retried += len(batch)
                self._complete(batch, p_u[inv], o_u[inv],
                               time.perf_counter())
                return batch
        msg = f"{type(err).__name__}: {err}"
        for r in batch:
            r.status = "failed"
            r.error = msg
            r.done_s = time.perf_counter()
        self.stats.failed += len(batch)
        return batch

    def _finalize_oldest(self) -> List[Request]:
        """Sync the oldest in-flight batch (block on its device results,
        bounded by the watchdog when armed) and complete its requests.
        FIFO, so completion order matches submission order regardless of
        pipeline depth. A sync failure, watchdog trip, or guard trip
        fails ONLY this batch — the slot is released either way."""
        fl = self._inflight.popleft()
        t0 = time.perf_counter()
        try:
            self._watchdog_sync(fl)
            preds_a = np.asarray(fl.preds_dev)
            orders_a = np.asarray(fl.orders_dev)
            self._guard_results(preds_a, orders_a, fl.nb_real)
        except Exception as e:   # noqa: BLE001 — batch-level isolation
            return self._fail_batch(fl.requests, e)
        if fl.fill is not None:
            # fill only after the guards pass — a poisoned/hung batch
            # must not seed future batches. Steps 1..T_max of a batch
            # row are exact global values (hop 0, full budget), so the
            # whole series is insertable.
            batch_nodes, dep_nodes, gv = fl.fill
            series = np.asarray(fl.series_dev)
            self.cache.fill(
                self.store, batch_nodes,
                series[1:, :fl.nb_real].transpose(1, 0, 2), dep_nodes, gv)
        preds = preds_a[:fl.nb_real][fl.inv]
        orders = orders_a[:fl.nb_real][fl.inv]
        done = time.perf_counter()
        self.batch_timings.append({
            "host_s": fl.host_s, "dispatch_s": fl.dispatch_s,
            "sync_s": done - t0, "n": len(fl.requests)})
        self._complete(fl.requests, preds, orders, done)
        return fl.requests

    def _complete(self, batch: List[Request], preds, orders,
                  done: float) -> None:
        bid = self.stats.batches
        for r, p, o in zip(batch, preds, orders):
            r.done_s = done
            r.prediction = int(p)
            r.exit_order = int(o)
            r.batch_id = bid
            r.status = "completed"
            self.stats.latencies.append(done - r.arrival_s)
            self.stats.exit_hist[int(o)] = \
                self.stats.exit_hist.get(int(o), 0) + 1
        self.stats.served += len(batch)
        self.stats.batches += 1

    def _validate_node_id(self, node_id) -> int:
        """Reject an out-of-range id at SUBMIT time with a clear error.
        Unvalidated, a bad id fails deep in the sampler with an opaque
        index error — and takes its whole batch down with it."""
        nid = int(node_id)
        if not 0 <= nid < self.store.n:
            raise ValueError(
                f"node id {nid} out of range for store "
                f"{self.store.name!r} with n={self.store.n} nodes "
                f"(valid ids are 0..{self.store.n - 1})")
        return nid

    def submit(self, node_ids, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        # validate the whole call before enqueuing any of it, so a bad
        # id rejects atomically instead of half-submitting
        nids = [self._validate_node_id(nid)
                for nid in np.atleast_1d(node_ids)]
        for nid in nids:
            self.queue.append(Request(nid, now))

    def submit_request(self, req: Request) -> None:
        """Enqueue a pre-built request (the front-end path: deadline and
        SLO class already stamped by `repro.serving.frontend`)."""
        self._validate_node_id(req.node_id)
        self.queue.append(req)

    def form_batch(self, now: Optional[float] = None, *,
                   force: bool = False) -> List[Request]:
        """Deadline-aware batch former: close a batch on size OR age,
        whichever comes first. A full `batch_size` closes immediately;
        a partial batch closes only once its oldest request has waited
        `max_wait_s` — and then it closes UNCONDITIONALLY, taking
        everything queued (up to batch_size). The latency bound takes
        priority over batch fill: there is no minimum-fill guard (the
        old `batch_size // 4` gate held post-deadline batches hostage to
        fill — and degenerated them to size 1 whenever batch_size <= 3).
        Returns [] while neither trigger has fired.

        `now` defaults to the wall clock; pass an explicit timestamp to
        drive the former on a virtual clock (deterministic tests/parity
        replays). `force=True` (the closed-loop benchmark path and
        `flush`) closes whatever is queued immediately."""
        if not self.queue:
            return []
        if not force:
            now = time.perf_counter() if now is None else now
            aged = now - self.queue[0].arrival_s >= self.max_wait_s
            if len(self.queue) < self.nai.batch_size and not aged:
                return []           # neither size nor age has closed it
        batch: List[Request] = []
        while self.queue and len(batch) < self.nai.batch_size:
            batch.append(self.queue.popleft())
        return batch

    def _advance(self, opportunistic: bool = False) -> List[Request]:
        """Finalize only batches already past the pipeline depth — the
        empty-queue path must NOT drain the pipeline (a momentarily
        empty queue under bursty arrivals is exactly when overlap
        matters; a full drain is a sync barrier that silently degrades
        pipeline_depth=2 to serial). `flush()` stays the explicit drain.

        `opportunistic=True` (the front-end's `poll`) additionally
        finalizes in-flight batches whose device results are ALREADY
        complete — `jax.Array.is_ready` makes that a non-blocking check,
        so completions surface promptly during arrival lulls without
        ever stalling on unfinished device work."""
        done: List[Request] = []
        while len(self._inflight) >= self.pipeline_depth:
            done += self._finalize_oldest()
        if opportunistic:
            while self._inflight:
                # no is_ready attribute means the results are already
                # host-materialized (plain arrays), i.e. trivially ready
                # — treating that as NOT ready parks the batch below
                # pipeline_depth where poll() can never finalize it
                ready = getattr(self._inflight[0].preds_dev,
                                "is_ready", None)
                if ready is not None and not ready():
                    break
                done += self._finalize_oldest()
        # watchdog re-arm: a hung head batch must not wedge open-loop
        # serving (poll never blocks, so without this check a
        # never-ready future parks below pipeline_depth forever) —
        # finalize it now; _watchdog_sync declares it failed immediately
        # since its deadline has already passed
        if self.watchdog_s is not None:
            while (self._inflight
                   and time.perf_counter() - self._inflight[0].t_submit
                   >= self.watchdog_s):
                done += self._finalize_oldest()
        return done

    def _inject_host_faults(self) -> None:
        """Host-stage injection point (`slow` then `host`); called once
        per served batch so a plan's event counters align with batch
        indices. No-op without a FaultPlan."""
        if self._faults is None:
            return
        spec = self._faults.fire("slow")
        if spec is not None and spec.delay_s > 0.0:
            time.sleep(spec.delay_s)
        if self._faults.fire("host") is not None:
            raise InjectedFault("injected host-stage failure")

    def _serve_batch(self, batch: List[Request]) -> List[Request]:
        nodes = np.asarray([r.node_id for r in batch])
        # dedupe per batch (client retries): the sampler requires
        # duplicate-free batches — duplicated rows would double-count in
        # the stationary state and skew every exit distance
        uniq, inv = np.unique(nodes, return_inverse=True)
        if self.mode == "host":
            try:
                self._inject_host_faults()
                p_u, o_u, _, _, _ = infer_batch_host(
                    self.cfg, self.nai, self.params, self.store, uniq)
            except Exception as e:   # noqa: BLE001 — batch isolation
                return self._fail_batch(batch, e)
            self._complete(batch, p_u[inv], o_u[inv], time.perf_counter())
            return batch
        t0 = time.perf_counter()
        try:
            self._inject_host_faults()
            packed, step_active, fill = self._host_stage(uniq)
            t1 = time.perf_counter()
            if (self._faults is not None
                    and self._faults.fire("device") is not None):
                raise InjectedFault("injected device-stage failure")
            preds_dev, orders_dev, series_dev = self._device_stage(
                packed, step_active)
            preds_dev, orders_dev = poison_results(self._faults,
                                                   preds_dev, orders_dev)
        except Exception as e:   # noqa: BLE001 — batch-level isolation:
            # a stage failure takes down THIS batch only; in-flight
            # batches and the queue are untouched, and _advance keeps
            # the pipeline moving
            return self._fail_batch(batch, e) + self._advance()
        t2 = time.perf_counter()
        self._inflight.append(
            _Inflight(batch, inv, packed.nb_real, preds_dev, orders_dev,
                      host_s=t1 - t0, dispatch_s=t2 - t1, t_submit=t2,
                      series_dev=series_dev, fill=fill))
        done: List[Request] = []
        while len(self._inflight) >= self.pipeline_depth:
            done += self._finalize_oldest()
        return done

    def step(self) -> List[Request]:
        """Closed-loop step: serve whatever is queued RIGHT NOW (up to
        batch_size), without waiting on the batch former's size/age
        triggers — callers on this path (benchmarks, run_until_drained)
        submit pre-formed batches. Returns completed requests; with
        pipeline_depth > 1 those belong to an EARLIER batch (or none
        while the pipeline fills/idles) — call `flush()` after the last
        `step()` to drain the in-flight tail. An empty queue only
        advances the pipeline (no drain barrier)."""
        batch = self.form_batch(force=True)
        if not batch:
            return self._advance()
        return self._serve_batch(batch)

    def poll(self, now: Optional[float] = None) -> List[Request]:
        """Open-loop serving step (the front-end path): dispatch a batch
        only if size OR age has closed one (`form_batch`), otherwise
        advance the pipeline non-blockingly — finalizing batches past
        the pipeline depth plus any whose device results are already
        complete. Never blocks on unfinished device work and never
        serves a partial batch before its age bound."""
        batch = self.form_batch(now)
        if not batch:
            return self._advance(opportunistic=True)
        return self._serve_batch(batch)

    def flush(self) -> List[Request]:
        """Sync and complete every in-flight batch (no-op when serial)."""
        done: List[Request] = []
        while self._inflight:
            done += self._finalize_oldest()
        return done

    def run_until_drained(self) -> EngineStats:
        while self.queue:
            self.step()
        self.flush()
        return self.stats
