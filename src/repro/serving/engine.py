"""Batched NAI serving engine (the paper's deployment scenario: streaming
inference over unseen nodes with latency constraints).

Requests (node ids) arrive on a queue; the batch former groups them up to
`batch_size` or `max_wait_s`; each batch runs Algorithm 1. Latency
percentiles and the exit-order histogram are tracked per engine — the
quantities a production deployment would alarm on.

Two serving modes:

* ``mode="host"`` — the faithful numpy path (`infer_batch_host`), with
  real frontier shrinking and MAC accounting.
* ``mode="compiled"`` — the end-to-end compiled path: vectorized support
  sampling -> bucket-padded block-ELL packing (repro.gnn.packing) -> one
  jitted function doing Pallas-SpMM masked NAP plus per-order
  classification. Operand shapes are bucketed and held at per-batch-size
  high-water marks, so repeat batches hit the jit compile cache;
  `jit_stats` counts compiles vs hits (alarm on compiles in steady
  state).

Compiled-mode `spmm_impl` selects the propagation operator per step:
``"segment"`` (jnp segment-sum), ``"block_ell"`` (Pallas SpMM kernel +
separate jnp exit distance), or ``"fused"`` (one Pallas kernel doing the
SpMM, the exit distance, and the next step's row-block predicate in a
single grid pass — no HBM round trip between matmul and distance check).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.gnn.graph import Graph
from repro.gnn.models import GNNConfig
from repro.gnn.nai import (NAIConfig, infer_batch_host, make_compiled_infer,
                           support_stationary_factors)
from repro.gnn.packing import next_bucket, pack_support, step_active_blocks
from repro.gnn.sampler import sample_support
from repro.kernels.spmm.kernel import RB


@dataclasses.dataclass
class Request:
    node_id: int
    arrival_s: float
    done_s: float = -1.0
    prediction: int = -1
    exit_order: int = -1


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    batches: int = 0
    latencies: List[float] = dataclasses.field(default_factory=list)
    exit_hist: Dict[int, int] = dataclasses.field(default_factory=dict)

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "served": self.served,
            "batches": self.batches,
            "p50_ms": 1e3 * self.percentile(50),
            "p95_ms": 1e3 * self.percentile(95),
            "p99_ms": 1e3 * self.percentile(99),
            "mean_exit_order": (
                sum(k * v for k, v in self.exit_hist.items())
                / max(self.served, 1)),
        }


class NAIServingEngine:
    def __init__(self, cfg: GNNConfig, nai: NAIConfig, params, graph: Graph,
                 *, max_wait_s: float = 0.01, mode: str = "host",
                 spmm_impl: str = "block_ell", interpret: bool = True):
        if mode not in ("host", "compiled"):
            raise ValueError(f"unknown mode {mode!r}")
        self.cfg = cfg
        self.nai = nai
        self.params = params
        self.graph = graph
        self.max_wait_s = max_wait_s
        self.mode = mode
        self.spmm_impl = spmm_impl
        self.queue: Deque[Request] = deque()
        self.stats = EngineStats()
        # compiled-path state: jitted runner + bucket high-water marks
        # keyed by padded batch size -> (s_bucket, tb_bucket, e_bucket)
        self.jit_stats: Dict[str, int] = {"compiles": 0, "hits": 0}
        self._runner = None
        self._bucket_hwm: Dict[int, Tuple[int, int, int]] = {}
        self._seen_keys: set = set()
        if mode == "compiled":
            self._runner = make_compiled_infer(
                cfg, nai, spmm_impl=spmm_impl, interpret=interpret)
            self._cls_params = {
                l: {k: jnp.asarray(v) for k, v in p.items()}
                for l, p in params["cls"].items()}

    def jit_cache_size(self) -> int:
        """Shapes traced by the compiled runner (0 in host mode)."""
        return self._runner._cache_size() if self._runner is not None else 0

    def _infer_compiled(self, nodes: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized sample -> block-ELL pack -> jitted masked NAI +
        classification. `nodes` must be duplicate-free."""
        g, cfg, nai = self.graph, self.cfg, self.nai
        sup = sample_support(g, nodes, nai.t_max, cfg.r)
        nb = sup.n_batch
        x0 = g.features[sup.nodes].astype(np.float32)
        # dense x_inf is built from the f32 factors so the fused kernel
        # (which streams the factors and multiplies in f32) is
        # bit-consistent with the dense block_ell/segment distance; in
        # fused mode the dense matrix is never materialized at all —
        # a zero-column placeholder carries just the batch-row count
        c_inf, s_inf = support_stationary_factors(g, sup, x0, cfg.r)
        c_inf = c_inf.astype(np.float32)
        s_inf = s_inf.astype(np.float32)
        if self.spmm_impl == "fused":
            x_inf = np.zeros((nb, 0), np.float32)
        else:
            x_inf = c_inf[:, None] * s_inf[None, :]

        nb_bucket = next_bucket(nb, RB)
        hwm = self._bucket_hwm.get(nb_bucket, (0, 0, 0))
        packed = pack_support(sup, x0, x_inf, nb_bucket=nb_bucket,
                              s_bucket=hwm[0], tb_bucket=hwm[1],
                              e_bucket=hwm[2],
                              build_tiles=self.spmm_impl in ("block_ell",
                                                             "fused"),
                              build_edges=self.spmm_impl == "segment",
                              x_inf_factors=(c_inf, s_inf)
                              if self.spmm_impl == "fused" else None)
        self._bucket_hwm[nb_bucket] = (
            max(hwm[0], packed.n_pad), max(hwm[1], packed.tiles.shape[1]),
            max(hwm[2], len(packed.src)))

        key = packed.shape_key(self.spmm_impl)
        if key in self._seen_keys:
            self.jit_stats["hits"] += 1
        else:
            self._seen_keys.add(key)
            self.jit_stats["compiles"] += 1

        if self.spmm_impl in ("block_ell", "fused"):
            operands = {
                "tiles": jnp.asarray(packed.tiles),
                "tile_col": jnp.asarray(packed.tile_col),
                "valid": jnp.asarray(packed.valid),
                "step_active": jnp.asarray(
                    step_active_blocks(packed.hop_rb, nai.t_max)),
            }
            if self.spmm_impl == "fused":
                operands["c_inf"] = jnp.asarray(packed.c_inf)
                operands["s_inf"] = jnp.asarray(packed.s_inf)
        else:
            operands = {"src": jnp.asarray(packed.src),
                        "dst": jnp.asarray(packed.dst),
                        "coef": jnp.asarray(packed.coef)}
        preds, orders = self._runner(self._cls_params, operands,
                                     jnp.asarray(packed.x0),
                                     jnp.asarray(packed.x_inf))
        return (np.asarray(preds)[:packed.nb_real],
                np.asarray(orders)[:packed.nb_real])

    def submit(self, node_ids, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        for nid in np.atleast_1d(node_ids):
            self.queue.append(Request(int(nid), now))

    def _form_batch(self) -> List[Request]:
        batch: List[Request] = []
        deadline = (self.queue[0].arrival_s + self.max_wait_s
                    if self.queue else 0.0)
        while self.queue and len(batch) < self.nai.batch_size:
            batch.append(self.queue.popleft())
            if time.perf_counter() > deadline and len(batch) >= 1:
                # latency bound takes priority over batch fill
                if len(batch) >= self.nai.batch_size // 4:
                    break
        return batch

    def step(self) -> List[Request]:
        """Serve one batch; returns completed requests."""
        batch = self._form_batch()
        if not batch:
            return []
        nodes = np.asarray([r.node_id for r in batch])
        # dedupe per batch (client retries): the sampler requires
        # duplicate-free batches — duplicated rows would double-count in
        # the stationary state and skew every exit distance
        uniq, inv = np.unique(nodes, return_inverse=True)
        if self.mode == "compiled":
            p_u, o_u = self._infer_compiled(uniq)
        else:
            p_u, o_u, _, _, _ = infer_batch_host(
                self.cfg, self.nai, self.params, self.graph, uniq)
        preds, orders = p_u[inv], o_u[inv]
        done = time.perf_counter()
        for r, p, o in zip(batch, preds, orders):
            r.done_s = done
            r.prediction = int(p)
            r.exit_order = int(o)
            self.stats.latencies.append(done - r.arrival_s)
            self.stats.exit_hist[int(o)] = self.stats.exit_hist.get(int(o), 0) + 1
        self.stats.served += len(batch)
        self.stats.batches += 1
        return batch

    def run_until_drained(self) -> EngineStats:
        while self.queue:
            self.step()
        return self.stats
