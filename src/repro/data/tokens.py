"""Deterministic synthetic LM data pipeline.

Generates Markov-ish token streams (a learnable structure, so training loss
actually decreases) plus optional frontend stubs (image patches / audio
frames) for the VLM/audio architectures.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def synthetic_lm_batch(rng: np.random.Generator, batch: int, seq: int,
                       vocab: int, cfg=None) -> Dict[str, np.ndarray]:
    """Order-1 Markov chain over a small latent alphabet mapped into vocab —
    learnable by a tiny LM in a few hundred steps."""
    K = min(64, vocab)
    # fixed transition matrix derived from a seeded generator so every call
    # sees the same language
    tg = np.random.default_rng(0)
    T = tg.dirichlet(np.ones(K) * 0.3, size=K)
    states = rng.integers(0, K, size=(batch,))
    out = np.empty((batch, seq), np.int32)
    for t in range(seq):
        u = rng.random((batch, 1))
        cdf = np.cumsum(T[states], axis=1)
        states = (u < cdf).argmax(axis=1)
        out[:, t] = states
    batch_dict: Dict[str, np.ndarray] = {"tokens": out}
    if cfg is not None:
        d = cfg.d_model
        if cfg.is_encdec:
            batch_dict["frontend"] = rng.standard_normal(
                (batch, cfg.encoder_seq, d)).astype(np.float32)
        elif cfg.num_image_tokens:
            batch_dict["frontend"] = rng.standard_normal(
                (batch, cfg.num_image_tokens, d)).astype(np.float32)
    return batch_dict


def synthetic_stream(seed: int, batch: int, seq: int, vocab: int,
                     cfg=None) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        yield synthetic_lm_batch(rng, batch, seq, vocab, cfg)
