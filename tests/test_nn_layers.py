"""Unit tests for the NN substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import ModelConfig
from repro.nn import basic, attention as A
from repro.nn.params import init_tree
from repro.nn.moe import apply_moe, moe_defs

CFG = ModelConfig(name="t", arch_type="dense", d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97,
                  num_layers=2, dtype="float32", param_dtype="float32")


def test_rmsnorm_unit_scale():
    p = init_tree(jax.random.PRNGKey(0), basic.norm_defs(CFG), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64)) * 7.0
    y = basic.apply_norm(CFG, p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-4)


def test_layernorm_zero_mean():
    cfg = CFG.scaled(norm_kind="layernorm")
    p = init_tree(jax.random.PRNGKey(0), basic.norm_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64)) + 5.0
    y = basic.apply_norm(cfg, p, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)


def test_rotary_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = basic.rotary(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # shifting positions by c rotates q and k identically -> q.k invariant
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16))
    def score(off):
        qr = basic.rotary(q, pos + off, 10000.0)
        kr = basic.rotary(k, pos + off, 10000.0)
        return jnp.einsum("bshe,bthe->bsht", qr, kr)
    np.testing.assert_allclose(score(0), score(17), rtol=1e-3, atol=1e-4)


def test_causal_mask_banded():
    m = A.causal_mask(6, 6, window=2)[0]
    assert bool(m[3, 3]) and bool(m[3, 2])
    assert not bool(m[3, 1])      # outside window
    assert not bool(m[2, 3])      # future


def test_self_attention_causality():
    p = init_tree(jax.random.PRNGKey(0), A.attn_defs(CFG), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    out1, _ = A.self_attention(CFG, p, x, pos)
    x2 = x.at[:, 5:].set(0.0)      # perturb the future
    out2, _ = A.self_attention(CFG, p, x2, pos)
    np.testing.assert_allclose(out1[:, :5], out2[:, :5], atol=1e-5)


def test_chunked_attention_matches_dense():
    p = init_tree(jax.random.PRNGKey(0), A.attn_defs(CFG), "float32")
    S = A.Q_CHUNK * 2
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 64))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    dense, _ = A.self_attention(CFG, p, x, pos)
    old = A.CHUNK_THRESHOLD
    try:
        A.CHUNK_THRESHOLD = 16
        chunked, _ = A.self_attention(CFG, p, x, pos)
    finally:
        A.CHUNK_THRESHOLD = old
    np.testing.assert_allclose(dense, chunked, atol=2e-5)


@pytest.mark.parametrize("kind", ["swiglu", "geglu", "gelu"])
def test_mlp_kinds(kind):
    cfg = CFG.scaled(mlp_kind=kind)
    p = init_tree(jax.random.PRNGKey(0), basic.mlp_defs(cfg), "float32")
    y = basic.apply_mlp(cfg, p, jnp.ones((2, 3, 64)))
    assert y.shape == (2, 3, 64)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_capacity_and_aux():
    cfg = CFG.scaled(num_experts=4, experts_per_token=2, capacity_factor=8.0)
    p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    y, aux = apply_moe(cfg, p, x)
    assert y.shape == x.shape
    assert float(aux) > 0.0
    # with huge capacity nothing drops: output invariant to batch order
    perm = jnp.array([1, 0])
    y2, _ = apply_moe(cfg, p, x[perm])
    np.testing.assert_allclose(y2, y[perm], atol=1e-5)


def test_moe_top1_rowsum():
    """top-k gate weights renormalize to 1 -> identical expert weights give
    the dense-FFN result regardless of routing."""
    cfg = CFG.scaled(num_experts=4, experts_per_token=2, capacity_factor=8.0)
    p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg), "float32")
    p = dict(p, w_gate=jnp.broadcast_to(p["w_gate"][:1], p["w_gate"].shape),
             w_up=jnp.broadcast_to(p["w_up"][:1], p["w_up"].shape),
             w_down=jnp.broadcast_to(p["w_down"][:1], p["w_down"].shape))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 64))
    y, _ = apply_moe(cfg, p, x)
    h = jax.nn.silu(x @ p["w_gate"][0]) * (x @ p["w_up"][0])
    dense = h @ p["w_down"][0]
    np.testing.assert_allclose(y, dense, atol=1e-4)
