"""Tiled online-softmax (flash) attention Pallas kernel.

Causal attention with optional sliding-window banding — the kernel behind
the `local` layers (RecurrentGemma) and the beyond-paper sliding-window
serving variant that lets full-attention architectures run long_500k.

Grid: (batch*heads, q_blocks, k_blocks), k innermost. Running max / sum /
accumulator live in VMEM scratch; fully-masked k blocks are skipped with
`@pl.when` (the flash-style compute saving — for a window W only ~W/S of
blocks are touched)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_first = qi * BQ                 # absolute first query position
    k_first = ki * BK
    # block-level skip: entirely above the diagonal or left of the window
    skip = False
    if causal:
        relevant = k_first <= q_first + BQ - 1
        if window > 0:
            relevant &= (k_first + BK - 1) > (q_first - window)
    else:
        relevant = True

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
            kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
            mask = kpos <= qpos
            if window > 0:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool = True):
    """q, k, v: (BH, S, hd) with S % BQ == 0 == S % BK.
    Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    Sk = k.shape[1]
    assert S % BQ == 0 and Sk % BK == 0, (S, Sk)
    scale = 1.0 / (hd ** 0.5)
    grid = (BH, S // BQ, Sk // BK)
    fn = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, BK, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, BK, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v)
