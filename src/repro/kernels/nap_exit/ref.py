"""Pure-jnp oracle for the nap_exit kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.nap_exit.kernel import NB


def ref_nap_exit(x, x_inf, active, t_s):
    diff = (x - x_inf).astype(jnp.float32)
    dist2 = jnp.sum(diff * diff, axis=1, keepdims=True)
    was_active = active != 0
    exits = was_active & (dist2 < t_s * t_s)
    still = was_active & ~exits
    blk = still.reshape(-1, NB).any(axis=1, keepdims=True).astype(jnp.int32)
    return dist2, exits.astype(jnp.int32), blk
