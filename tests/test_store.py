"""GraphStore tests: the MmapStore/InMemoryStore bit-parity the store
redesign promises (same CSR, same features => same sampling, packing,
predictions AND exit orders), the save/load round trip, the strict
store-first sampler contract, and hypothesis properties of the
synthetic power-law generator (valid CSR, deterministic under seed,
in-RAM == on-disk generation)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.gnn.sampler import sample_support, _sample_support_legacy
from repro.gnn.store import (FORMAT, GraphStore, InMemoryStore, MmapStore,
                             as_store, make_graph, save_graph_store)
from repro.serving import EngineConfig, NAIServingEngine


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g = load_dataset("pubmed-like", scale=0.02, seed=4)
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
    path = str(tmp_path_factory.mktemp("store") / "pubmed_store")
    save_graph_store(g, path)
    return g, cfg, params, nai, path


def _serve(engine, nodes):
    engine.submit(nodes)
    done = []
    while engine.queue:
        done += engine.step()
    done += engine.flush()
    assert [r.node_id for r in done] == list(map(int, nodes))
    return (np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))


# ------------------------------------------------------- store contract
def test_inmemory_store_is_zero_copy(setup):
    g, *_ = setup
    store = InMemoryStore(g)
    row_ptr, col_idx = g.csr()
    assert store.row_ptr is row_ptr and store.col_idx is col_idx
    assert store.features is g.features
    assert store.num_edges == g.num_edges
    assert store.num_self_loops == g.num_self_loops
    np.testing.assert_array_equal(store.degrees, g.degrees)


def test_save_load_round_trip_bit_identical(setup):
    g, _, _, _, path = setup
    mem = InMemoryStore(g)
    for mmap in (True, False):
        st = MmapStore(path, mmap=mmap)
        assert (st.n, st.feat_dim, st.num_classes) == \
            (mem.n, mem.feat_dim, mem.num_classes)
        assert st.num_edges == mem.num_edges
        assert st.num_self_loops == mem.num_self_loops
        assert st.meta["format"] == FORMAT
        np.testing.assert_array_equal(st.row_ptr, mem.row_ptr)
        np.testing.assert_array_equal(st.col_idx, mem.col_idx)
        np.testing.assert_array_equal(st.degrees, mem.degrees)
        np.testing.assert_array_equal(st.features, mem.features)
        np.testing.assert_array_equal(st.labels, mem.labels)


def test_mmap_gather_bounded_residency_is_lossless(setup):
    """The residency guards (pread-based row gathers + budgeted
    MADV_DONTNEED drops of the mapped CSR views) must be invisible to
    callers: gathers past the budget (which trigger drop-resident
    cycles) stay bit-identical to the eager store, and the gathered-
    bytes estimate resets on every drop."""
    g, _, _, _, path = setup
    tiny_budget = 1 << 16   # force a drop every couple of gathers
    st = MmapStore(path, resident_budget=tiny_budget)
    eager = MmapStore(path, mmap=False)
    rng = np.random.default_rng(0)
    for _ in range(20):
        nodes = np.sort(rng.choice(st.n, size=64, replace=False))
        np.testing.assert_array_equal(st.gather_features(nodes),
                                      eager.gather_features(nodes))
        assert st._touched_est < tiny_budget   # auto-drop reset it
    assert st.drop_resident() >= 0
    assert st._touched_est == 0
    # in-RAM stores expose the same method as a no-op
    assert InMemoryStore(g).drop_resident() == 0
    assert eager.drop_resident() == 0


def test_as_store_memoizes_and_sampler_is_strict(setup):
    g, *_ = setup
    s1 = as_store(g)
    s2 = as_store(g)
    assert s1 is s2 and isinstance(s1, InMemoryStore)
    assert as_store(s1) is s1
    with pytest.raises(TypeError):
        as_store(np.arange(3))
    # the memoized wrap is bit-identical to a fresh zero-copy wrap
    fresh = InMemoryStore(g)
    assert s1.features is g.features is fresh.features
    np.testing.assert_array_equal(s1.row_ptr, fresh.row_ptr)
    np.testing.assert_array_equal(s1.col_idx, fresh.col_idx)
    np.testing.assert_array_equal(s1.degrees, fresh.degrees)
    assert s1.num_edges == fresh.num_edges
    # the positional-Graph deprecation shim is retired: sample_support
    # is store-first and a raw Graph is a TypeError, not a warning
    nodes = g.test_idx[:8]
    with pytest.raises(TypeError, match="store-first"):
        sample_support(g, nodes, 1, 0.5)


def test_sampler_accepts_store_and_matches_wrapped_graph(setup):
    g, cfg, _, nai, path = setup
    store = MmapStore(path)
    rng = np.random.default_rng(0)
    nodes = rng.choice(g.test_idx, size=32, replace=False)
    sup_m = sample_support(store, nodes, nai.t_max, cfg.r)
    sup_g = sample_support(as_store(g), nodes, nai.t_max, cfg.r)
    sup_o = _sample_support_legacy(store, nodes, nai.t_max, cfg.r)
    for a, b in ((sup_m, sup_g), (sup_m, sup_o)):
        np.testing.assert_array_equal(a.nodes, b.nodes)
        np.testing.assert_array_equal(a.hop, b.hop)
        np.testing.assert_array_equal(a.src, b.src)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.coef, b.coef)
        assert a.sub_edges == b.sub_edges


def test_mmap_serving_bit_identical_to_in_memory(setup):
    """The acceptance property: the SAME graph served from disk
    (MmapStore) and from RAM (InMemoryStore of the original Graph) must
    produce identical predictions AND exit orders, in host and compiled
    mode."""
    g, cfg, params, nai, path = setup
    rng = np.random.default_rng(1)
    for mode in ("host", "compiled"):
        mem = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                               mode=mode)
        mm = NAIServingEngine(cfg, nai, params, MmapStore(path),
                              max_wait_s=10.0, mode=mode)
        for _ in range(2):
            nodes = rng.choice(g.test_idx, size=32, replace=False)
            p_mem, o_mem = _serve(mem, nodes)
            p_mm, o_mm = _serve(mm, nodes)
            np.testing.assert_array_equal(p_mm, p_mem)
            np.testing.assert_array_equal(o_mm, o_mem)
            assert (p_mm >= 0).all()


# -------------------------------------------------- power-law generator
def test_make_graph_in_ram_equals_on_disk(tmp_path):
    ram = make_graph(3000, avg_deg=6.0, alpha=2.2, seed=11, feat_dim=8)
    disk = make_graph(3000, avg_deg=6.0, alpha=2.2, seed=11, feat_dim=8,
                      path=str(tmp_path / "g"))
    assert isinstance(ram, InMemoryStore) and isinstance(disk, MmapStore)
    np.testing.assert_array_equal(ram.row_ptr, disk.row_ptr)
    np.testing.assert_array_equal(ram.col_idx, disk.col_idx)
    np.testing.assert_array_equal(ram.degrees, disk.degrees)
    np.testing.assert_array_equal(ram.labels, disk.labels)
    np.testing.assert_array_equal(ram.features, disk.features)
    assert ram.num_edges == disk.num_edges
    assert ram.num_self_loops == disk.num_self_loops == 3000


def test_make_graph_requires_seed_and_min_size():
    with pytest.raises(ValueError):
        make_graph(1)
    with pytest.raises(ValueError):
        make_graph(100, seed=None)


def _assert_valid_csr(store: GraphStore):
    row_ptr = np.asarray(store.row_ptr)
    col_idx = np.asarray(store.col_idx)
    n = store.n
    assert row_ptr.shape == (n + 1,) and row_ptr[0] == 0
    assert (np.diff(row_ptr) >= 1).all()          # sorted, every row has
    assert row_ptr[-1] == len(col_idx)            # at least its self loop
    assert (col_idx >= 0).all() and (col_idx < n).all()
    # exactly one self loop per row, stored last in its row
    last = col_idx[row_ptr[1:] - 1]
    np.testing.assert_array_equal(last, np.arange(n))
    dst = np.repeat(np.arange(n), np.diff(row_ptr))
    assert int((col_idx == dst).sum()) == n
    # persisted metadata agrees with a recount
    deg = np.diff(row_ptr) - 1                    # in-degree sans loop
    np.testing.assert_array_equal(store.degrees, deg)
    assert store.num_self_loops == n
    assert store.num_edges == (len(col_idx) - n) // 2


@pytest.mark.parametrize("n,avg_deg,alpha,seed", [
    (2, 1.0, 1.6, 0), (7, 3.0, 2.0, 1), (63, 8.0, 2.2, 42),
    (128, 2.5, 3.5, 7), (400, 12.0, 1.8, 2**31 - 1),
])
def test_make_graph_valid_csr_seeded_grid(n, avg_deg, alpha, seed):
    """Deterministic slice of the hypothesis property below — runs even
    where hypothesis is unavailable (the CI image has no pip access)."""
    s1 = make_graph(n, avg_deg, alpha, seed, feat_dim=4, num_classes=3)
    _assert_valid_csr(s1)
    s2 = make_graph(n, avg_deg, alpha, seed, feat_dim=4, num_classes=3)
    np.testing.assert_array_equal(s1.col_idx, s2.col_idx)
    np.testing.assert_array_equal(s1.features, s2.features)


def test_make_graph_emits_valid_csr_property():
    hyp = pytest.importorskip("hypothesis")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 400), avg_deg=st.floats(1.0, 12.0),
           alpha=st.floats(1.6, 3.5), seed=st.integers(0, 2**31 - 1))
    def prop(n, avg_deg, alpha, seed):
        s1 = make_graph(n, avg_deg, alpha, seed, feat_dim=4,
                        num_classes=3)
        _assert_valid_csr(s1)
        # deterministic under seed
        s2 = make_graph(n, avg_deg, alpha, seed, feat_dim=4,
                        num_classes=3)
        np.testing.assert_array_equal(s1.col_idx, s2.col_idx)
        np.testing.assert_array_equal(s1.features, s2.features)

    prop()


def test_make_graph_store_serves_end_to_end(tmp_path):
    """A generated on-disk store drives the full serving path."""
    store = make_graph(2000, avg_deg=5.0, alpha=2.2, seed=3, feat_dim=16,
                       num_classes=4, path=str(tmp_path / "g"))
    cfg = GNNConfig("sgc", 16, store.num_classes, k=2, hidden=8,
                    mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=16)
    eng = NAIServingEngine(cfg, nai, params, store, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment")
    nodes = np.arange(16) * 100
    preds, orders = _serve(eng, nodes)
    assert (preds >= 0).all() and set(orders) <= {1, 2}


# --------------------------------------------------------- EngineConfig
def test_engine_config_validation():
    for bad in (dict(mode="warp"), dict(spmm_impl="nope"),
                dict(gather_mode="psychic"), dict(pipeline_depth=0),
                dict(mode="host", pipeline_depth=2),
                dict(mode="host", mesh=object()),
                dict(max_wait_s=-1.0), dict(latency_window=0)):
        with pytest.raises(ValueError):
            EngineConfig(**bad)
    ec = EngineConfig(mode="compiled", pipeline_depth=2)
    assert dataclasses.replace(ec, spmm_impl="segment").pipeline_depth == 2


def test_engine_config_and_kwargs_are_exclusive(setup):
    g, cfg, params, nai, _ = setup
    with pytest.raises(ValueError):
        NAIServingEngine(cfg, nai, params, g,
                         config=EngineConfig(), max_wait_s=1.0)


def test_engine_config_equivalent_to_kwargs(setup):
    g, cfg, params, nai, _ = setup
    ec = EngineConfig(mode="compiled", spmm_impl="segment",
                      pipeline_depth=2, max_wait_s=10.0)
    a = NAIServingEngine(cfg, nai, params, g, config=ec)
    b = NAIServingEngine(cfg, nai, params, g, mode="compiled",
                         spmm_impl="segment", pipeline_depth=2,
                         max_wait_s=10.0)
    assert a.config == b.config == ec
    rng = np.random.default_rng(5)
    nodes = rng.choice(g.test_idx, size=32, replace=False)
    pa, oa = _serve(a, nodes)
    pb, ob = _serve(b, nodes)
    np.testing.assert_array_equal(pa, pb)
    np.testing.assert_array_equal(oa, ob)
