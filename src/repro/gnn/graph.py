"""Graph container + normalized adjacency utilities.

Graphs are stored as COO edge lists (numpy on host, jnp in compiled code)
with CSR indptr for neighborhood queries. The propagation operator
Â = D̃^{r-1} Ã D̃^{-r} (paper Eq. 1) is materialized as per-edge
coefficients; self-loops are explicit edges.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    n: int
    src: np.ndarray            # (E,) int32 — edge source (col j)
    dst: np.ndarray            # (E,) int32 — edge destination (row i)
    features: np.ndarray       # (n, f) float32
    labels: np.ndarray         # (n,) int32
    num_classes: int
    train_idx: np.ndarray      # labeled training nodes (V_l)
    unlabeled_idx: np.ndarray  # unlabeled training nodes (V_u)
    test_idx: np.ndarray       # V_test (unseen during training)
    name: str = "graph"

    # -- caches
    _indptr: Optional[np.ndarray] = None
    _neighbors: Optional[np.ndarray] = None
    _order: Optional[np.ndarray] = None

    @property
    def num_self_loops(self) -> int:
        """Count of explicitly stored self loops. The full graph carries
        one per node (`add_self_loops`), but `train_subgraph()` keeps
        only the loops of retained nodes — so this is counted, never
        assumed to equal n."""
        return int((self.src == self.dst).sum())

    @property
    def num_edges(self) -> int:
        """Undirected edge count m (each stored twice; self loops stored
        once and excluded). Counts actual self loops rather than assuming
        one per node: after `train_subgraph()` only kept nodes retain
        theirs, and the old `(E - n) // 2` undercounted by
        (n - n_train) / 2 — going negative on small splits and poisoning
        the `stationary_weights` denominator 2m + n."""
        return (len(self.src) - self.num_self_loops) // 2

    @property
    def degrees(self) -> np.ndarray:
        """Degree WITHOUT self loop (d_i in the paper). Subtracts each
        node's actual stored self loops, so nodes whose loop was dropped
        by `train_subgraph()` report 0, not -1."""
        deg = np.bincount(self.dst, minlength=self.n)
        loops = np.bincount(self.dst[self.src == self.dst],
                            minlength=self.n)
        return (deg - loops).astype(np.int64)

    def csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """(indptr, neighbors) sorted by dst: in-neighbors of each node."""
        if self._indptr is None:
            self._order = np.argsort(self.dst, kind="stable")
            self._neighbors = self.src[self._order].astype(np.int32)
            counts = np.bincount(self.dst, minlength=self.n)
            self._indptr = np.concatenate(
                [[0], np.cumsum(counts)]).astype(np.int64)
        return self._indptr, self._neighbors

    def train_subgraph(self) -> "Graph":
        """G_train: induced on V_train (paper §2.1 inductive setting)."""
        keep = np.zeros(self.n, bool)
        train_all = np.concatenate([self.train_idx, self.unlabeled_idx])
        keep[train_all] = True
        emask = keep[self.src] & keep[self.dst]
        return dataclasses.replace(
            self, src=self.src[emask], dst=self.dst[emask],
            _indptr=None, _neighbors=None, name=self.name + "-train")


def add_self_loops(src: np.ndarray, dst: np.ndarray, n: int):
    loop = np.arange(n, dtype=np.int32)
    return (np.concatenate([src.astype(np.int32), loop]),
            np.concatenate([dst.astype(np.int32), loop]))


def edge_coefficients(g: Graph, r: float = 0.5) -> np.ndarray:
    """Per-edge weight of Â = D̃^{r-1} Ã D̃^{-r}:
    coef(j->i) = (d_i+1)^{r-1} (d_j+1)^{-r}."""
    dt = (g.degrees + 1).astype(np.float64)
    return (dt[g.dst] ** (r - 1.0) * dt[g.src] ** (-r)).astype(np.float32)


def stationary_weights(g: Graph, r: float = 0.5):
    """Rank-1 factors of Â^∞ (paper Eq. 7):
    X∞[i] = a[i] * (b @ X) with a[i]=(d_i+1)^r/(2m+n), b[j]=(d_j+1)^{1-r}.
    Never materializes the n×n matrix (TPU adaptation, DESIGN.md §3)."""
    dt = (g.degrees + 1).astype(np.float64)
    denom = 2.0 * g.num_edges + g.n
    a = (dt ** r / denom).astype(np.float32)
    b = (dt ** (1.0 - r)).astype(np.float32)
    return a, b


def spmm(g: Graph, coef: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Host SpMM: out[i] = sum_j coef(j->i) x[j]. CSR segment-reduce;
    robust to isolated nodes (empty segments, e.g. after train_subgraph)."""
    indptr, nbr = g.csr()
    vals = coef[g._order, None] * x[nbr]
    out = np.zeros_like(x)
    counts = np.diff(indptr)
    nz = counts > 0
    starts = indptr[:-1][nz]
    if len(starts):
        out[nz] = np.add.reduceat(vals, starts, axis=0)
    return out.astype(x.dtype)


def propagated_series(g: Graph, x: np.ndarray, k: int, r: float = 0.5):
    """[X^(0), X^(1), ..., X^(k)] with X^(l) = Â^l X."""
    coef = edge_coefficients(g, r)
    out = [x.astype(np.float32)]
    for _ in range(k):
        out.append(spmm(g, coef, out[-1]))
    return out
