"""gemma-7b — dense, GeGLU, head_dim 256 [arXiv:2403.08295].
28L, d_model 3072, 16 heads (kv=16; the 2b sibling uses MQA), d_ff 24576,
vocab 256000, tied embeddings, sqrt(d) embedding scale."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    source="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=("attn",),
    mlp_kind="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed_sqrt_d=True,
)
