"""Per-node propagated-feature cache with graph-delta invalidation.

Caches the full propagation series ``X^(1..t_max)[v]`` per node, filled
from the batch-row series the engine already carries (those rows are
hop 0 in their own batch, so every stored step is the exact global
value).  The sampler consults the cache during frontier expansion
(`probe`), and hit rows are *seeded* into the NAP loop at their stored
values instead of being re-propagated from x0 — see
``packing.pack_support(seeds=...)`` and ``backends._masked_loop``.

Invalidation is block-granular: every cache entry records the store's
``mutation_clock`` at sample time (``gv``) plus the set of
``VERSION_BLOCK`` superblocks its value depends on (all support nodes of
the batch that produced it — a conservative superset of the true l-hop
dependency cone).  ``GraphStore.add_edges`` stamps only the endpoint
blocks, so an entry survives mutations that touch unrelated blocks and
goes stale exactly when a dependency block is stamped after ``gv``.

Thread-safety: none — the cache lives in the engine's host stage, which
is single-threaded by construction.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List

import numpy as np

from .store import VERSION_BLOCK, GraphStore

__all__ = ["PropCache"]


class _FillEvent:
    """Shared validity record for every entry inserted by one fill.

    All rows filled from one batch share the same ``gv`` (mutation clock
    at sample time) and the same dependency-block set, so staleness is
    checked once per event per mutation-clock value and memoized.
    """

    __slots__ = ("gv", "dep_blocks", "_checked_clock", "_valid")

    def __init__(self, gv: int, dep_blocks: np.ndarray):
        self.gv = gv
        self.dep_blocks = dep_blocks  # sorted unique int64 block ids
        self._checked_clock = -1
        self._valid = True

    def valid(self, block_versions: np.ndarray, clock: int) -> bool:
        if not self._valid:
            return False
        if clock == self._checked_clock:
            return True
        # A block id past the end of `block_versions` can only belong to
        # nodes added after this fill — those rows were never sources
        # for it, and add_nodes stamps only the new blocks, so treat
        # missing blocks as unstamped.
        blocks = self.dep_blocks
        if len(blocks) and blocks[-1] >= len(block_versions):
            blocks = blocks[blocks < len(block_versions)]
        ok = bool(np.all(block_versions[blocks] <= self.gv))
        if ok:
            self._checked_clock = clock
        else:
            self._valid = False
        return ok


class PropCache:
    """LRU cache of propagated-feature series, partitioned by shard.

    Parameters
    ----------
    capacity:
        Maximum number of cached nodes (across all partitions).
    t_max:
        Propagation depth of the stored series; ``gather`` returns
        arrays of shape ``(k, t_max, f)``.
    n_shards:
        Number of shard-local partitions.  Each node belongs to
        partition ``(node // VERSION_BLOCK) % n_shards`` — the same
        CB-superblock round-robin the packer uses to assign row
        ownership, so at D>1 each partition caches (approximately) the
        rows its shard owns.  Capacity is split evenly.
    """

    def __init__(self, capacity: int, t_max: int, *, n_shards: int = 1):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if t_max < 1:
            raise ValueError(f"t_max must be >= 1, got {t_max}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.capacity = int(capacity)
        self.t_max = int(t_max)
        self.n_shards = int(n_shards)
        self._cap_per = max(1, self.capacity // self.n_shards)
        # node -> (event, vals (t_max, f));  OrderedDict == LRU order
        self._parts: List[OrderedDict] = [OrderedDict() for _ in range(self.n_shards)]
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.fills = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return sum(len(p) for p in self._parts)

    def _part_of(self, node: int) -> OrderedDict:
        return self._parts[(node // VERSION_BLOCK) % self.n_shards]

    # ------------------------------------------------------------------
    def probe(self, store: GraphStore, nodes: np.ndarray) -> np.ndarray:
        """Mark hits among ``nodes``; returns a boolean hit mask.

        Bumps LRU recency for hits, evicts entries discovered stale, and
        updates hit/miss/stale counters.  Never inserts, so a later
        ``gather`` on the hit subset cannot race an eviction.
        """
        bv = store.block_versions
        clock = store.mutation_clock
        mask = np.zeros(len(nodes), dtype=bool)
        if len(self) == 0:          # empty (e.g. fills disabled): skip
            self.misses += len(nodes)   # the per-node lookup loop
            return mask
        for i, node in enumerate(nodes):
            node = int(node)
            part = self._part_of(node)
            entry = part.get(node)
            if entry is None:
                self.misses += 1
                continue
            if not entry[0].valid(bv, clock):
                del part[node]
                self.stale += 1
                self.misses += 1
                continue
            part.move_to_end(node)
            self.hits += 1
            mask[i] = True
        return mask

    def gather(self, nodes: np.ndarray) -> np.ndarray:
        """Stack cached series for ``nodes`` -> ``(k, t_max, f)``.

        Every node must have hit in a preceding ``probe`` with no
        intervening ``fill`` or mutation (the engine's host stage
        guarantees this ordering).
        """
        if len(nodes) == 0:
            return np.zeros((0, self.t_max, 0), dtype=np.float32)
        return np.stack([self._part_of(int(n))[int(n)][1] for n in nodes])

    def fill(
        self,
        store: GraphStore,
        nodes: np.ndarray,
        series: np.ndarray,
        dep_nodes: np.ndarray,
        gv: int,
    ) -> None:
        """Insert series rows for ``nodes`` (shape ``(k, t_max, f)``).

        ``dep_nodes`` is the full support node set of the batch that
        produced the series (a conservative superset of each row's true
        dependency cone); ``gv`` is the store's mutation clock at
        *sample* time.  If the graph mutated between sampling and fill,
        the entries are inserted with the older ``gv`` and go stale on
        their first probe — sound, just wasted work.
        """
        if series.shape[:2] != (len(nodes), self.t_max):
            raise ValueError(
                f"series shape {series.shape} != ({len(nodes)}, {self.t_max}, f)"
            )
        event = _FillEvent(
            int(gv), np.unique(np.asarray(dep_nodes, dtype=np.int64) // VERSION_BLOCK)
        )
        for i, node in enumerate(nodes):
            node = int(node)
            part = self._part_of(node)
            if node in part:
                del part[node]
            # copy: `series` is typically a view into a donated/reused
            # device buffer — holding it would pin the whole base array
            part[node] = (event, np.ascontiguousarray(series[i]))
            self.fills += 1
            while len(part) > self._cap_per:
                part.popitem(last=False)
                self.evictions += 1

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        probes = self.hits + self.misses
        return {
            "size": len(self),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "stale": self.stale,
            "fills": self.fills,
            "evictions": self.evictions,
            "hit_rate": (self.hits / probes) if probes else 0.0,
        }

    def reset_stats(self) -> None:
        """Zero the counters; cached entries are kept."""
        self.hits = self.misses = self.stale = self.fills = self.evictions = 0

    def clear(self) -> None:
        for p in self._parts:
            p.clear()
