"""Chaos benchmark: goodput under seeded fault schedules through the
full serving stack (store -> engine pipeline -> SLO front-end).

`benchmarks.frontend_bench` measures the fault-FREE serving story; this
bench drives deterministic `FaultPlan` schedules (repro.serving.faults)
through the same stack and gates the failure story:

* **Conservation** (the system property that makes isolation real, not
  a pile of try/excepts): under EVERY scenario, each submitted request
  terminates exactly once — ``offered == rejected + completed + failed``
  per class, no request lost, none finalized twice, nothing pending
  after the drain — and the pipeline never deadlocks (wall-clock
  bounded drain).
* **Fault-free bit-parity**: a front-end with the whole isolation stack
  armed but idle (empty plan, watchdog, NaN guard, breaker) serves a
  deterministic virtual-clock trace bit-identically to a plain
  front-end — zero failed/degraded requests, zero breaker transitions,
  identical predictions and exit orders.
* **Goodput under faults**: the committed ``baseline`` scenario (1%
  random batch failures plus a concentrated burst that trips the gold
  circuit breaker) must keep total goodput within ``min_ratio`` of the
  clean run — demotion onto the best-effort engine and the bounded
  shed are what hold it up — and the breaker's open/half-open/closed
  transitions are recorded in the payload.

Scenarios (all seeded, all replayable):

  ``clean``          empty plan, breaker armed — the goodput denominator
  ``store_io``       injected StoreIOError + latency on gathers, with
                     the reference-path retry recovering most batches
  ``host_crash``     host-stage exceptions + straggler sleeps (gold)
  ``device_nan``     NaN logits from the device stage; the NaN guard
                     fails the batch, the retry completes it host-side
  ``hang_watchdog``  a never-ready device future; the watchdog declares
                     the batch hung and re-arms the pipeline
  ``baseline``       1% device faults + a burst window: breaker trips,
                     demotes gold onto best_effort, recovers via probes

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.chaos_bench [--smoke] [--check]
                                                    [--out F]

Full runs merge the payload under the ``"chaos"`` key of
``BENCH_serving.json``; ``--smoke`` writes a standalone (gitignored)
``BENCH_chaos_smoke.json``. ``--check`` exits nonzero on any
conservation/parity/goodput/breaker gate failure — the CI guard.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

if __package__ in (None, ""):     # `python benchmarks/chaos_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import jax
import numpy as np

from benchmarks.common import csv_row, write_bench_json
from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.gnn.store import as_store
from repro.serving import (BreakerConfig, EngineConfig, FaultPlan,
                           FaultSpec, FaultyStore, ServingFrontend,
                           SLOClass)

IMPL = "segment"          # reference backend: cheap, real async dispatch
BUDGET_S = 2.0            # per-request deadline budget (generous: the
                          # bench gates failure handling, not latency)
MIN_GOODPUT_RATIO = 0.5   # baseline-vs-clean goodput gate (stated
                          # fraction; typical observed ratio is ~1.0
                          # because demotion keeps gold completing)


def _setup(smoke: bool):
    g = load_dataset("pubmed-like", scale=0.02 if smoke else 0.05, seed=0)
    feat = 64
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :feat]))
    cfg = GNNConfig("sgc", feat, g.num_classes, k=2, hidden=32,
                    mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2,
                    batch_size=8 if smoke else 16)
    return g, cfg, params, nai


def _breaker() -> BreakerConfig:
    # misses are excluded so tripping is failure-driven — a contended CI
    # runner's latency noise must not open breakers in the clean run
    return BreakerConfig(window=32, trip_frac=0.5, min_events=16,
                         cooldown_s=0.3, probes=2, open_depth_frac=0.5,
                         count_misses=False)


def _frontend(g, cfg, params, nai, *, gold_plan: Optional[FaultPlan],
              watchdog: Optional[float], retry: bool,
              breaker: Optional[BreakerConfig], depth: int = 2
              ) -> ServingFrontend:
    """Two-tier front-end; the fault plan (if any) rides on the GOLD
    engine's config, so best_effort stays a clean degradation target."""
    qd = 4 * nai.batch_size
    base = dict(mode="compiled", spmm_impl=IMPL, pipeline_depth=depth,
                watchdog_s=watchdog, retry_failed=retry)
    classes = [
        SLOClass("gold", nai, deadline_s=BUDGET_S, max_wait_s=0.002,
                 queue_depth=qd, demote_to="best_effort",
                 engine=EngineConfig(**base, faults=gold_plan)),
        SLOClass("best_effort", dataclasses.replace(nai, t_max=nai.t_min),
                 deadline_s=BUDGET_S, max_wait_s=0.002, queue_depth=qd),
    ]
    return ServingFrontend(cfg, params, g, classes, breaker=breaker,
                           engine=EngineConfig(**base))


# ------------------------------------------------ conservation ledger
def _conservation(fe: ServingFrontend, accepted: List, terminal: List
                  ) -> List[str]:
    errs = []
    ids = [id(r) for r in terminal]
    if len(ids) != len(set(ids)):
        errs.append("a request was finalized more than once")
    if set(ids) != set(id(r) for r in accepted):
        errs.append(f"lost/phantom requests: accepted {len(accepted)}, "
                    f"terminal {len(set(ids))}")
    if fe.pending() != 0:
        errs.append(f"{fe.pending()} requests still pending after drain")
    for r in accepted:
        if r.status not in ("completed", "failed"):
            errs.append(f"non-terminal status {r.status!r} after drain")
            break
    for name, st in fe.stats.items():
        if st.offered != st.accepted + st.rejected:
            errs.append(f"{name}: offered {st.offered} != accepted "
                        f"{st.accepted} + rejected {st.rejected}")
        if st.accepted != st.completed + st.failed:
            errs.append(f"{name}: accepted {st.accepted} != completed "
                        f"{st.completed} + failed {st.failed}")
    return errs


# --------------------------------------------- fault-free parity gate
def _trace(g, nai, n_bursts: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    events: List[Tuple[float, str, int]] = []
    t = 0.0
    for _ in range(n_bursts):
        size = int(rng.integers(nai.batch_size // 2,
                                2 * nai.batch_size + 1))
        for nid in rng.choice(g.test_idx, size=size, replace=True):
            cls = "gold" if rng.random() < 0.5 else "best_effort"
            events.append((t, cls, int(nid)))
            t += 1e-4
        t += 0.2
    return events


def _replay(fe: ServingFrontend, events) -> List:
    reqs = []
    for t, cls, nid in events:
        r = fe.submit(nid, cls, now=t, budget_s=1e9)
        assert r is not None
        reqs.append(r)
        fe.step(now=t)
    fe.step(now=events[-1][0] + 100.0)
    fe.flush()
    return reqs


def _parity_fault_free(g, cfg, params, nai, smoke: bool) -> Dict:
    """The isolation stack armed but idle must be invisible: identical
    predictions/exit orders, zero failed/degraded, zero transitions."""
    events = _trace(g, nai, n_bursts=4 if smoke else 8, seed=1)
    plain = _frontend(g, cfg, params, nai, gold_plan=None, watchdog=None,
                      retry=False, breaker=None)
    wired = _frontend(g, cfg, params, nai, gold_plan=FaultPlan(),
                      watchdog=5.0, retry=True, breaker=_breaker())
    r0 = _replay(plain, events)
    r1 = _replay(wired, events)
    bit_identical = (
        [(r.node_id, r.prediction, r.exit_order) for r in r0]
        == [(r.node_id, r.prediction, r.exit_order) for r in r1])
    errs = _conservation(wired, r1, r1)
    out = {
        "trace_requests": len(events),
        "parity_fault_free": bool(bit_identical),
        "wired_failed": sum(st.failed for st in wired.stats.values()),
        "wired_degraded": sum(st.degraded
                              for st in wired.stats.values()),
        "breaker_transitions": sum(len(b.transitions)
                                   for b in wired.breakers.values()),
        "conservation_errors": errs,
    }
    plain.close()
    wired.close()
    return out


# ------------------------------------------------------ scenario runs
def _run_scenario(name: str, g, cfg, params, nai, smoke: bool,
                  *, gold_plan: Optional[FaultPlan] = None,
                  store_plan: Optional[FaultPlan] = None,
                  watchdog: Optional[float] = None, retry: bool = False,
                  recover: bool = False) -> Dict:
    """Real-clock run of one fault schedule: seeded bursty arrivals,
    non-blocking pumping, bounded drain, conservation ledger."""
    bursts = 8 if smoke else 16
    burst_size = int(1.5 * nai.batch_size)
    wall_guard = 60.0
    store_inj = store_plan.injector() if store_plan is not None else None
    graph = (FaultyStore(as_store(g), store_inj)
             if store_inj is not None else g)
    fe = _frontend(graph, cfg, params, nai, gold_plan=gold_plan,
                   watchdog=watchdog, retry=retry, breaker=_breaker())
    rng = np.random.default_rng(17)
    accepted: List = []
    terminal: List = []
    t0 = time.perf_counter()
    deadline = t0 + wall_guard

    def pump(budget_s: float) -> None:
        guard = time.perf_counter() + budget_s
        while time.perf_counter() < min(guard, deadline):
            terminal.extend(fe.step())
            if not fe.pending():
                return
            time.sleep(5e-4)

    def offer(size: int) -> None:
        for nid in rng.choice(g.test_idx, size=size, replace=True):
            cls = "gold" if rng.random() < 0.6 else "best_effort"
            r = fe.submit(int(nid), cls, budget_s=BUDGET_S)
            if r is not None:
                accepted.append(r)

    for _ in range(bursts):
        offer(burst_size)
        pump(0.05 if watchdog is None else watchdog + 0.1)
    if recover:
        # keep offering gold probes until the breaker closes again (or
        # the wall guard trips) — the recovery arc is part of the gate
        brk = fe.breakers["gold"]
        while (brk.state != "closed"
               and time.perf_counter() < deadline):
            offer(4)
            pump(0.1)
            time.sleep(0.05)
    pump(wall_guard)                      # bounded drain
    deadlock = fe.pending() != 0
    if not deadlock:
        terminal.extend(fe.flush())
    wall_s = time.perf_counter() - t0

    brk = fe.breakers["gold"]
    errs = [] if deadlock else _conservation(fe, accepted, terminal)
    totals = {k: sum(getattr(st, k) for st in fe.stats.values())
              for k in ("offered", "accepted", "rejected", "completed",
                        "failed", "retried", "degraded",
                        "deadline_hits")}
    injectors = {}
    for cname, eng in fe.engines.items():
        if eng.fault_stats:
            injectors[cname] = eng.fault_stats
    if store_inj is not None:
        injectors["store"] = store_inj.summary()
    out = {
        "name": name,
        "faults": {
            "gold": gold_plan.describe() if gold_plan else [],
            "store": store_plan.describe() if store_plan else [],
            "watchdog_s": watchdog, "retry_failed": retry,
        },
        "wall_s": round(wall_s, 3),
        "deadlock": bool(deadlock),
        "conservation_errors": errs,
        "classes": fe.summary(),
        "totals": totals,
        "goodput_frac": (totals["deadline_hits"]
                         / max(totals["offered"], 1)),
        "breaker": {
            "state": brk.state, "trips": brk.trips,
            "transitions": [[round(t - t0, 3), a, b]
                            for t, a, b in brk.transitions],
        },
        "injectors": injectors,
    }
    fe.close()
    return out


def _scenarios(smoke: bool) -> List[Dict]:
    burst_idx = tuple(range(4, 10))
    return [
        dict(name="clean"),
        # every non-clean schedule carries at least one positional
        # anchor (at=) so the gate "this scenario fired" is guaranteed,
        # not left to a rate draw over a few dozen events
        dict(name="store_io", retry=True,
             store_plan=FaultPlan([
                 FaultSpec("store_read", rate=0.04, at=(3,)),
                 FaultSpec("store_latency", rate=0.1, delay_s=0.002),
             ], seed=11)),
        dict(name="host_crash",
             gold_plan=FaultPlan([
                 FaultSpec("host", rate=0.12, at=(2,)),
                 FaultSpec("slow", rate=0.2, delay_s=0.003),
             ], seed=12)),
        dict(name="device_nan", retry=True,
             gold_plan=FaultPlan([FaultSpec("nan", rate=0.2, at=(1,))],
                                 seed=13)),
        dict(name="hang_watchdog", watchdog=0.25,
             gold_plan=FaultPlan([FaultSpec("hang", at=(2,))], seed=14)),
        dict(name="baseline", recover=True, watchdog=2.0,
             gold_plan=FaultPlan([
                 FaultSpec("device", rate=0.01),
                 FaultSpec("device", at=burst_idx),
             ], seed=15)),
    ]


def _checkpoint_corrupt(smoke: bool) -> Dict:
    """Offline-driver chaos: corrupt a COMMITTED checkpoint of a
    preempted full-graph inference run, and separately crash a
    checkpoint write mid-commit (`ckpt_write` fault stage), then
    resume. Gates: the resume falls back to an earlier verifiable
    superstep, detection is typed (counted in `corrupt_steps` /
    `ckpt_write_failures`), and the final predictions and exit orders
    stay bit-identical to an uninterrupted run. Lives under its own
    payload key — the `scenarios` table is the serving front-end's."""
    import tempfile

    from repro.gnn.models import init_classifiers as _init_cls
    from repro.gnn.store import make_graph
    from repro.launch.full_graph_infer import (
        OfflineConfig, PreemptionSimulated, first_step_distance_quantile,
        run_full_graph_infer)

    t0 = time.time()
    n = 800 if smoke else 2000
    t_max = 3
    store = make_graph(n, avg_deg=6.0, alpha=2.2, seed=7, path=None,
                       feat_dim=24, num_classes=5)
    t_s = first_step_distance_quantile(store, 0.5, 0.5)
    cfg = GNNConfig("sgc", store.feat_dim, store.num_classes, k=t_max,
                    r=0.5, hidden=16, mlp_layers=2)
    params = {"cls": _init_cls(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=t_s, t_min=1, t_max=t_max)

    def _go(ck, **kw):
        plan = kw.pop("fault_plan", None)
        return run_full_graph_infer(store, cfg, params, nai,
                                    OfflineConfig(ckpt_dir=ck, **kw),
                                    fault_plan=plan)

    with tempfile.TemporaryDirectory() as d:
        ref = _go(os.path.join(d, "clean"))

        # 1. byte-flip a committed step payload; resume must fall back
        ck = os.path.join(d, "flip")
        try:
            _go(ck, crash_after=2)
        except PreemptionSimulated:
            pass
        path = os.path.join(ck, "step_00002", "x.npy")
        with open(path, "r+b") as fh:
            fh.seek(os.path.getsize(path) // 2)
            b = fh.read(1)
            fh.seek(-1, 1)
            fh.write(bytes([b[0] ^ 0xFF]))
        flip = _go(ck)
        flip_rec = {
            "resumed_from": flip.stats["resumed_from"],
            "corrupt_steps": flip.stats["corrupt_steps"],
            "parity": bool(
                np.array_equal(flip.predictions, ref.predictions)
                and np.array_equal(flip.exit_orders, ref.exit_orders)),
        }

        # 2. ckpt_write fault (payloads written, manifest not
        #    committed), then preemption: resume from the last step
        #    that DID commit
        ck = os.path.join(d, "wfault")
        plan = FaultPlan([FaultSpec("ckpt_write", at=(2,))], seed=21)
        try:
            _go(ck, crash_after=t_max, fault_plan=plan)
        except PreemptionSimulated:
            pass
        wf = _go(ck)
        write_rec = {
            "resumed_from": wf.stats["resumed_from"],
            "fell_back": wf.stats["resumed_from"] < t_max,
            "parity": bool(
                np.array_equal(wf.predictions, ref.predictions)
                and np.array_equal(wf.exit_orders, ref.exit_orders)),
        }
    return {"n": n, "t_max": t_max, "byte_flip": flip_rec,
            "write_fault": write_rec,
            "wall_s": round(time.time() - t0, 3)}


def collect(smoke: bool = False) -> Dict:
    g, cfg, params, nai = _setup(smoke)
    payload: Dict = {
        "impl": IMPL, "smoke": bool(smoke),
        "batch_size": nai.batch_size,
        "budget_s": BUDGET_S, "min_goodput_ratio": MIN_GOODPUT_RATIO,
        "structural": _parity_fault_free(g, cfg, params, nai, smoke),
        "scenarios": {},
    }
    for sc in _scenarios(smoke):
        kw = dict(sc)
        name = kw.pop("name")
        payload["scenarios"][name] = _run_scenario(
            name, g, cfg, params, nai, smoke, **kw)
        print(f"# scenario {name}: "
              f"goodput={payload['scenarios'][name]['goodput_frac']:.3f} "
              f"failed={payload['scenarios'][name]['totals']['failed']} "
              f"wall={payload['scenarios'][name]['wall_s']}s",
              flush=True)
    payload["checkpoint_corrupt"] = _checkpoint_corrupt(smoke)
    cc = payload["checkpoint_corrupt"]
    print(f"# checkpoint_corrupt: flip_parity="
          f"{cc['byte_flip']['parity']} "
          f"write_parity={cc['write_fault']['parity']} "
          f"wall={cc['wall_s']}s", flush=True)
    clean = payload["scenarios"]["clean"]["goodput_frac"]
    base = payload["scenarios"]["baseline"]["goodput_frac"]
    payload["goodput_gate"] = {
        "clean": clean, "baseline": base,
        "ratio": base / max(clean, 1e-9),
        "min_ratio": MIN_GOODPUT_RATIO,
    }
    return payload


# ------------------------------------------------------------- gating
def check(payload: Dict) -> List[str]:
    errs: List[str] = []
    st = payload["structural"]
    if not st["parity_fault_free"]:
        errs.append("fault-free wired front-end diverged from the plain "
                    "one (predictions/exit orders)")
    if st["wired_failed"] or st["wired_degraded"]:
        errs.append(f"fault-free run recorded failed="
                    f"{st['wired_failed']} degraded="
                    f"{st['wired_degraded']}")
    if st["breaker_transitions"]:
        errs.append(f"fault-free run recorded "
                    f"{st['breaker_transitions']} breaker transitions")
    errs += [f"structural: {e}" for e in st["conservation_errors"]]

    for name, sc in payload["scenarios"].items():
        if sc["deadlock"]:
            errs.append(f"{name}: pipeline deadlocked (requests pending "
                        f"after the bounded drain)")
        errs += [f"{name}: {e}" for e in sc["conservation_errors"]]
        if name != "clean" and not any(
                v.get("fired", 0)
                for inj in sc["injectors"].values()
                for v in inj.values()):
            errs.append(f"{name}: no fault ever fired — the scenario "
                        f"exercised nothing")

    sc = payload["scenarios"]
    if sc["clean"]["totals"]["failed"]:
        errs.append(f"clean: {sc['clean']['totals']['failed']} failed "
                    f"requests without any injected fault")
    if not sc["store_io"]["totals"]["retried"] \
            and not sc["store_io"]["totals"]["failed"]:
        errs.append("store_io: injected read failures neither retried "
                    "nor failed any request")
    if not sc["host_crash"]["totals"]["failed"]:
        errs.append("host_crash: injected host exceptions failed no "
                    "requests")
    nan = sc["device_nan"]
    if not nan["totals"]["retried"] and not nan["totals"]["failed"]:
        errs.append("device_nan: poisoned batches neither retried nor "
                    "failed (NaN reached completed requests?)")
    hang = sc["hang_watchdog"]
    if not hang["totals"]["failed"]:
        errs.append("hang_watchdog: the hung batch was not failed by "
                    "the watchdog")
    if not hang["totals"]["completed"]:
        errs.append("hang_watchdog: nothing completed after the hang — "
                    "the pipeline did not re-arm")

    base = sc["baseline"]
    kinds = [(a, b) for _, a, b in base["breaker"]["transitions"]]
    if base["breaker"]["trips"] < 1 or ("closed", "open") not in kinds:
        errs.append("baseline: the burst window never tripped the "
                    "breaker")
    if base["breaker"]["state"] != "closed" \
            or ("half_open", "closed") not in kinds:
        errs.append(f"baseline: breaker did not recover to closed "
                    f"(state={base['breaker']['state']}, "
                    f"transitions={kinds})")
    gate = payload["goodput_gate"]
    if gate["ratio"] < gate["min_ratio"]:
        errs.append(f"baseline goodput {gate['baseline']:.3f} fell "
                    f"below {gate['min_ratio']} of clean "
                    f"{gate['clean']:.3f}")

    cc = payload.get("checkpoint_corrupt")
    if cc is not None:
        flip, wf = cc["byte_flip"], cc["write_fault"]
        if not flip["parity"]:
            errs.append("checkpoint_corrupt/byte_flip: resumed run "
                        "diverged from the uninterrupted one")
        if flip["corrupt_steps"] < 1:
            errs.append("checkpoint_corrupt/byte_flip: the flipped "
                        "payload was never detected as corrupt")
        if flip["resumed_from"] >= 2:
            errs.append(f"checkpoint_corrupt/byte_flip: resume did not "
                        f"fall back past the corrupt superstep "
                        f"(resumed_from={flip['resumed_from']})")
        if not wf["parity"]:
            errs.append("checkpoint_corrupt/write_fault: resumed run "
                        "diverged from the uninterrupted one")
        if not wf["fell_back"]:
            errs.append("checkpoint_corrupt/write_fault: the crashed "
                        "manifest commit did not force an earlier "
                        "resume point")
    return errs


def _rows(payload: Dict) -> List[str]:
    rows = []
    for name, sc in payload["scenarios"].items():
        t = sc["totals"]
        derived = (f"goodput_frac={sc['goodput_frac']:.4f};"
                   f"offered={t['offered']};completed={t['completed']};"
                   f"failed={t['failed']};rejected={t['rejected']};"
                   f"retried={t['retried']};degraded={t['degraded']};"
                   f"trips={sc['breaker']['trips']};"
                   f"deadlock={sc['deadlock']}")
        rows.append(csv_row(f"chaos/{name}", 1e6 * sc["wall_s"], derived))
    st = payload["structural"]
    rows.append(csv_row(
        "chaos/structural", 0.0,
        f"parity_fault_free={st['parity_fault_free']};"
        f"trace_requests={st['trace_requests']};"
        f"breaker_transitions={st['breaker_transitions']}"))
    cc = payload.get("checkpoint_corrupt")
    if cc is not None:
        rows.append(csv_row(
            "chaos/checkpoint_corrupt", 1e6 * cc["wall_s"],
            f"flip_parity={cc['byte_flip']['parity']};"
            f"flip_resumed_from={cc['byte_flip']['resumed_from']};"
            f"corrupt_steps={cc['byte_flip']['corrupt_steps']};"
            f"write_parity={cc['write_fault']['parity']};"
            f"write_resumed_from={cc['write_fault']['resumed_from']}"))
    return rows


def run() -> list:
    return _rows(collect(smoke=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / short runs (CI smoke job)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on a conservation/parity/goodput/"
                         "breaker gate failure")
    ap.add_argument("--out", default="",
                    help="JSON output path (default: merge under the "
                         "'chaos' key of BENCH_serving.json; with "
                         "--smoke, standalone BENCH_chaos_smoke.json)")
    args = ap.parse_args()
    payload = collect(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in _rows(payload):
        print(r, flush=True)
    if args.out:
        out_path, merge = args.out, args.out == "BENCH_serving.json"
    elif args.smoke:
        out_path, merge = "BENCH_chaos_smoke.json", False
    else:
        out_path, merge = "BENCH_serving.json", True
    write_bench_json(out_path, payload,
                     section="chaos" if merge else None)
    if args.check:
        errs = check(payload)
        for e in errs:
            print(f"CHECK FAIL: {e}", file=sys.stderr)
        if errs:
            sys.exit(1)
        print("# all chaos gates passed")


if __name__ == "__main__":
    main()
