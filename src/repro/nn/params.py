"""Parameter definition trees.

A model declares its parameters ONCE as a pytree of `ParamDef`s (shape +
logical axes + initializer). Everything else is derived from that single
source of truth:

  * `init_tree(key, defs, dtype)`      -> pytree of initialized jnp arrays
  * `spec_tree(defs)`                  -> matching pytree of PartitionSpec
  * `abstract_tree(defs, dtype)`       -> pytree of ShapeDtypeStruct (dry-run)

This is the pure-JAX replacement for a module system: params stay ordinary
pytrees, `apply` functions stay ordinary functions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import spec as logical_spec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "fan_in"          # fan_in | normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Optional[str] = None   # override model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(key: jax.Array, d: ParamDef, default_dtype) -> jax.Array:
    dtype = jnp.dtype(d.dtype or default_dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32)
                * (d.scale / math.sqrt(d.shape[-1]))).astype(dtype)
    if d.init == "small":
        return (jax.random.normal(key, d.shape, jnp.float32) * 0.02 * d.scale
                ).astype(dtype)
    if d.init == "fan_in":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def init_tree(key: jax.Array, defs, dtype="float32"):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = [_init_one(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def spec_tree(defs):
    return jax.tree.map(lambda d: logical_spec(*d.logical), defs, is_leaf=_is_def)


def abstract_tree(defs, dtype="float32"):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or dtype)),
        defs, is_leaf=_is_def)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_def)
    total = 0
    for leaf in leaves:
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        total += int(np.prod(shape)) if shape else 1
    return total


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
