"""msgpack-based checkpointing (orbax is not available offline).

Pytrees of jax/numpy arrays are flattened to path-keyed buffers; dtypes and
shapes round-trip exactly. Sharded arrays are gathered to host before save
(adequate at the scales this container runs; a per-shard layout is a noted
production follow-up in DESIGN.md).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        flat[_key_str(kp)] = {
            "dtype": arr.dtype.name,   # name survives ml_dtypes (bfloat16)
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    payload = {"step": step, "arrays": flat}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, step)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = payload["arrays"]
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for kp, leaf in leaves_with_path:
        k = _key_str(kp)
        if k not in arrays:
            raise KeyError(f"checkpoint missing {k}")
        rec = arrays[k]
        arr = np.frombuffer(rec["data"], dtype=jnp.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), payload["step"]
