from repro.models import decoder_lm

__all__ = ["decoder_lm"]
