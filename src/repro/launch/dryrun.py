import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and record roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS assignment above MUST precede every other import — jax locks
the device count at first initialization.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.common import INPUT_SHAPES, TPU_V5E, TrainConfig
from repro.configs import ARCHS, get_config, input_shardings, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (active_params, model_flops,
                                   roofline_terms)
from repro.models import decoder_lm as M
from repro.nn.params import count_params
from repro.optim import adamw_update, make_schedule
from repro.sharding import named
from repro.sharding import spec as logical_spec


def _train_cfg(cfg) -> TrainConfig:
    n = count_params(M.model_defs(cfg))
    # >60B params: bf16 Adam moments, else f32 (recorded in EXPERIMENTS.md)
    mdt = "bfloat16" if n > 60e9 else "float32"
    return TrainConfig(moment_dtype=mdt)


def build_train_step(cfg):
    tc = _train_cfg(cfg)
    sched = make_schedule(tc)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, argnums=1, has_aux=True)(cfg, params, batch)
        lr = sched(opt_state["count"])
        params, opt_state, om = adamw_update(grads, opt_state, params, tc, lr)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step, tc


def abstract_opt_state(cfg, tc):
    ab = M.abstract_params(cfg)
    mdt = jnp.dtype(tc.moment_dtype)
    mom = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, mdt), ab)
    return {"mu": mom, "nu": mom,
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_specs(cfg):
    ps = M.param_specs(cfg)
    return {"mu": ps, "nu": ps, "count": logical_spec()}


def _named_tree(mesh, spec_tree_, abstract_tree_):
    """PartitionSpec tree + matching abstract tree -> NamedSharding tree,
    fitting every spec to its leaf's shape (divisibility fallback)."""
    is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
    specs, treedef = jax.tree.flatten(spec_tree_, is_leaf=is_spec)
    abs_ = treedef.flatten_up_to(abstract_tree_)
    return treedef.unflatten(
        [named(mesh, s, a.shape) for s, a in zip(specs, abs_)])


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                compile_: bool = True, verbose: bool = True):
    """Returns a result record dict (or raises)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    in_specs = input_specs(cfg, shape)
    in_sh = input_shardings(cfg, shape)
    batch_sh = {k: named(mesh, v, in_specs[k].shape)
                for k, v in in_sh.items()}
    ab_params = M.abstract_params(cfg)
    pspecs = _named_tree(mesh, M.param_specs(cfg), ab_params)

    with mesh:
        if shape.mode == "train":
            step, tc = build_train_step(cfg)
            ab_opt = abstract_opt_state(cfg, tc)
            ospecs = _named_tree(mesh, opt_state_specs(cfg), ab_opt)
            fn = jax.jit(step,
                         in_shardings=(pspecs, ospecs, batch_sh),
                         out_shardings=(pspecs, ospecs, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(ab_params, ab_opt, in_specs)
        elif shape.mode == "prefill":
            def prefill(params, batch):
                return M.prefill_step(cfg, params, batch["tokens"],
                                      frontend=batch.get("frontend"))
            ab_c = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
            csp = _named_tree(mesh, M.cache_specs(cfg, shape.global_batch,
                                                  shape.seq_len), ab_c)
            out_sh = (named(mesh, logical_spec("batch", "vocab"),
                            (shape.global_batch, cfg.vocab_size)), csp)
            fn = jax.jit(prefill, in_shardings=(pspecs, batch_sh),
                         out_shardings=out_sh)
            lowered = fn.lower(ab_params, in_specs)
        else:  # decode
            L = M._decode_len(cfg, shape.seq_len)
            ab_cache = M.abstract_cache(cfg, shape.global_batch, L)
            csp = _named_tree(mesh, M.cache_specs(cfg, shape.global_batch, L),
                              ab_cache)

            def serve_step(params, cache, batch, pos):
                return M.decode_step(cfg, params, cache, batch["tokens"], pos)
            out_sh = (named(mesh, logical_spec("batch", None, "vocab"),
                            (shape.global_batch, 1, cfg.vocab_size)), csp)
            fn = jax.jit(serve_step,
                         in_shardings=(pspecs, csp, batch_sh,
                                       named(mesh, logical_spec())),
                         out_shardings=out_sh,
                         donate_argnums=(1,))
            lowered = fn.lower(ab_params, ab_cache, in_specs,
                               jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.time() - t0
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": chips, "mode": shape.mode,
            "lower_s": round(t_lower, 1),
        }
        if not compile_:
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()

    n_params = count_params(M.model_defs(cfg))
    n_active = active_params(cfg, n_params)
    terms = roofline_terms(cost, hlo, chips=chips)
    mf = model_flops(cfg, shape, n_params, n_active)
    terms["model_flops"] = mf
    terms["useful_ratio"] = mf / terms["hlo_flops"] if terms["hlo_flops"] else 0.0

    def _mem_attr(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    rec.update({
        "params": n_params,
        "active_params": n_active,
        "memory": {
            "argument_bytes": _mem_attr("argument_size_in_bytes"),
            "output_bytes": _mem_attr("output_size_in_bytes"),
            "temp_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_bytes": _mem_attr("generated_code_size_in_bytes"),
            "alias_bytes": _mem_attr("alias_size_in_bytes"),
        },
        "roofline": terms,
    })
    # per-chip residency: arguments are sharded; temp is per-program
    arg_b = rec["memory"]["argument_bytes"] or 0
    tmp_b = rec["memory"]["temp_bytes"] or 0
    rec["memory"]["per_chip_gb"] = round((arg_b + tmp_b) / chips / 1e9, 3)
    rec["fits_hbm"] = rec["memory"]["per_chip_gb"] <= TPU_V5E.hbm_bytes / 1e9

    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}] "
              f"lower={rec['lower_s']}s compile={rec.get('compile_s')}s")
        print("  memory_analysis:", rec["memory"])
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (terms["hlo_flops"], terms["hlo_bytes_per_chip"]))
        print("  roofline: compute=%.3fms memory=%.3fms collective=%.3fms"
              " dominant=%s useful=%.2f" %
              (1e3 * terms["t_compute_s"], 1e3 * terms["t_memory_s"],
               1e3 * terms["t_collective_s"], terms["dominant"],
               terms["useful_ratio"]))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2pod' if mp else '1pod'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                try:
                    rec = lower_combo(arch, shape, multi_pod=mp,
                                      compile_=not args.no_compile)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
    if failures:
        print("FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()
