"""End-to-end engine parity: `NAIServingEngine(mode="compiled")`
(vectorized sample -> block-ELL pack -> Pallas SpMM masked NAI ->
per-order classification, one jitted function) must reproduce the host
path's predictions and exit orders, and repeat batches of the same bucket
must not recompile."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.serving import NAIServingEngine
from repro.gnn.store import as_store


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("pubmed-like", scale=0.02, seed=4)
    # one FB feature block keeps interpret-mode Pallas test-sized
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
    return g, cfg, params, nai


def _serve(engine, nodes):
    engine.submit(nodes)
    done = []
    while engine.queue:
        done += engine.step()
    assert [r.node_id for r in done] == list(map(int, nodes))
    return (np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))


def test_compiled_matches_host(setup):
    g, cfg, params, nai = setup
    host = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0)
    comp = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled")
    rng = np.random.default_rng(0)
    for trial in range(2):
        nodes = rng.choice(g.test_idx, size=32, replace=False)
        ph, oh = _serve(host, nodes)
        pc, oc = _serve(comp, nodes)
        np.testing.assert_array_equal(pc, ph)
        np.testing.assert_array_equal(oc, oh)
        assert (pc >= 0).all() and set(oc) <= {1, 2}
        # guard: exact order equality is only a fair ask while every exit
        # distance sits far from T_s — the compiled path evaluates d in
        # float32 vs the host's float64 (see support_stationary_state).
        # If a config tweak shrinks this margin, fix the config, not the
        # engines.
        from repro.gnn import sample_support
        from repro.gnn.nai import _subgraph_spmm, support_stationary_state
        sup = sample_support(as_store(g), nodes, nai.t_max, cfg.r)
        x0 = g.features[sup.nodes].astype(np.float32)
        x_inf = support_stationary_state(g, sup, x0, cfg.r)
        x1, _ = _subgraph_spmm(sup, x0, np.ones(len(sup), bool))
        d = np.linalg.norm(x1[:len(nodes)] - x_inf, axis=1)
        assert np.abs(d - nai.t_s).min() > 1e-3


def test_fused_impl_matches_host_and_block_ell(setup):
    """spmm_impl='fused' (one Pallas kernel per NAP step) must reproduce
    the host path AND be bit-identical to block_ell on exit orders (both
    compiled impls share the f32 stationary-state arithmetic)."""
    g, cfg, params, nai = setup
    host = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0)
    bell = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled", spmm_impl="block_ell")
    fused = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                             mode="compiled", spmm_impl="fused")
    rng = np.random.default_rng(2)
    for trial in range(2):
        nodes = rng.choice(g.test_idx, size=32, replace=False)
        ph, oh = _serve(host, nodes)
        pb, ob = _serve(bell, nodes)
        pf, of = _serve(fused, nodes)
        np.testing.assert_array_equal(pf, ph)
        np.testing.assert_array_equal(of, oh)
        np.testing.assert_array_equal(pf, pb)
        np.testing.assert_array_equal(of, ob)
    # repeat batches hit the jit cache exactly like the other impls
    assert fused.jit_stats["compiles"] >= 1


def test_same_bucket_batch_hits_jit_cache(setup):
    g, cfg, params, nai = setup
    comp = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled")
    nodes = np.asarray(g.test_idx[:32])
    p1, _ = _serve(comp, nodes)
    assert comp.jit_stats == {"compiles": 1, "hits": 0}
    assert comp.jit_cache_size() == 1
    # identical batch -> identical buckets -> no recompile
    p2, _ = _serve(comp, nodes)
    assert comp.jit_stats == {"compiles": 1, "hits": 1}
    assert comp.jit_cache_size() == 1
    np.testing.assert_array_equal(p1, p2)


def test_high_water_mark_reuses_shape_for_smaller_support(setup):
    """A later batch whose support fits inside the high-water-mark buckets
    reuses the compiled shape even though its raw sizes differ."""
    g, cfg, params, nai = setup
    comp = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled", spmm_impl="segment")
    rng = np.random.default_rng(1)
    sizes = [32, 32, 32]
    for i, s in enumerate(sizes):
        _serve(comp, rng.choice(g.test_idx, size=s, replace=False))
    # supports differ per batch but land in few buckets; every batch past
    # the high-water mark is a cache hit
    assert comp.jit_stats["compiles"] + comp.jit_stats["hits"] == len(sizes)
    assert comp.jit_cache_size() == comp.jit_stats["compiles"]
    assert comp.jit_stats["hits"] >= 1


def test_engine_dedupes_batch_in_both_modes(setup):
    """Duplicate node ids within one batch (client retries) must get
    consistent results, and the two modes must agree — duplicated rows
    would double-count in the stationary state and skew exit distances."""
    g, cfg, params, nai = setup
    base = np.asarray(g.test_idx[:8])
    nodes = np.concatenate([base, base[:4]])
    out = {}
    for mode in ("host", "compiled"):
        eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                               mode=mode)
        preds, orders = _serve(eng, nodes)
        np.testing.assert_array_equal(preds[:4], preds[8:])
        np.testing.assert_array_equal(orders[:4], orders[8:])
        out[mode] = (preds, orders)
    np.testing.assert_array_equal(out["host"][0], out["compiled"][0])
    np.testing.assert_array_equal(out["host"][1], out["compiled"][1])
