"""Supporting-node sampling for inductive batches (Algorithm 1 line 3).

BFS from the batch nodes over the in-neighbor CSR up to `hops`, returning
the supporting set partitioned into hop layers plus the induced subgraph
(local ids, per-edge coefficients using GLOBAL degrees, per the paper)."""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.gnn.graph import Graph


@dataclasses.dataclass
class Support:
    nodes: np.ndarray          # (S,) global ids; nodes[:n_batch] == batch
    hop: np.ndarray            # (S,) BFS layer of each supporting node
    n_batch: int
    src: np.ndarray            # (Es,) LOCAL ids
    dst: np.ndarray            # (Es,) LOCAL ids
    coef: np.ndarray           # (Es,) propagation coefficients
    sub_edges: int             # undirected edge count of the subgraph
    def __len__(self):
        return len(self.nodes)


def sample_support(g: Graph, batch: np.ndarray, hops: int, r: float) -> Support:
    indptr, nbr = g.csr()
    seen = {}
    order: List[int] = []
    hop_of: List[int] = []
    for b in batch:
        seen[int(b)] = 0
        order.append(int(b))
        hop_of.append(0)
    frontier = list(batch)
    for h in range(1, hops + 1):
        nxt = []
        for u in frontier:
            for v in nbr[indptr[u]:indptr[u + 1]]:
                v = int(v)
                if v not in seen:
                    seen[v] = h
                    order.append(v)
                    hop_of.append(h)
                    nxt.append(v)
        frontier = nxt
    nodes = np.asarray(order, np.int64)
    local = {u: i for i, u in enumerate(order)}

    # induced edges (j -> i) for i in support whose source j is in support
    srcs, dsts = [], []
    for u in order:
        for v in nbr[indptr[u]:indptr[u + 1]]:
            v = int(v)
            if v in local:
                dsts.append(local[u])
                srcs.append(local[v])
    src = np.asarray(srcs, np.int32)
    dst = np.asarray(dsts, np.int32)

    dt = (g.degrees + 1).astype(np.float64)    # GLOBAL degrees (known)
    gsrc = nodes[src]
    gdst = nodes[dst]
    coef = (dt[gdst] ** (r - 1.0) * dt[gsrc] ** (-r)).astype(np.float32)
    sub_edges = (len(src) - len(nodes)) // 2   # self loops included once
    return Support(nodes=nodes, hop=np.asarray(hop_of, np.int32),
                   n_batch=len(batch), src=src, dst=dst, coef=coef,
                   sub_edges=max(sub_edges, 0))
