"""Serving throughput benchmark: requests/sec and latency percentiles for
the NAI serving engine across every serving configuration — host vs
compiled × {segment, block_ell, fused} × {serial, pipelined} — plus the
per-batch host-stage vs device-stage time breakdown and the structural
counters the pipelined refactor is accountable for.

Interpret-mode Pallas timings on CPU are emulation, not TPU performance;
the structural columns carry the backend-independent signal:

* ``series_rows`` — rows written to the per-step NAP series carry. The
  batch-row carry (PR 3) stores ``nb_pad`` rows instead of the full
  padded support (``support_rows``); with T_max-hop supports that is the
  difference between S·f and nb·f of HBM series traffic per step.
* ``steady_compiles`` — jit compiles observed during the timed pass
  (must be 0: bucketed repeat batches hit the compile cache; the
  batch-row carry must not add a shape axis that defeats bucketing).
* ``steady_pack_allocs`` — bucket-sized numpy allocations during the
  timed pass (must be 0: the engine packs into a rotating pool of
  preallocated buffer sets).

``--sharded`` adds mesh-sharded serving rows (req/s and p50/95/99 vs
device count for row-sharded engines built on `make_serving_mesh`; see
README "Sharded serving"): the n_shards / steady-compile / pack-alloc
columns are the structural guarantee — a sharded engine must report one
shard per device and keep the zero-steady-state invariants — while
host-platform device timings share physical cores and are trend-only.
Sharded rows also carry the halo-exchange structural columns:

* ``gather_rows_per_step`` — frontier rows each shard materializes per
  NAP step (the bucket-padded halo frame H_pad·CB under
  ``gather_mode="halo"``/``"alltoall"``; the full S_pad under the dense
  reference row);
* ``halo_rows`` / ``halo_frac`` — the true boundary (widest shard's
  real halo entries · CB) and its fraction of S_pad. ``--check`` fails
  when a halo-mode row at D >= 2 reports ``halo_frac == 1.0`` (the halo
  path silently degenerated to the dense exchange) or a frame larger
  than the dense frontier.

``--cache`` adds the propagated-feature-cache section (engine
``cache_nodes=``; see README "Propagated-feature cache"): a seeded
Zipf(1.0) request stream — hub nodes land in nearly every request
window — served through cache-on vs cache-off engines. Cached serving
must be BIT-IDENTICAL to cold (predictions and exit orders, the same
gate the mutation and sharded rounds re-check after ``add_edges`` /
``add_nodes`` and at D=2), while the row accounting shows the win:
``rows_packed`` < ``rows_support`` (frontier rows served from cache are
dropped from the packed SpMM). The 0%-hit control serves the same
stream with ``cache_fill=False`` — every probe misses by construction,
so the cache-on/cache-off req/s ratio bounds the probe+seed overhead
deterministically (timing itself stays advisory, as everywhere else in
this bench; the structural ``--check`` gates are hit_rate > 0, parity,
and the zero-steady-state counters with the cache enabled).

``--graph-scale`` adds the store-scale sweep: synthetic power-law graphs
(1e5 → 1e7 nodes full-size, one small size under ``--smoke``) are
generated ON DISK in a subprocess (``python -m repro.gnn.store``) and
served through a memory-mapped `MmapStore` — the features are never
copied into RAM, only the pages each batch's support gathers touch. Each
scale row records req/s, p50/95/99, the halo fraction (sharded rows),
the host-stage share of batch time, the zero-steady-state counters, and
the serving process's peak RSS next to the full feature-matrix bytes:
``peak_rss_bytes < feature_bytes`` at the large sizes is the evidence
the host stage's working set tracks the support, not the graph
(``--check`` enforces it where the feature matrix is big enough to make
the comparison meaningful, plus an MmapStore-vs-in-RAM bit-parity flag
at the smallest size).

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--check]
                                                      [--sharded] [--cache]
                                                      [--graph-scale]
                                                      [--out F]

writes ``BENCH_serving.json`` (``BENCH_serving_smoke.json`` with
``--smoke``) so the serving trajectory accumulates across commits.
``--check`` exits nonzero when a structural counter regresses — the CI
guard.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import Dict, List

if __package__ in (None, ""):      # `python benchmarks/serving_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, write_bench_json
from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import (NAIConfig, infer_batch_masked,
                           support_stationary_factors)
from repro.gnn.packing import next_bucket, pack_support, step_active_blocks
from repro.gnn.sampler import sample_support
from repro.gnn.store import MmapStore, as_store
from repro.kernels.spmm.kernel import RB
from repro.serving import NAIServingEngine


def _setup(smoke: bool):
    """The default serving shape: pubmed-like graph, one FB feature
    block (keeps interpret-mode Pallas a benchmark, not a soak), random
    classifier weights (throughput does not depend on trained values)."""
    g = load_dataset("pubmed-like", scale=0.02 if smoke else 0.05, seed=0)
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2,
                    batch_size=32 if smoke else 64)
    return g, cfg, params, nai


def _request_stream(g, nai, n_batches: int, seed: int = 0):
    """Ragged batch sizes inside one bucket — the steady-state pattern a
    deployment sees (full batches with occasional stragglers)."""
    rng = np.random.default_rng(seed)
    bs = nai.batch_size
    sizes = [bs if i % 3 else max(bs - rng.integers(0, bs // 8), 1)
             for i in range(n_batches)]
    return [rng.choice(g.test_idx, size=s, replace=False) for s in sizes]


def _drain(engine, stream) -> float:
    """Submit+serve the stream, return wall seconds for the whole drain
    (including the pipeline flush)."""
    t0 = time.perf_counter()
    for nodes in stream:
        engine.submit(nodes)
        engine.step()
    engine.flush()
    return time.perf_counter() - t0


def _bench_configs(g, cfg, params, nai, specs, stream,
                   rounds: int) -> List[Dict]:
    """Warm every engine, then INTERLEAVE the timed rounds (all configs
    per round, best round per config) so machine drift during the run
    hits every configuration equally instead of whichever happened to be
    measured in a contended window. Each spec is a dict with keys
    ``mode``/``impl``/``depth`` and optionally ``devices`` (> 1 serves
    through a ``make_serving_mesh`` row-sharded engine) and ``gather``
    (the sharded frontier exchange; engine default "halo")."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.engine import EngineStats, LatencyRing
    engines, baselines = [], []
    for sp in specs:
        kw = dict(max_wait_s=10.0, mode=sp["mode"])
        if sp["mode"] == "compiled":
            kw.update(spmm_impl=sp["impl"], pipeline_depth=sp["depth"])
        if sp.get("devices", 1) > 1:
            kw["mesh"] = make_serving_mesh(sp["devices"])
            if "gather" in sp:
                kw["gather_mode"] = sp["gather"]
        eng = NAIServingEngine(cfg, nai, params, g, **kw)
        _drain(eng, stream)               # warm 1: compiles, HWM growth
        _drain(eng, stream)               # warm 2: pack pool converges
        engines.append(eng)
        baselines.append((eng.jit_stats["compiles"],
                          eng.pack_stats["allocs"]))
    best = [dict(wall=float("inf")) for _ in specs]
    for _ in range(rounds):
        for i, eng in enumerate(engines):
            eng.stats = EngineStats(latencies=LatencyRing(16384))
            eng.batch_timings.clear()
            wall = _drain(eng, stream)
            if wall < best[i]["wall"]:
                best[i] = dict(wall=wall, served=eng.stats.served,
                               summary=eng.stats.summary(),
                               timings=list(eng.batch_timings))
    rows = []
    for sp, eng, (c0, a0), b in zip(specs, engines, baselines, best):
        mode = sp["mode"]
        row = {
            "mode": mode,
            "impl": sp["impl"] if mode == "compiled" else "-",
            "pipeline_depth": sp["depth"],
            "devices": sp.get("devices", 1),
            "n_shards": eng.n_shards,
            "req_per_s": round(b["served"] / b["wall"], 1),
            "p50_ms": round(b["summary"]["p50_ms"], 3),
            "p95_ms": round(b["summary"]["p95_ms"], 3),
            "p99_ms": round(b["summary"]["p99_ms"], 3),
            "steady_compiles": eng.jit_stats["compiles"] - c0,
            "steady_pack_allocs": eng.pack_stats["allocs"] - a0,
        }
        if eng.n_shards > 1:
            row["gather_mode"] = eng.gather_mode
            row.update({k: (round(v, 3) if isinstance(v, float) else v)
                        for k, v in eng.halo_stats.items()})
        if mode == "compiled" and b["timings"]:
            for k, label in (("host_s", "host_stage_ms"),
                             ("dispatch_s", "dispatch_ms"),
                             ("sync_s", "device_sync_ms")):
                row[label] = round(
                    1e3 * float(np.mean([t[k] for t in b["timings"]])), 3)
        rows.append(row)
    return rows


def _sharded_specs(smoke: bool) -> List[Dict]:
    """Sharded serving sweep: req/s vs device count for the CPU-real
    segment impl (1/2/4/8 — the 1-device row is the unsharded
    reference), plus the Pallas impls at the middle counts for kernel-
    path structural coverage (interpret-mode timings are emulation; the
    structural counters are the signal). Sharded engines run the default
    halo exchange; one dense-gather segment row rides along as the
    communication-volume reference (same shapes, full-frontier
    all_gather). Counts are clipped to the available devices — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the full
    sweep."""
    avail = len(jax.devices())
    seg = [d for d in ((1, 2) if smoke else (1, 2, 4, 8)) if d <= avail]
    krn = [d for d in ((2,) if smoke else (2, 4)) if d <= avail]
    specs = [dict(mode="compiled", impl="segment", depth=2, devices=d,
                  gather="halo")
             for d in seg]
    for impl in ("block_ell", "fused"):
        specs += [dict(mode="compiled", impl=impl, depth=2, devices=d,
                       gather="halo")
                  for d in krn]
    if 2 <= avail:
        specs.append(dict(mode="compiled", impl="segment", depth=2,
                          devices=2, gather="dense"))
    return specs


def _graph_scale_specs(smoke: bool) -> List[Dict]:
    """The store-scale sweep. Full-size features are 256-wide so the
    feature matrix (n·f·4 bytes: 102 MB / 1.02 GB / 10.2 GB) dwarfs any
    plausible process RSS at the two large sizes — that gap is what the
    RSS gate measures. Smoke keeps one small cheap size (structure only;
    a 25 MB feature matrix can't beat a jax-loaded process's baseline
    RSS, so the gate doesn't apply there)."""
    if smoke:
        return [dict(n=100_000, feat_dim=64, avg_deg=8.0, n_batches=4)]
    return [dict(n=100_000, feat_dim=256, avg_deg=16.0, n_batches=8),
            dict(n=1_000_000, feat_dim=256, avg_deg=16.0, n_batches=8),
            dict(n=10_000_000, feat_dim=256, avg_deg=16.0, n_batches=8)]


def _reset_peak_rss() -> bool:
    """Reset the kernel's VmHWM high-water mark to the current RSS (so
    the per-row peak measures this row's serving, not process history).
    Returns False where /proc/self/clear_refs is unwritable — the row
    then reports the lifetime peak, still valid for the < feature_bytes
    gate because the graph-scale section runs before everything else."""
    try:
        with open("/proc/self/clear_refs", "w") as fh:
            fh.write("5")
        return True
    except OSError:
        return False


def _peak_rss_bytes() -> int:
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return -1


def _serve_collect(engine, stream):
    """Drain the stream, returning (predictions, exit orders) in
    completion order — FIFO and deterministic, so two engines serving
    the same stream are comparable element-wise."""
    done = []
    for nodes in stream:
        engine.submit(nodes)
        done += engine.step()
    done += engine.flush()
    return ([r.prediction for r in done], [r.exit_order for r in done])


def _graph_scale(smoke: bool, store_dir: str = "") -> Dict:
    """Generate power-law `MmapStore` graphs on disk (in a subprocess,
    so generation never inflates the serving process's RSS) and serve
    batches from each through the compiled engine. Runs FIRST in
    `collect` — before any other section allocates — so even without a
    VmHWM reset the recorded peak belongs to store-backed serving."""
    import subprocess
    import tempfile

    from repro.launch.mesh import make_serving_mesh
    from repro.serving.engine import EngineStats, LatencyRing

    devices = min(2, len(jax.devices()))
    rounds = 2
    seed = 7
    specs = _graph_scale_specs(smoke)
    section: Dict = {
        "impl": "segment", "pipeline_depth": 2, "devices": devices,
        "seed": seed, "expected_sizes": [sp["n"] for sp in specs],
        "store_parity": None, "rows": []}
    tmp = None
    if not store_dir:
        tmp = tempfile.TemporaryDirectory(prefix="graphstore-")
        store_dir = tmp.name
    try:
        for si, sp in enumerate(specs):
            path = os.path.join(store_dir, f"n{sp['n']}")
            env = dict(os.environ)
            src = os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src")
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
            t0 = time.perf_counter()
            if not os.path.exists(os.path.join(path, "meta.json")):
                subprocess.run(
                    [sys.executable, "-c",
                     "from repro.gnn.store import _main; _main()",
                     "--n", str(sp["n"]), "--avg-deg", str(sp["avg_deg"]),
                     "--seed", str(seed),
                     "--feat-dim", str(sp["feat_dim"]), "--out", path],
                    check=True, env=env)
            gen_s = time.perf_counter() - t0
            store = MmapStore(path)
            cfg = GNNConfig("sgc", sp["feat_dim"], store.num_classes,
                            k=2, hidden=32, mlp_layers=2)
            params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
            nai = NAIConfig(t_s=6.0, t_min=1, t_max=2,
                            batch_size=32 if smoke else 64)
            rng = np.random.default_rng(seed)
            # uniform ids WITHOUT Generator.choice(replace=False): that
            # permutes the whole population (an O(n) allocation at 1e7
            # nodes). Collision odds at 64-of-1e7 are negligible and the
            # engine dedupes per batch anyway.
            stream = [np.unique(rng.integers(0, sp["n"],
                                             size=nai.batch_size))
                      for _ in range(sp["n_batches"])]
            kw = dict(max_wait_s=10.0, mode="compiled",
                      spmm_impl="segment", pipeline_depth=2)
            if devices > 1:
                kw.update(mesh=make_serving_mesh(devices),
                          gather_mode="halo")
            eng = NAIServingEngine(cfg, nai, params, store, **kw)
            _drain(eng, stream)           # warm 1: compiles, HWM growth
            _drain(eng, stream)           # warm 2: pack pool converges
            c0, a0 = eng.jit_stats["compiles"], eng.pack_stats["allocs"]
            # release warmup's resident feature pages so the post-reset
            # high-water mark measures the TIMED rounds' working set
            store.drop_resident()
            rss_reset = _reset_peak_rss()
            best = dict(wall=float("inf"))
            for _ in range(rounds):
                eng.stats = EngineStats(latencies=LatencyRing(16384))
                eng.batch_timings.clear()
                wall = _drain(eng, stream)
                if wall < best["wall"]:
                    best = dict(wall=wall, served=eng.stats.served,
                                summary=eng.stats.summary(),
                                timings=list(eng.batch_timings))
            tm = best["timings"]
            host = float(np.mean([t["host_s"] for t in tm]))
            disp = float(np.mean([t["dispatch_s"] for t in tm]))
            sync = float(np.mean([t["sync_s"] for t in tm]))
            row = {
                "n": sp["n"], "feat_dim": sp["feat_dim"],
                "avg_deg": sp["avg_deg"],
                "num_edges": store.num_edges,
                "gen_s": round(gen_s, 2),
                "feature_bytes": int(sp["n"]) * sp["feat_dim"] * 4,
                "peak_rss_bytes": _peak_rss_bytes(),
                "rss_reset": rss_reset,
                "req_per_s": round(best["served"] / best["wall"], 1),
                "p50_ms": round(best["summary"]["p50_ms"], 3),
                "p95_ms": round(best["summary"]["p95_ms"], 3),
                "p99_ms": round(best["summary"]["p99_ms"], 3),
                "host_stage_ms": round(1e3 * host, 3),
                "dispatch_ms": round(1e3 * disp, 3),
                "device_sync_ms": round(1e3 * sync, 3),
                "host_share": round(host / max(host + disp + sync, 1e-12),
                                    3),
                "steady_compiles": eng.jit_stats["compiles"] - c0,
                "steady_pack_allocs": eng.pack_stats["allocs"] - a0,
            }
            if devices > 1:
                row["gather_mode"] = eng.gather_mode
                row["halo_frac"] = round(eng.halo_stats["halo_frac"], 3)
            section["rows"].append(row)
            if si == 0:
                # bit-parity gate at the cheapest size: the mmap-backed
                # engine vs one serving the SAME files eagerly loaded
                # into RAM — predictions AND exit orders must match
                ram = NAIServingEngine(
                    cfg, nai, params, MmapStore(path, mmap=False), **kw)
                p_m, o_m = _serve_collect(eng, stream)
                p_r, o_r = _serve_collect(ram, stream)
                section["store_parity"] = bool(p_m == p_r and o_m == o_r)
                ram.close()
            eng.close()           # releases the store's fd/maps too
    finally:
        if tmp is not None:
            tmp.cleanup()
    return section


def _cache_stream(ids, bs: int, n_batches: int, exponent: float,
                  seed: int) -> List[np.ndarray]:
    """Zipf(`exponent`) request batches over `ids` (exponent=0 =
    uniform). Batches may repeat nodes within and across batches — the
    engine dedupes per batch; cross-batch repetition is what the cache
    serves."""
    from benchmarks.common import zipf_requests
    flat = zipf_requests(ids, bs * n_batches, exponent=exponent,
                         seed=seed)
    return [flat[i * bs:(i + 1) * bs] for i in range(n_batches)]


def _timed_req_per_s(engine, stream, rounds: int) -> float:
    """Best-of-`rounds` drain throughput on an already-warm engine.
    `reset_stats()` zeroes the request/row counters but keeps cache
    CONTENTS, pack pools, and shape high-water marks — the steady state
    the timing should measure."""
    best = float("inf")
    served = 0
    for _ in range(rounds):
        engine.reset_stats()
        wall = _drain(engine, stream)
        served = engine.stats.served
        best = min(best, wall)
    return round(served / best, 1)


def _cache_section(smoke: bool) -> Dict:
    """Propagated-feature cache rounds (see the module docstring):

    * ``zipf`` — fresh cache-on vs cache-off engines over the same
      Zipf(1.0) stream: bit-parity, hit/row accounting, then warm
      best-of-rounds req/s and the zero-steady-state counters with the
      cache enabled (seed shapes must bucket like everything else).
    * ``no_hit_control`` — same stream, ``cache_fill=False``: the cache
      machinery runs (probe per hop, seed operands threaded) but every
      probe misses by construction, so hit_rate is exactly 0 and the
      req/s ratio vs cache-off is a deterministic overhead bound.
    * ``mutation`` — two engines over two lockstep `InMemoryStore`s;
      after half the stream both stores get the same ``add_edges`` (the
      endpoints drawn from already-cached nodes, so invalidation lands
      on live entries) and ``add_nodes``; parity must survive, and the
      cached engine must report stale invalidations.
    * ``sharded`` — the same parity gate at D=2 with shard-local caches
      (None when the backend exposes fewer than 2 devices).
    """
    from repro.gnn.store import InMemoryStore

    g, cfg, params, nai = _setup(smoke)
    bs = nai.batch_size
    n_batches = 6 if smoke else 16
    rounds = 2 if smoke else 3
    capacity = 4096
    kw = dict(max_wait_s=10.0, mode="compiled", spmm_impl="segment",
              pipeline_depth=2)
    stream = _cache_stream(g.test_idx, bs, n_batches, 1.0, seed=11)
    section: Dict = {
        "impl": "segment", "pipeline_depth": 2, "capacity": capacity,
        "zipf_exponent": 1.0, "n_requests": bs * n_batches,
        "batch_size": bs,
    }

    # --- Zipf round: parity + hit accounting on FRESH engines ---------
    eng_on = NAIServingEngine(cfg, nai, params, g,
                              cache_nodes=capacity, **kw)
    eng_off = NAIServingEngine(cfg, nai, params, g, **kw)
    p_on, o_on = _serve_collect(eng_on, stream)
    p_off, o_off = _serve_collect(eng_off, stream)
    cs = eng_on.cache_stats
    zipf = {
        "parity": bool(p_on == p_off and o_on == o_off),
        "hit_rate": round(cs["hit_rate"], 4),
        "hits": int(cs["hits"]), "stale": int(cs["stale"]),
        "fills": int(cs["fills"]),
        "rows_support": int(cs["rows_support"]),
        "rows_packed": int(cs["rows_packed"]),
        "rows_saved_frac": round(
            1.0 - cs["rows_packed"] / max(cs["rows_support"], 1), 4),
        "rows_packed_per_req": round(
            cs["rows_packed"] / (bs * n_batches), 2),
    }
    # warm drains: the hit pattern saturates at drain 2, once every
    # requested node is cached (same stream -> same hits thereafter),
    # so the pack pool needs drain 3 to converge on the saturated
    # shapes — one more warm pass than the cold engine's two
    _drain(eng_on, stream)
    _drain(eng_on, stream)
    _drain(eng_off, stream)
    c0, a0 = eng_on.jit_stats["compiles"], eng_on.pack_stats["allocs"]
    zipf["req_per_s_on"] = _timed_req_per_s(eng_on, stream, rounds)
    zipf["req_per_s_off"] = _timed_req_per_s(eng_off, stream, rounds)
    zipf["steady_compiles"] = eng_on.jit_stats["compiles"] - c0
    zipf["steady_pack_allocs"] = eng_on.pack_stats["allocs"] - a0
    zipf["warm_hit_rate"] = round(eng_on.cache_stats["hit_rate"], 4)
    section["zipf"] = zipf

    # --- 0%-hit control ----------------------------------------------
    ctl = NAIServingEngine(cfg, nai, params, g, cache_nodes=capacity,
                           cache_fill=False, **kw)
    _drain(ctl, stream)
    _drain(ctl, stream)
    rps_on = _timed_req_per_s(ctl, stream, rounds)
    rps_off = _timed_req_per_s(eng_off, stream, rounds)
    section["no_hit_control"] = {
        "hit_rate": round(ctl.cache_stats["hit_rate"], 4),
        "req_per_s_on": rps_on, "req_per_s_off": rps_off,
        "overhead_ratio": round(rps_on / max(rps_off, 1e-9), 3),
    }

    # --- mutation round: lockstep stores, cached vs cold -------------
    rng = np.random.default_rng(13)
    s_hot, s_cold = InMemoryStore(g), InMemoryStore(g)
    m_on = NAIServingEngine(cfg, nai, params, s_hot,
                            cache_nodes=capacity, **kw)
    m_off = NAIServingEngine(cfg, nai, params, s_cold, **kw)
    half = max(n_batches // 2, 1)
    p1, o1 = _serve_collect(m_on, stream[:half])
    q1, r1 = _serve_collect(m_off, stream[:half])
    hot = np.unique(np.concatenate(stream[:half]))
    src = rng.choice(hot, size=min(8, len(hot)), replace=False)
    dst = (src + 1) % g.n
    keep = src != dst
    src, dst = src[keep], dst[keep]
    new_feats = rng.normal(size=(2, g.features.shape[1])).astype(
        np.float32)
    for s in (s_hot, s_cold):
        s.add_edges(src, dst)
        new_ids = s.add_nodes(new_feats)
    tail = list(stream[half:])
    tail.append(np.concatenate([new_ids, hot[:max(bs - 2, 1)]]))
    p2, o2 = _serve_collect(m_on, tail)
    q2, r2 = _serve_collect(m_off, tail)
    mcs = m_on.cache_stats
    section["mutation"] = {
        "parity": bool(p1 == q1 and o1 == r1 and p2 == q2 and o2 == r2),
        "stale": int(mcs["stale"]), "hits": int(mcs["hits"]),
        "hit_rate": round(mcs["hit_rate"], 4),
        "edges_added": int(len(src)), "nodes_added": len(new_ids),
        "mutation_clock": int(s_hot.mutation_clock),
    }

    # --- sharded D=2 parity ------------------------------------------
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import make_serving_mesh
        skw = dict(kw, mesh=make_serving_mesh(2), gather_mode="halo")
        sh_on = NAIServingEngine(cfg, nai, params, g,
                                 cache_nodes=capacity, **skw)
        sh_off = NAIServingEngine(cfg, nai, params, g, **skw)
        sp_on, so_on = _serve_collect(sh_on, stream)
        sp_off, so_off = _serve_collect(sh_off, stream)
        scs = sh_on.cache_stats
        section["sharded"] = {
            "devices": 2, "n_shards": sh_on.n_shards,
            "parity": bool(sp_on == sp_off and so_on == so_off),
            "hit_rate": round(scs["hit_rate"], 4),
            "hits": int(scs["hits"]),
        }
    else:
        section["sharded"] = None
    return section


def _series_structural(g, cfg, nai, stream) -> Dict:
    """Measure — not assume — the series-carry shape on the default
    serving shape: pack one stream batch and run the masked NAP core
    directly; the carry's row count is what the jitted loop writes to
    HBM per step (valid under interpret mode: shapes are shapes)."""
    nodes = stream[0]
    store = as_store(g)
    sup = sample_support(store, nodes, nai.t_max, cfg.r)
    x0 = store.gather_features(sup.nodes).astype(np.float32)
    c, s = support_stationary_factors(store, sup, x0, cfg.r)
    x_inf = (c[:, None] * s[None, :]).astype(np.float32)
    packed = pack_support(sup, x0, x_inf,
                          nb_bucket=next_bucket(sup.n_batch, RB))
    sa = step_active_blocks(packed.hop_rb, nai.t_max)
    _, series = infer_batch_masked(
        cfg, nai, None, None, None, None, jnp.asarray(packed.x0),
        jnp.asarray(packed.x_inf), packed.n_batch, spmm_impl="block_ell",
        ell=(jnp.asarray(packed.tiles), jnp.asarray(packed.tile_col),
             jnp.asarray(packed.valid)),
        step_active=jnp.asarray(sa), interpret=True)
    return {
        "series_rows": int(series.shape[1]),
        "nb_pad": int(packed.n_batch),
        "support_rows": int(packed.n_pad),
        "series_rows_saving": round(
            1.0 - series.shape[1] / packed.n_pad, 3),
        "steps": int(series.shape[0] - 1),
    }


def collect(smoke: bool = False, sharded: bool = False,
            graph_scale: bool = False, store_dir: str = "",
            cache: bool = False) -> Dict:
    # graph-scale first: its RSS gate wants a process that has not yet
    # allocated every other section's engines and operands
    gs = _graph_scale(smoke, store_dir) if graph_scale else None
    g, cfg, params, nai = _setup(smoke)
    n_batches = 4 if smoke else 8
    rounds = 2 if smoke else 3
    stream = _request_stream(g, nai, n_batches)
    specs = [dict(mode="host", impl="-", depth=1)]
    for impl in ("segment", "block_ell", "fused"):
        for depth in (1, 2):
            specs.append(dict(mode="compiled", impl=impl, depth=depth))
    configs = _bench_configs(g, cfg, params, nai, specs, stream, rounds)
    speedups = {}
    for impl in ("segment", "block_ell", "fused"):
        ser = next(c for c in configs if c["impl"] == impl
                   and c["pipeline_depth"] == 1)
        pip = next(c for c in configs if c["impl"] == impl
                   and c["pipeline_depth"] == 2)
        speedups[impl] = round(pip["req_per_s"] / ser["req_per_s"], 3)
    # the acceptance comparison pins the impl whose device timing is real
    # on this backend: on CPU the Pallas impls run interpret-mode
    # EMULATION on the same cores as the host stage (nothing to overlap,
    # ~0.5% potential gain under ±% noise), so segment — actual async XLA
    # CPU compute — is the meaningful serial-vs-pipelined comparison; on
    # an accelerator the engine default block_ell is.
    d_impl = "segment" if jax.default_backend() == "cpu" else "block_ell"
    d_ser = next(c for c in configs if c["impl"] == d_impl
                 and c["pipeline_depth"] == 1)
    d_pip = next(c for c in configs if c["impl"] == d_impl
                 and c["pipeline_depth"] == 2)
    default_cmp = {
        "impl": d_impl,
        "serial_req_per_s": d_ser["req_per_s"],
        "pipelined_req_per_s": d_pip["req_per_s"],
        "pipelined_ge_serial": d_pip["req_per_s"] >= d_ser["req_per_s"],
    }
    payload = {
        "bench": "serving_bench",
        "smoke": bool(smoke),
        "unix_time": time.time(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices_available": len(jax.devices()),
        "shape": {"batch_size": nai.batch_size, "t_max": nai.t_max,
                  "feat": 64, "n_batches": n_batches},
        "structural": _series_structural(g, cfg, nai, stream),
        "pipelined_speedup": speedups,
        "default_shape_comparison": default_cmp,
        "configs": configs,
    }
    if sharded:
        payload["sharded"] = _bench_configs(
            g, cfg, params, nai, _sharded_specs(smoke), stream, rounds)
    if cache:
        payload["cache"] = _cache_section(smoke)
    if gs is not None:
        payload["graph_scale"] = gs
    return payload


def check(payload: Dict) -> List[str]:
    """Structural regressions that must fail CI (timing-independent)."""
    errs = []
    st = payload["structural"]
    if st["series_rows"] > st["nb_pad"]:
        errs.append(f"series carry stores {st['series_rows']} rows > "
                    f"nb_pad {st['nb_pad']} (batch-row carry regressed)")
    for c in payload["configs"] + payload.get("sharded", []):
        if c["mode"] != "compiled":
            continue
        tag = f"{c['impl']}/depth{c['pipeline_depth']}/dev{c['devices']}"
        if c["steady_compiles"] > 0:
            errs.append(f"{tag}: {c['steady_compiles']} jit compiles in "
                        f"steady state (bucketing defeated)")
        if c["steady_pack_allocs"] > 0:
            errs.append(f"{tag}: {c['steady_pack_allocs']} bucket-sized "
                        f"pack allocations in steady state")
    for c in payload.get("sharded", []):
        if c["n_shards"] != c["devices"]:
            errs.append(f"sharded/{c['impl']}/dev{c['devices']}: engine "
                        f"reports {c['n_shards']} shards (mesh not "
                        f"threaded through)")
        if c["devices"] < 2:
            continue
        tag = f"sharded/{c['impl']}/dev{c['devices']}/{c['gather_mode']}"
        if c["gather_mode"] != "dense":
            if c["halo_frac"] >= 1.0:
                errs.append(f"{tag}: halo_frac == 1.0 (halo path "
                            f"silently fell back to the dense exchange)")
            if c["gather_rows_per_step"] > c["s_pad"]:
                errs.append(f"{tag}: halo frame "
                            f"{c['gather_rows_per_step']} rows exceeds "
                            f"the dense frontier {c['s_pad']}")
            if c["halo_rows"] > c["gather_rows_per_step"]:
                errs.append(f"{tag}: true halo rows {c['halo_rows']} "
                            f"exceed the gathered frame "
                            f"{c['gather_rows_per_step']} (metadata "
                            f"bound violated)")
    ca = payload.get("cache")
    if ca is not None:
        z = ca["zipf"]
        if not z["parity"]:
            errs.append("cache/zipf: cached serving diverged from cold "
                        "(predictions/exit orders)")
        if not z["hit_rate"] > 0:
            errs.append("cache/zipf: hit_rate == 0 under Zipf(1.0) "
                        "(the cache never served a frontier row)")
        if z["rows_packed"] >= z["rows_support"]:
            errs.append(f"cache/zipf: rows_packed {z['rows_packed']} >= "
                        f"rows_support {z['rows_support']} (hits did "
                        f"not shrink the packed SpMM)")
        if z["steady_compiles"] > 0:
            errs.append(f"cache/zipf: {z['steady_compiles']} jit "
                        f"compiles in steady state with the cache on "
                        f"(seed shapes defeat bucketing)")
        if z["steady_pack_allocs"] > 0:
            errs.append(f"cache/zipf: {z['steady_pack_allocs']} "
                        f"bucket-sized pack allocations in steady state "
                        f"with the cache on")
        if ca["no_hit_control"]["hit_rate"] != 0.0:
            errs.append("cache/no_hit_control: a probe hit with fills "
                        "disabled (the control is not 0%-hit)")
        if not ca["mutation"]["parity"]:
            errs.append("cache/mutation: cached serving diverged from "
                        "cold after add_edges/add_nodes")
        if ca["mutation"]["stale"] <= 0:
            errs.append("cache/mutation: zero stale invalidations — "
                        "add_edges never landed on a cached entry's "
                        "version block")
        sh = ca.get("sharded")
        if sh is not None:
            if not sh["parity"]:
                errs.append(f"cache/sharded/dev{sh['devices']}: cached "
                            f"sharded serving diverged from cold")
            if sh["n_shards"] != sh["devices"]:
                errs.append(f"cache/sharded: engine reports "
                            f"{sh['n_shards']} shards for "
                            f"{sh['devices']} devices")
    gs = payload.get("graph_scale")
    if gs is not None:
        have = {r["n"] for r in gs["rows"]}
        for n_ in gs["expected_sizes"]:
            if n_ not in have:
                errs.append(f"graph_scale: missing scale row n={n_}")
        if gs.get("store_parity") is False:
            errs.append("graph_scale: MmapStore serving diverged from "
                        "the in-RAM store (predictions/exit orders)")
        for r in gs["rows"]:
            tag = f"graph_scale/n{r['n']}"
            if r["steady_compiles"] > 0:
                errs.append(f"{tag}: {r['steady_compiles']} jit compiles "
                            f"in steady state (bucketing defeated)")
            if r["steady_pack_allocs"] > 0:
                errs.append(f"{tag}: {r['steady_pack_allocs']} "
                            f"bucket-sized pack allocations in steady "
                            f"state")
            # the streaming claim: serving a graph whose feature matrix
            # dwarfs any plausible process footprint must NOT page it
            # all in. Only meaningful where the matrix actually dwarfs
            # the baseline (jax + engines is a few hundred MB on its
            # own), so the gate starts at 800 MB of features.
            if (r["feature_bytes"] >= 8e8 and r["peak_rss_bytes"] > 0
                    and r["peak_rss_bytes"] >= r["feature_bytes"]):
                errs.append(
                    f"{tag}: peak RSS {r['peak_rss_bytes']} >= feature "
                    f"bytes {r['feature_bytes']} (the store was "
                    f"materialized in RAM — streaming regressed)")
    return errs


def _sharded_csv(sharded: List[Dict]) -> List[str]:
    rows = []
    for c in sharded:
        name = f"serving/sharded/{c['impl']}/dev{c['devices']}"
        if c.get("gather_mode", "dense") != "halo" and c["devices"] > 1:
            name += f"/{c['gather_mode']}"
        us = 1e6 / max(c["req_per_s"], 1e-9)
        derived = (
            f"req_per_s={c['req_per_s']};p50_ms={c['p50_ms']};"
            f"p95_ms={c['p95_ms']};p99_ms={c['p99_ms']};"
            f"n_shards={c['n_shards']};"
            f"steady_compiles={c['steady_compiles']};"
            f"steady_pack_allocs={c['steady_pack_allocs']}")
        if c["devices"] > 1:
            derived += (f";gather_mode={c['gather_mode']};"
                        f"gather_rows_per_step={c['gather_rows_per_step']};"
                        f"halo_rows={c['halo_rows']};"
                        f"halo_frac={c['halo_frac']}")
        rows.append(csv_row(name, us, derived))
    return rows


def _graph_scale_csv(gs: Dict) -> List[str]:
    rows = []
    if not gs:
        return rows
    for r in gs.get("rows", []):
        us = 1e6 / max(r["req_per_s"], 1e-9)
        derived = (
            f"req_per_s={r['req_per_s']};p50_ms={r['p50_ms']};"
            f"p95_ms={r['p95_ms']};p99_ms={r['p99_ms']};"
            f"host_share={r['host_share']};"
            f"feature_bytes={r['feature_bytes']};"
            f"peak_rss_bytes={r['peak_rss_bytes']};"
            f"steady_compiles={r['steady_compiles']};"
            f"steady_pack_allocs={r['steady_pack_allocs']}")
        if "halo_frac" in r:
            derived += f";halo_frac={r['halo_frac']}"
        rows.append(csv_row(f"serving/graph_scale/n{r['n']}", us, derived))
    return rows


def _cache_csv(ca: Dict) -> List[str]:
    rows = []
    if not ca:
        return rows
    z = ca["zipf"]
    rows.append(csv_row(
        "serving/cache/zipf", 1e6 / max(z["req_per_s_on"], 1e-9),
        f"req_per_s_on={z['req_per_s_on']};"
        f"req_per_s_off={z['req_per_s_off']};"
        f"hit_rate={z['hit_rate']};warm_hit_rate={z['warm_hit_rate']};"
        f"rows_saved_frac={z['rows_saved_frac']};"
        f"rows_packed_per_req={z['rows_packed_per_req']};"
        f"parity={z['parity']};steady_compiles={z['steady_compiles']};"
        f"steady_pack_allocs={z['steady_pack_allocs']}"))
    nh = ca["no_hit_control"]
    rows.append(csv_row(
        "serving/cache/no_hit_control",
        1e6 / max(nh["req_per_s_on"], 1e-9),
        f"req_per_s_on={nh['req_per_s_on']};"
        f"req_per_s_off={nh['req_per_s_off']};"
        f"overhead_ratio={nh['overhead_ratio']}"))
    mu = ca["mutation"]
    rows.append(csv_row(
        "serving/cache/mutation", 0.0,
        f"parity={mu['parity']};stale={mu['stale']};"
        f"hit_rate={mu['hit_rate']};edges_added={mu['edges_added']};"
        f"nodes_added={mu['nodes_added']}"))
    if ca.get("sharded"):
        sh = ca["sharded"]
        rows.append(csv_row(
            f"serving/cache/sharded_dev{sh['devices']}", 0.0,
            f"parity={sh['parity']};hit_rate={sh['hit_rate']};"
            f"n_shards={sh['n_shards']}"))
    return rows


def _rows(payload: Dict) -> List[str]:
    rows = []
    for c in payload["configs"]:
        name = (f"serving/{c['mode']}" +
                (f"/{c['impl']}/depth{c['pipeline_depth']}"
                 if c["mode"] == "compiled" else ""))
        us = 1e6 / max(c["req_per_s"], 1e-9)
        derived = (f"req_per_s={c['req_per_s']};p50_ms={c['p50_ms']};"
                   f"p95_ms={c['p95_ms']};p99_ms={c['p99_ms']};"
                   f"steady_compiles={c['steady_compiles']}")
        if "host_stage_ms" in c:
            derived += (f";host_stage_ms={c['host_stage_ms']};"
                        f"dispatch_ms={c['dispatch_ms']};"
                        f"device_sync_ms={c['device_sync_ms']}")
        rows.append(csv_row(name, us, derived))
    rows += _sharded_csv(payload.get("sharded", []))
    rows += _cache_csv(payload.get("cache", {}))
    rows += _graph_scale_csv(payload.get("graph_scale", {}))
    st = payload["structural"]
    rows.append(csv_row(
        "serving/structural/series_carry", 0.0,
        f"series_rows={st['series_rows']};nb_pad={st['nb_pad']};"
        f"support_rows={st['support_rows']};"
        f"series_rows_saving={st['series_rows_saving']}"))
    return rows


def run() -> list:
    return _rows(collect(smoke=True))


def run_sharded() -> list:
    """Sharded rows only (for benchmarks.run): serve the smoke stream
    through row-sharded engines at every device count available. On a
    1-device backend there is nothing to shard (the only row would
    duplicate the serving suite's segment/pipelined row) — force host
    devices (XLA_FLAGS=--xla_force_host_platform_device_count=8) for
    the real sweep."""
    if len(jax.devices()) == 1:
        return []
    g, cfg, params, nai = _setup(True)
    stream = _request_stream(g, nai, 4)
    return _sharded_csv(_bench_configs(
        g, cfg, params, nai, _sharded_specs(True), stream, 2))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few rounds (CI smoke job)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on structural counter regression")
    ap.add_argument("--sharded", action="store_true",
                    help="add mesh-sharded serving rows (device counts "
                         "clipped to what the backend exposes; force "
                         "host devices via XLA_FLAGS for the full sweep)")
    ap.add_argument("--cache", action="store_true",
                    help="add the propagated-feature-cache section "
                         "(Zipf stream, parity/mutation/0%%-hit-control "
                         "rounds; sharded parity when >= 2 devices)")
    ap.add_argument("--graph-scale", action="store_true",
                    help="add the MmapStore graph-size sweep (graphs "
                         "generated on disk in a subprocess; 1e5-1e7 "
                         "nodes full-size, one small size with --smoke)")
    ap.add_argument("--store-dir", default="",
                    help="directory for --graph-scale store dirs "
                         "(default: a tempdir, deleted afterwards; "
                         "point at a persistent dir to reuse generated "
                         "graphs across runs)")
    ap.add_argument("--out", default="",
                    help="JSON output path (default BENCH_serving.json, "
                         "or BENCH_serving_smoke.json with --smoke)")
    args = ap.parse_args()
    out_path = args.out or ("BENCH_serving_smoke.json" if args.smoke
                            else "BENCH_serving.json")
    payload = collect(smoke=args.smoke, sharded=args.sharded,
                      graph_scale=args.graph_scale,
                      store_dir=args.store_dir, cache=args.cache)
    print("name,us_per_call,derived")
    for r in _rows(payload):
        print(r, flush=True)
    # sub-benches (frontend/chaos/cache/offline) merge their sections
    # into this file; write_bench_json carries them — and any section
    # this invocation's flags did not regenerate — across rewrites
    write_bench_json(out_path, payload)
    # timing-dependent, so advisory only (never a CI failure: a contended
    # runner can flip a few-percent comparison) — the committed
    # full-size BENCH_serving.json is the record of the pipelining win
    cmp_ = payload["default_shape_comparison"]
    if not cmp_["pipelined_ge_serial"]:
        print(f"WARNING: pipelined < serial req/s on the default shape "
              f"({cmp_['impl']}: {cmp_['pipelined_req_per_s']} vs "
              f"{cmp_['serial_req_per_s']}) — noise on this run?",
              file=sys.stderr)
    nh = payload.get("cache", {}).get("no_hit_control")
    if nh is not None and nh["overhead_ratio"] < 1.0:
        print(f"WARNING: cache-on req/s below cache-off at 0% hit rate "
              f"(ratio {nh['overhead_ratio']}: {nh['req_per_s_on']} vs "
              f"{nh['req_per_s_off']}) — probe/seed overhead or noise?",
              file=sys.stderr)
    if args.check:
        errs = check(payload)
        for e in errs:
            print(f"STRUCTURAL REGRESSION: {e}", file=sys.stderr)
        if errs:
            sys.exit(1)
        print("# structural counters OK (series_rows <= nb_pad, "
              "0 steady-state compiles/allocs)")


if __name__ == "__main__":
    main()
