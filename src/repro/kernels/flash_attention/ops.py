"""jit'd GQA-aware wrapper around the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import BK, BQ, flash_attention


def gqa_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        interpret: bool = True):
    """q (B, S, H, hd); k, v (B, S, KV, hd). Pads S to the block size,
    repeats KV heads to H, runs the kernel, unpads."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    S_pad = -(-S // max(BQ, BK)) * max(BQ, BK)
    pad = S_pad - S

    def prep(x, heads):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if heads != H:
            x = jnp.repeat(x, G, axis=2)
        return x.transpose(0, 2, 1, 3).reshape(B * H, S_pad, hd)

    qf = prep(q, H)
    kf = prep(k, KV)
    vf = prep(v, KV)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          interpret=interpret)
    out = out.reshape(B, H, S_pad, hd).transpose(0, 2, 1, 3)
    return out[:, :S]
