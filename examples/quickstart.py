"""Quickstart: the paper's pipeline in ~40 lines.

Train NAI (base SGC + Inception Distillation) on a synthetic pubmed-scale
graph, then run Node-Adaptive Inference at three latency settings.

    PYTHONPATH=src python examples/quickstart.py

Set ``EXAMPLES_SMOKE=1`` for the scaled-down CI shape.
"""
import os

import numpy as np

from repro.gnn import (DistillConfig, GNNConfig, NAIConfig, accuracy,
                       infer_all, load_dataset, order_distribution, train_nai)
from repro.gnn.baselines import run_vanilla

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))

# 1. data: inductive split — test nodes are unseen during training
g = load_dataset("pubmed-like", scale=0.03 if SMOKE else 0.1, seed=0)
print(f"graph: {g.n} nodes, {g.num_edges} edges, {g.num_classes} classes")

# 2. train the base model f^(k) and distill into per-order classifiers
cfg = GNNConfig(base_model="sgc", feat_dim=g.features.shape[1],
                num_classes=g.num_classes, k=4, hidden=64, mlp_layers=2)
ep = (20, 10, 10) if SMOKE else (150, 80, 80)
params, info = train_nai(cfg, g, DistillConfig(
    epochs_base=ep[0], epochs_offline=ep[1], epochs_online=ep[2]))
print(f"trained: base_loss={info['base_loss']:.4f}")

# 3. vanilla inference = every node propagates k times
van = run_vanilla(cfg, g, params)
print(f"vanilla SGC: acc={van.acc:.4f} fp_macs/node={van.fp_macs:.0f}")

# 4. NAI: per-node adaptive propagation order (Algorithm 1)
for tag, t_s, t_max in [("speed-first", 25.0, 2),
                        ("balanced", 16.0, 3),
                        ("accuracy-first", 8.0, 4)]:
    res = infer_all(cfg, NAIConfig(t_s=t_s, t_min=1, t_max=t_max,
                                   batch_size=500), params, g)
    print(f"NAI[{tag:15s}] acc={accuracy(res, g):.4f} "
          f"fp_macs/node={res.fp_macs:.0f} "
          f"({van.fp_macs / max(res.fp_macs, 1):.1f}x fewer) "
          f"exit orders={list(order_distribution(res, cfg.k))}")
