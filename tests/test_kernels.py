"""Pallas kernel validation vs the pure-jnp oracles (interpret=True): shape
and dtype sweeps per kernel (deliverable c)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.spmm import (active_blocks_from_nodes, build_block_ell,
                                pad_features, ref_spmm_dense, ref_spmm_tiles,
                                spmm, RB)
from repro.kernels.nap_exit import exit_decision, nap_exit, ref_nap_exit
from repro.kernels.nap_exit import NB as EXIT_NB, FB as EXIT_FB
from repro.kernels.flash_attention import (flash_attention,
                                           gqa_flash_attention, ref_attention)


def _random_graph(rng, n, avg_deg):
    E = n * avg_deg
    src = rng.integers(0, n, E).astype(np.int32)
    dst = rng.integers(0, n, E).astype(np.int32)
    src = np.concatenate([src, np.arange(n, dtype=np.int32)])
    dst = np.concatenate([dst, np.arange(n, dtype=np.int32)])
    key = dst.astype(np.int64) * n + src
    uk = np.unique(key)
    dst, src = (uk // n).astype(np.int32), (uk % n).astype(np.int32)
    coef = rng.random(len(src)).astype(np.float32)
    return src, dst, coef


# ------------------------------------------------------------------- spmm
@pytest.mark.parametrize("n,deg,f", [(64, 3, 64), (200, 6, 100),
                                     (300, 2, 130), (128, 10, 256)])
def test_spmm_shapes(rng, n, deg, f):
    src, dst, coef = _random_graph(rng, n, deg)
    ell = build_block_ell(src, dst, coef, n)
    x = rng.standard_normal((n, f)).astype(np.float32)
    xp = jnp.asarray(pad_features(x, ell.n_pad))
    out = spmm(ell, xp, interpret=True)
    ref = ref_spmm_dense(src, dst, coef, ell.n_pad, xp,
                         np.ones(ell.tile_col.shape[0], np.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("frac_active", [0.0, 0.3, 1.0])
def test_spmm_nap_predication(rng, frac_active):
    src, dst, coef = _random_graph(rng, 192, 4)
    ell = build_block_ell(src, dst, coef, 192)
    n_rb = ell.tile_col.shape[0]
    active = (rng.random(n_rb) < frac_active).astype(np.int32)
    x = rng.standard_normal((192, 64)).astype(np.float32)
    xp = jnp.asarray(pad_features(x, ell.n_pad))
    out = spmm(ell, xp, jnp.asarray(active), interpret=True)
    ref = ref_spmm_tiles(ell.tiles, ell.tile_col, ell.valid, active, xp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # inactive row blocks are exactly zero
    for rb in np.flatnonzero(active == 0):
        assert float(jnp.abs(out[rb * RB:(rb + 1) * RB]).max()) == 0.0


def test_spmm_dtype_bf16(rng):
    src, dst, coef = _random_graph(rng, 128, 4)
    ell = build_block_ell(src, dst, coef, 128)
    x = rng.standard_normal((128, 128)).astype(np.float32)
    xp = jnp.asarray(pad_features(x, ell.n_pad)).astype(jnp.bfloat16)
    out = spmm(ell, xp, interpret=True)
    ref = ref_spmm_dense(src, dst, coef, ell.n_pad, xp.astype(jnp.float32),
                         np.ones(ell.tile_col.shape[0], np.int32))
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=0.05, atol=0.05)


def test_active_blocks_from_nodes():
    act = jnp.zeros(20, bool).at[9].set(True)
    blk = active_blocks_from_nodes(act, 24)
    assert blk.shape == (3,)
    assert list(np.asarray(blk)) == [0, 1, 0]


# ---------------------------------------------------------------- nap_exit
@pytest.mark.parametrize("n,f", [(40, 100), (100, 300), (8, 128), (256, 500)])
def test_nap_exit_shapes(rng, n, f):
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    xi = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    act = jnp.asarray(rng.random(n) < 0.7)
    t_s = float(np.sqrt(f) * 1.2)
    d, e, blk = exit_decision(x, xi, act, t_s, interpret=True)
    ref_d = jnp.linalg.norm(x - xi, axis=1)
    np.testing.assert_allclose(np.asarray(d), np.asarray(ref_d), rtol=1e-4)
    ref_e = np.asarray(act) & (np.asarray(ref_d) < t_s)
    assert np.array_equal(np.asarray(e), ref_e)


def test_nap_exit_vs_oracle_padded(rng):
    n, f = 100, 200
    n_pad = -(-n // EXIT_NB) * EXIT_NB
    f_pad = -(-f // EXIT_FB) * EXIT_FB
    x = jnp.zeros((n_pad, f_pad)).at[:n, :f].set(
        jnp.asarray(rng.standard_normal((n, f)), jnp.float32))
    xi = jnp.zeros((n_pad, f_pad)).at[:n, :f].set(
        jnp.asarray(rng.standard_normal((n, f)), jnp.float32))
    ap = jnp.zeros((n_pad, 1), jnp.int32).at[:n, 0].set(1)
    for out_k, out_r in zip(nap_exit(x, xi, ap, 15.0, interpret=True),
                            ref_nap_exit(x, xi, ap, 15.0)):
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("S,hd,causal,window",
                         [(128, 64, True, 0), (256, 64, True, 64),
                          (256, 128, False, 0), (384, 32, True, 128)])
def test_flash_attention_sweep(rng, S, hd, causal, window):
    q = jnp.asarray(rng.standard_normal((2, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, S, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = ref_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.standard_normal((1, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    ref = ref_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=0.05, atol=0.05)


def test_gqa_wrapper_unpadded_seq(rng):
    q = jnp.asarray(rng.standard_normal((2, 100, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 100, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 100, 2, 32)), jnp.float32)
    out = gqa_flash_attention(q, k, v, interpret=True)
    kr = jnp.repeat(k, 4, 2)
    vr = jnp.repeat(v, 4, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(16, 100, 32)
    ref = ref_attention(qf, kr.transpose(0, 2, 1, 3).reshape(16, 100, 32),
                        vr.transpose(0, 2, 1, 3).reshape(16, 100, 32))
    ref = ref.reshape(2, 8, 100, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


# -------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("T,hd,H", [(32, 16, 2), (40, 16, 3), (64, 32, 1)])
def test_wkv6_kernel_vs_sequential(rng, T, hd, H):
    from repro.kernels.wkv6 import ref_wkv6_sequential, wkv6_heads
    B = 2
    r = rng.standard_normal((B, T, H, hd)).astype(np.float32)
    k = rng.standard_normal((B, T, H, hd)).astype(np.float32)
    v = rng.standard_normal((B, T, H, hd)).astype(np.float32)
    logw = np.maximum(
        -np.exp(rng.standard_normal((B, T, H, hd)) * 0.5), -5.0
    ).astype(np.float32)
    u = (rng.standard_normal((H, hd)) * 0.1).astype(np.float32)
    out = wkv6_heads(jnp.asarray(r), jnp.asarray(k), jnp.asarray(v),
                     jnp.asarray(logw), jnp.asarray(u), interpret=True)
    flat = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    ref = ref_wkv6_sequential(
        flat(r), flat(k), flat(v), flat(logw),
        np.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    ).reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_wkv6_state_continuity_across_chunks(rng):
    """Outputs after the first chunk depend on earlier chunks' state."""
    from repro.kernels.wkv6 import CHUNK, wkv6
    BH, T, hd = 1, CHUNK * 2, 16
    r = jnp.asarray(rng.standard_normal((BH, T, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, T, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, T, hd)), jnp.float32)
    lw = jnp.full((BH, T, hd), -0.1, jnp.float32)
    u = jnp.zeros((BH, hd), jnp.float32)
    full = wkv6(r, k, v, lw, u, interpret=True)
    # zeroing the first chunk's k must change the second chunk's output
    k2 = k.at[:, :CHUNK].set(0.0)
    alt = wkv6(r, k2, v, lw, u, interpret=True)
    assert float(jnp.abs(full[:, CHUNK:] - alt[:, CHUNK:]).max()) > 1e-3
