"""Synthetic graph datasets (the container has no network access, so the
paper's OGB/Planetoid datasets are replaced by deterministic generators with
matched scale knobs — DESIGN.md §6).

Generator: degree-corrected stochastic block model. Classes are SBM blocks;
node features are noisy class prototypes, so feature propagation over the
homophilous graph genuinely improves classification — the same mechanism the
paper's technique exploits (nodes deep inside a block smooth quickly -> exit
early; boundary/high-degree nodes need more hops).

Reproducibility contract (shared with `repro.gnn.store.make_graph`): every
generator takes an EXPLICIT seed — no module-level RNG, no default — and
routes all randomness through the one `np.random.Generator` seeded from
it, so the same (name, scale, seed) triple yields the same graph in every
process. Bench and test graphs are reproducible across machines because
of this; do not add `np.random.*` module calls here.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.gnn.graph import Graph, add_self_loops

# name -> (nodes, avg_degree, feat_dim, classes) — shaped after Table 2,
# scaled to CPU-friendly sizes by `scale`.
PRESETS: Dict[str, tuple] = {
    "pubmed-like":   (19_717, 4,  500, 3),
    "flickr-like":   (89_250, 20, 500, 7),
    "arxiv-like":    (169_343, 13, 128, 40),
    "products-like": (2_449_029, 100, 100, 47),
}


def make_sbm(name: str, *, scale: float = 1.0, seed: int,
             homophily: float = 0.9, power_law: float = 1.6,
             feature_noise: float = 1.8) -> Graph:
    if seed is None:
        raise ValueError("make_sbm requires an explicit integer seed "
                         "(graphs must be reproducible across processes)")
    n_full, avg_deg, f, c = PRESETS[name]
    n = max(int(n_full * scale), 50 * c)
    rng = np.random.default_rng(seed)

    labels = rng.integers(0, c, n).astype(np.int32)

    # degree-corrected: power-law degree propensities
    theta = rng.pareto(power_law, n) + 1.0
    theta = np.clip(theta / theta.mean(), 0.05, 50.0)
    target_edges = n * avg_deg // 2

    # sample edges: with prob `homophily` endpoints share a class
    def sample_endpoints(k, same_class):
        u = np.empty(k, np.int64)
        v = np.empty(k, np.int64)
        p = theta / theta.sum()
        u[:] = rng.choice(n, size=k, p=p)
        if same_class:
            # choose v from u's class, degree-weighted
            order = np.argsort(labels, kind="stable")
            sorted_theta = theta[order]
            bounds = np.searchsorted(labels[order], np.arange(c + 1))
            for cls in range(c):
                m = labels[u] == cls
                lo, hi = bounds[cls], bounds[cls + 1]
                if hi <= lo or not m.any():
                    continue
                pc = sorted_theta[lo:hi] / sorted_theta[lo:hi].sum()
                v[m] = order[lo + rng.choice(hi - lo, size=m.sum(), p=pc)]
        else:
            v[:] = rng.choice(n, size=k, p=p)
        return u, v

    k_same = int(target_edges * homophily)
    u1, v1 = sample_endpoints(k_same, True)
    u2, v2 = sample_endpoints(target_edges - k_same, False)
    u = np.concatenate([u1, u2])
    v = np.concatenate([v1, v2])
    keep = u != v
    u, v = u[keep], v[keep]
    # symmetrize + dedupe
    eid = np.unique(np.minimum(u, v) * n + np.maximum(u, v))
    u, v = (eid // n).astype(np.int32), (eid % n).astype(np.int32)
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    src, dst = add_self_loops(src, dst, n)

    # features: class prototypes + noise
    protos = rng.standard_normal((c, f)).astype(np.float32)
    feats = protos[labels] + feature_noise * rng.standard_normal((n, f)).astype(np.float32)

    # inductive split: ~80% train region (small labeled core), 20% test
    perm = rng.permutation(n)
    n_test = n // 5
    test_idx = perm[:n_test]
    rest = perm[n_test:]
    n_labeled = max(c * 20, int(0.05 * len(rest)))
    train_idx = rest[:n_labeled]
    unlabeled_idx = rest[n_labeled:]

    return Graph(n=n, src=src, dst=dst, features=feats, labels=labels,
                 num_classes=c, train_idx=train_idx.astype(np.int32),
                 unlabeled_idx=unlabeled_idx.astype(np.int32),
                 test_idx=test_idx.astype(np.int32), name=name)


def load_dataset(name: str, scale: float = 1.0, seed: int = None,
                 hard: bool = False) -> Graph:
    """`hard=True`: noisier features + weaker homophily — used by the
    sensitivity benchmark (fig3) where the default generator saturates.
    `seed` is required (explicit-seed contract, module docstring)."""
    if seed is None:
        raise ValueError("load_dataset requires an explicit integer seed "
                         "(graphs must be reproducible across processes)")
    if hard:
        return make_sbm(name, scale=scale, seed=seed, homophily=0.65,
                        feature_noise=6.0)
    return make_sbm(name, scale=scale, seed=seed)
