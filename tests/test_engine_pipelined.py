"""Pipelined serving (PR 3): the two-stage engine pipeline must be a pure
latency optimization — identical predictions and exit orders to serial
serving on the same request stream, zero steady-state jit compiles (the
batch-row series carry must not add a shape axis that defeats bucketing),
zero steady-state bucket-sized pack allocations, and bounded stats."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig, _needed_mask
from repro.gnn.sampler import sample_support
from repro.serving import NAIServingEngine
from repro.serving.engine import EngineStats, LatencyRing
from repro.gnn.store import as_store


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("pubmed-like", scale=0.02, seed=4)
    # one FB feature block keeps interpret-mode Pallas test-sized
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
    return g, cfg, params, nai


@pytest.fixture(scope="module")
def stream(setup):
    """One shared request stream with ragged batch sizes (same bucket)."""
    g = setup[0]
    rng = np.random.default_rng(0)
    return [rng.choice(g.test_idx, size=s, replace=False)
            for s in (32, 30, 32, 28)]


def _serve_stream(engine, stream):
    done = []
    for nodes in stream:
        engine.submit(nodes)
        done += engine.step()
    done += engine.flush()
    return (np.array([r.node_id for r in done]),
            np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))


@pytest.mark.parametrize("impl", ["segment", "block_ell", "fused"])
def test_pipelined_matches_serial(setup, stream, impl):
    """Same stream through a serial (depth-1) and a pipelined (depth-2)
    engine: identical completion order, predictions, and exit orders."""
    g, cfg, params, nai = setup
    serial = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                              mode="compiled", spmm_impl=impl)
    piped = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                             mode="compiled", spmm_impl=impl,
                             pipeline_depth=2)
    ns, ps, os_ = _serve_stream(serial, stream)
    np_, pp, op = _serve_stream(piped, stream)
    np.testing.assert_array_equal(np_, ns)   # FIFO completion preserved
    np.testing.assert_array_equal(pp, ps)
    np.testing.assert_array_equal(op, os_)
    assert piped.stats.served == serial.stats.served == \
        sum(len(b) for b in stream)
    # the pipeline really ran deferred: some step() returned a previous
    # batch, and flush() drained the in-flight tail
    assert not piped._inflight


def test_pipelined_steady_state_zero_compiles(setup, stream):
    """Ragged batch sizes landing in already-seen buckets must be jit
    cache hits AND pooled pack-buffer reuses — the batch-row series carry
    must not introduce a new shape axis that defeats bucketing."""
    g, cfg, params, nai = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           pipeline_depth=2)
    # warm: pass 1 grows the high-water marks (compiles + allocations);
    # pass 2 lets every rotating pool slot converge to the final bucket
    # shapes (a slot allocated before the HWM peaked is replaced once)
    _serve_stream(eng, stream)
    _serve_stream(eng, stream)
    compiles0 = eng.jit_stats["compiles"]
    allocs0 = eng.pack_stats["allocs"]
    _serve_stream(eng, stream)           # steady state
    assert eng.jit_stats["compiles"] == compiles0
    assert eng.jit_stats["hits"] >= len(stream)
    assert eng.pack_stats["allocs"] == allocs0
    assert eng.jit_cache_size() == compiles0


def test_pipeline_depth_validation(setup):
    g, cfg, params, nai = setup
    with pytest.raises(ValueError):
        NAIServingEngine(cfg, nai, params, g, pipeline_depth=0)
    with pytest.raises(ValueError):
        NAIServingEngine(cfg, nai, params, g, mode="host",
                         pipeline_depth=2)


def test_step_on_empty_queue_keeps_pipeline(setup, stream):
    """An empty queue must NOT drain the pipeline: a momentarily empty
    queue under bursty arrivals is exactly when host/device overlap
    matters, and the old `return self.flush()` was a sync barrier that
    silently degraded depth-2 to serial. Batches within the pipeline
    depth stay in flight across empty-queue steps; `flush()` remains the
    explicit drain."""
    g, cfg, params, nai = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           pipeline_depth=2)
    eng.submit(stream[0])
    assert eng.step() == []              # pipe filling
    assert len(eng._inflight) == 1
    assert eng.step() == []              # empty queue: pipeline kept
    assert len(eng._inflight) == 1       # still in flight, no barrier
    eng.submit(stream[1])
    done = eng.step()                    # next batch pushes depth to 2
    assert len(done) == len(stream[0])   # -> oldest finalized (FIFO)
    assert len(eng._inflight) == 1
    done = eng.flush()                   # explicit drain
    assert len(done) == len(stream[1])
    assert not eng._inflight


def test_donation_gating(setup):
    """On CPU (this suite's backend) donation is auto-disabled — XLA CPU
    does not implement buffer donation; an explicit donate=True still
    threads the argnums through for accelerator backends."""
    g, cfg, params, nai = setup
    from repro.gnn.nai import make_compiled_infer
    auto = NAIServingEngine(cfg, nai, params, g, mode="compiled",
                            spmm_impl="segment")
    expected = () if jax.default_backend() == "cpu" else (1, 2, 3)
    assert auto.donate_argnums == expected
    forced = make_compiled_infer(cfg, nai, spmm_impl="segment",
                                 donate=True)
    assert forced._donate_argnums == (1, 2, 3)


# ------------------------------------------------------------ satellites
def test_latency_ring_is_bounded():
    ring = LatencyRing(capacity=100)
    for i in range(1000):
        ring.append(float(i))
    assert len(ring) == 100
    assert ring.total_appended == 1000
    # window holds exactly the most recent 100 samples
    assert sorted(ring.values()) == [float(v) for v in range(900, 1000)]


def test_latency_ring_short_run_matches_list():
    """Below capacity the ring is indistinguishable from the old
    unbounded list: same samples, same percentiles, same summary."""
    rng = np.random.default_rng(3)
    lat = rng.random(50).tolist()
    stats = EngineStats()
    for v in lat:
        stats.latencies.append(v)
    for q in (50, 95, 99):
        assert stats.percentile(q) == pytest.approx(
            float(np.percentile(lat, q)))
    assert stats.summary()["p50_ms"] == pytest.approx(
        1e3 * float(np.percentile(lat, 50)))


def test_engine_stats_served_unaffected_by_ring(setup, stream):
    g, cfg, params, nai = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           latency_window=8)
    _serve_stream(eng, stream)
    total = sum(len(b) for b in stream)
    assert eng.stats.served == total
    assert len(eng.stats.latencies) == 8          # bounded window
    assert eng.stats.latencies.total_appended == total
    assert eng.stats.summary()["p99_ms"] >= 0.0


def _needed_mask_isin_reference(sup, active_batch, remaining_hops):
    """The pre-PR-3 np.isin implementation, kept as the oracle."""
    S = len(sup)
    dist = np.full(S, np.iinfo(np.int32).max, np.int32)
    dist[:sup.n_batch][active_batch] = 0
    frontier = np.flatnonzero(dist == 0)
    for h in range(1, remaining_hops + 1):
        if len(frontier) == 0:
            break
        m = np.isin(sup.dst, frontier)
        cand = sup.src[m]
        new = cand[dist[cand] > h]
        dist[new] = h
        frontier = np.unique(new)
    return dist <= remaining_hops


def test_needed_mask_matches_isin_reference(setup):
    """The O(E) boolean-lookup frontier filter must reproduce the
    np.isin scan bit-for-bit across hop budgets and active patterns."""
    g, cfg, _, nai = setup
    rng = np.random.default_rng(7)
    nodes = rng.choice(g.test_idx, size=32, replace=False)
    sup = sample_support(as_store(g), nodes, 3, cfg.r)
    for frac in (1.0, 0.5, 0.1, 0.0):
        active = rng.random(sup.n_batch) < frac
        for hops in (0, 1, 2, 3):
            got = _needed_mask(sup, active, hops)
            want = _needed_mask_isin_reference(sup, active, hops)
            np.testing.assert_array_equal(got, want, err_msg=f"{frac}/{hops}")
