"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].
40L, d_model 6144, 48 heads (GQA kv=8), d_ff 10752 per expert, vocab 100352."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    pattern=("attn_moe",),
    mlp_kind="swiglu",
    num_experts=16,
    experts_per_token=4,
    norm_kind="layernorm",
    rope_theta=500000.0,
)
