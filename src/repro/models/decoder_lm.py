"""Generic scanned-trunk language model.

One implementation covers every assigned architecture: the config's
`pattern` (repeated) + `remainder` decide what each layer is. Stacked
parameters + `jax.lax.scan` keep the HLO size independent of depth — an
88-layer mistral-large lowers as fast as a 2-layer smoke model.

Public API (all pure functions):
    model_defs / init_params / param_specs / abstract_params
    forward(cfg, params, batch, mode)      -> logits, aux, block_states
    loss_fn(cfg, params, batch)            -> loss, metrics
    init_cache / abstract_cache / cache_specs
    decode_step(cfg, params, cache, tokens, pos, frontend) -> logits, cache
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.nn import blocks as B
from repro.nn.basic import apply_norm, norm_defs
from repro.nn.params import ParamDef, abstract_tree, init_tree, spec_tree
from repro.sharding import constrain, spec as logical_spec


# ------------------------------------------------------------------ helpers
def _stack_defs(defs, n: int):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical,
                           d.init, d.scale, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _slice_tree(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


# ------------------------------------------------------------------- params
def model_defs(cfg) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    R = cfg.pattern_repeats
    defs: Dict[str, Any] = {
        "embed": ParamDef((V, d), ("vocab", "embed"), "embed"),
        "blocks": tuple(_stack_defs(B.layer_defs(cfg, kind), R)
                        for kind in cfg.pattern),
        "rem": tuple(B.layer_defs(cfg, kind) for kind in cfg.remainder),
        "final_norm": norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, V), ("embed", "vocab"))
    if cfg.is_encdec:
        defs["encoder"] = _stack_defs(B.layer_defs(cfg, "enc"),
                                      cfg.encoder_layers)
        defs["encoder_norm"] = norm_defs(cfg)
    if cfg.adaptive.enabled and cfg.adaptive.exit_layers:
        n = len(cfg.adaptive.exit_layers)
        defs["exits"] = {
            "adapter": ParamDef((n, d, d), ("exit", "embed", None), "small"),
            "norm_scale": ParamDef((n, d), ("exit", "embed"), "ones"),
            # self-attention ensemble weight vector s (Eq. 5 of the paper)
            "ens_s": ParamDef((V, 1), ("vocab", None), "small"),
        }
    return defs


def init_params(cfg, key):
    return init_tree(key, model_defs(cfg), cfg.param_dtype)


def param_specs(cfg):
    return spec_tree(model_defs(cfg))


def abstract_params(cfg):
    return abstract_tree(model_defs(cfg), cfg.param_dtype)


def _sinusoid(positions, d):
    """positions (B,S) -> (B,S,d) fixed sinusoidal embedding."""
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                   * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_tokens(cfg, params, tokens, positions):
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.scale_embed_sqrt_d:
        x = x * jnp.asarray(jnp.sqrt(jnp.float32(cfg.d_model)), dtype)
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoid(positions, cfg.d_model).astype(dtype)
    return constrain(x, "batch", "seq", "embed")


# ------------------------------------------------------------------ encoder
def _run_encoder(cfg, params, frontend):
    """Stub-frontend embeddings (B, Se, d) -> encoder output (B, Se, d)."""
    x = frontend.astype(jnp.dtype(cfg.dtype))
    Se = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Se)[None], x.shape[:2])

    def body(x, p):
        x, _, _ = B.apply_layer(cfg, "enc", p, x, mode="train",
                                positions=positions)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(cfg, params["encoder_norm"], x)


# ------------------------------------------------------------------ forward
def forward(cfg, params, tokens, *, frontend=None, mode: str = "train",
            collect_states: bool = False):
    """tokens (B,S) int32. Returns (logits, aux, states) where states is a
    list of per-block hidden states (adaptive-depth exits) or None.

    `frontend`: (B, N, d) stub embeddings — image patches (vlm), audio
    frames (audio enc-dec input), or None.
    """
    dtype = jnp.dtype(cfg.dtype)
    S = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], tokens.shape)
    x = _embed_tokens(cfg, params, tokens, positions)
    if cfg.is_encdec:
        frontend = _run_encoder(cfg, params, frontend)

    collect = collect_states or (cfg.adaptive.enabled and mode == "train")
    aux0 = jnp.zeros((), jnp.float32)

    def block_body(carry, pblock):
        x, aux = carry
        for j, kind in enumerate(cfg.pattern):
            x, _, aux = B.apply_layer(cfg, kind, pblock[j], x, mode="train",
                                      positions=positions, frontend=frontend,
                                      aux=aux)
        ys = x if collect else jnp.zeros((), dtype)
        return (x, aux), ys

    body = block_body
    if getattr(cfg, "_remat", True) and mode == "train":
        body = jax.checkpoint(block_body, prevent_cse=False)

    (x, aux), states = jax.lax.scan(body, (x, aux0), params["blocks"])
    for p, kind in zip(params["rem"], cfg.remainder):
        x, _, aux = B.apply_layer(cfg, kind, p, x, mode="train",
                                  positions=positions, frontend=frontend,
                                  aux=aux)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _project_logits(cfg, params, x)
    return logits, aux, (states if collect else None)


def _project_logits(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    return constrain(logits, "batch", "seq", "vocab")


def exit_logits(cfg, params, state, exit_index: int):
    """Exit head = per-exit adapter + rmsnorm + shared unembedding."""
    e = params["exits"]
    h = state @ e["adapter"][exit_index].astype(state.dtype)
    hf = h.astype(jnp.float32)
    hf = hf * jax.lax.rsqrt(jnp.mean(jnp.square(hf), -1, keepdims=True)
                            + cfg.norm_eps)
    h = (hf * e["norm_scale"][exit_index].astype(jnp.float32)).astype(state.dtype)
    return _project_logits(cfg, params, h)


# --------------------------------------------------------------------- loss
def softmax_xent(logits, labels, mask=None):
    """logits (B,S,V) any dtype; labels (B,S) int32; mask (B,S) optional."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def loss_fn(cfg, params, batch):
    """Next-token prediction; batch = {'tokens', optional 'frontend'}."""
    tokens = batch["tokens"]
    logits, aux, states = forward(cfg, params, tokens,
                                  frontend=batch.get("frontend"), mode="train")
    labels = tokens[:, 1:]
    lm = softmax_xent(logits[:, :-1], labels)
    loss = lm + aux
    metrics = {"lm_loss": lm, "aux_loss": aux}
    if cfg.adaptive.enabled and states is not None and "exits" in params:
        from repro.core.inception_distill import transformer_inception_loss
        id_loss, id_metrics = transformer_inception_loss(
            cfg, params, states, logits, labels)
        loss = loss + id_loss
        metrics.update(id_metrics)
    metrics["loss"] = loss
    return loss, metrics


def prefill_step(cfg, params, tokens, *, frontend=None):
    """Process a full prompt; returns (last-position logits (B, V), caches).
    This is the serving prefill: KV caches (or recurrent states) for every
    layer are materialized as scan outputs."""
    dtype = jnp.dtype(cfg.dtype)
    S = tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], tokens.shape)
    x = _embed_tokens(cfg, params, tokens, positions)
    if cfg.is_encdec:
        frontend = _run_encoder(cfg, params, frontend)

    def block_body(x, pblock):
        caches = []
        for j, kind in enumerate(cfg.pattern):
            x, c, _ = B.apply_layer(cfg, kind, pblock[j], x, mode="prefill",
                                    positions=positions, frontend=frontend)
            caches.append(c)
        return x, tuple(caches)

    x, block_caches = jax.lax.scan(block_body, x, params["blocks"])
    rem_caches = []
    for p, kind in zip(params["rem"], cfg.remainder):
        x, c, _ = B.apply_layer(cfg, kind, p, x, mode="prefill",
                                positions=positions, frontend=frontend)
        rem_caches.append(c)
    x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    logits = _project_logits(cfg, params, x)[:, 0, :]
    return logits, {"blocks": block_caches, "rem": tuple(rem_caches)}


# ------------------------------------------------------------------- decode
def _decode_len(cfg, shape_seq: int) -> int:
    """KV length actually materialized for a decode shape. Full-attention
    configs serving long contexts switch to the sliding-window variant."""
    if shape_seq > 32_768 and cfg.supports_long_context == "window":
        return cfg.long_context_window
    return shape_seq


def init_cache(cfg, batch: int, length: int):
    dtype = jnp.dtype(cfg.dtype)
    R = cfg.pattern_repeats

    def stacked(kind):
        one = B.init_layer_cache(cfg, kind, batch, length, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (R,) + a.shape).copy(), one)

    cache = {
        "blocks": tuple(stacked(kind) for kind in cfg.pattern),
        "rem": tuple(B.init_layer_cache(cfg, kind, batch, length, dtype)
                     for kind in cfg.remainder),
    }
    return cache


def abstract_cache(cfg, batch: int, length: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, length))


_CACHE_LOGICAL = {
    "h": ("batch", "rnn"),
    "conv": ("batch", None, "rnn"),
    "state": ("batch", "heads", None, None),
    "x_t": ("batch", "embed"),
    "x_c": ("batch", "embed"),
}

_TP_AXIS = 16  # production model-axis size (launch/mesh.py)


def _kv_cache_logical(cfg):
    """KV cache TP dim. NEVER the sequence dim: a seq-sharded cache turns
    the per-step dynamic-update-slice into a full cache all-gather
    (measured 104 GB/chip/step on mistral decode_32k — §Perf-3 iter 1).
    Prefer kv_heads; fall back to head_dim (partial-logits all-reduce is
    tiny); else replicate over model."""
    if cfg.num_kv_heads % _TP_AXIS == 0:
        return ("batch", "cache_seq", "kv_heads", None)
    if cfg.resolved_head_dim % _TP_AXIS == 0:
        return ("batch", "cache_seq", None, "cache_hd")
    return ("batch", "cache_seq", None, None)


def cache_specs(cfg, batch: int, length: int):
    ab = abstract_cache(cfg, batch, length)
    kv_logical = _kv_cache_logical(cfg)

    def to_spec(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        logical = kv_logical if key in ("k", "v", "xk", "xv") \
            else _CACHE_LOGICAL[key]
        stacked = len(leaf.shape) == len(logical) + 1
        names = (("layers",) + logical) if stacked else logical
        return logical_spec(*names)

    return jax.tree_util.tree_map_with_path(to_spec, ab)


def seed_frontend_cache(cfg, params, cache, frontend):
    """Fill the xk/xv entries of a fresh decode cache from frontend
    embeddings (VLM) or the encoder output (enc-dec) — decode-from-scratch
    serving without a full prefill."""
    from repro.nn import attention as A
    if cfg.is_encdec:
        frontend = _run_encoder(cfg, params, frontend)
    R = cfg.pattern_repeats
    new_blocks = []
    for j, kind in enumerate(cfg.pattern):
        cb = cache["blocks"][j]
        if kind in ("xattn", "encdec"):
            ks, vs = [], []
            for r in range(R):
                pr = jax.tree.map(lambda a: a[r], params["blocks"][j])
                k, v = A.project_kv(cfg, pr["xattn"], frontend)
                ks.append(k)
                vs.append(v)
            cb = dict(cb, xk=jnp.stack(ks).astype(cb["xk"].dtype),
                      xv=jnp.stack(vs).astype(cb["xv"].dtype))
        new_blocks.append(cb)
    new_rem = []
    for p, c, kind in zip(params["rem"], cache["rem"], cfg.remainder):
        if kind in ("xattn", "encdec"):
            k, v = A.project_kv(cfg, p["xattn"], frontend)
            c = dict(c, xk=k.astype(c["xk"].dtype),
                     xv=v.astype(c["xv"].dtype))
        new_rem.append(c)
    return {"blocks": tuple(new_blocks), "rem": tuple(new_rem)}


def decode_step(cfg, params, cache, tokens, pos, frontend=None):
    """One decode step. tokens (B,1) int32; pos scalar int32 (absolute
    position of the new token). Returns (logits (B,1,V), new cache)."""
    positions = jnp.broadcast_to(pos[None, None], tokens.shape)
    x = _embed_tokens(cfg, params, tokens, positions)

    # Layer scan with the stacked cache as CARRY, updated by a
    # dynamic-index DUS per iteration. Collecting new layer caches as scan
    # ys re-materializes the whole stacked cache every iteration (measured
    # 968 GB/chip/step on mistral decode_32k); unrolling makes full-buffer
    # copies per layer instead (§Perf-3 iterations 5-6). A loop carry
    # aliases in place.
    def block_body(carry, xs):
        x, cblocks, i = carry
        pblock = xs
        new_cblocks = []
        for j, kind in enumerate(cfg.pattern):
            cl_ = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(buf, i, 0,
                                                         keepdims=False),
                cblocks[j])
            x, c, _ = B.apply_layer(cfg, kind, pblock[j], x, mode="decode",
                                    cache=cl_, pos=pos, frontend=frontend)
            new_cblocks.append(jax.tree.map(
                lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                    buf, new.astype(buf.dtype), i, 0),
                cblocks[j], c))
        return (x, tuple(new_cblocks), i + 1), None

    (x, new_blocks, _), _ = jax.lax.scan(
        block_body, (x, cache["blocks"], jnp.int32(0)), params["blocks"])
    new_rem = []
    for p, c, kind in zip(params["rem"], cache["rem"], cfg.remainder):
        x, c2, _ = B.apply_layer(cfg, kind, p, x, mode="decode", cache=c,
                                 pos=pos, frontend=frontend)
        new_rem.append(c2)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _project_logits(cfg, params, x)
    return logits, {"blocks": new_blocks, "rem": tuple(new_rem)}
