"""Open-loop serving front-end benchmark: goodput and latency percentiles
vs offered load, per SLO class, serial vs pipelined.

The closed-loop serving bench (`benchmarks.serving_bench`) measures how
fast the engine drains pre-formed batches; this bench measures what a
deployment actually ships — a Poisson stream of single requests with
per-class deadlines flowing through `repro.serving.frontend`. Two passes:

**Deterministic virtual-time pass** (the ``--check`` CI gates). A fixed
bursty arrival trace is replayed on a virtual clock, so batch
composition depends only on the trace — not machine speed — and the
structural claims are exactly testable:

* ``parity_frontend_vs_direct`` — predictions and exit orders of every
  front-end-served request are bit-identical to replaying the SAME
  engine batches (regrouped via ``Request.batch_id``) through a fresh
  direct `NAIServingEngine`. The front-end adds routing and deadlines,
  never numerics.
* ``parity_pipelined_vs_serial`` — a depth-2 front-end serves the trace
  bit-identically to a depth-1 front-end (the batch former's triggers do
  not depend on pipeline depth).
* ``steady_compiles`` / ``steady_pack_allocs`` — per class, the third
  identical trace replay (after two warm-ups grow the bucket high-water
  marks and converge the pack pools) compiles nothing and allocates no
  bucket-sized buffers.

**Real-time open-loop pass** (the committed goodput record; timings are
machine-dependent and advisory in CI). Per-class batch service time is
calibrated on warm engines, then Poisson arrivals are offered at
``load_frac`` in {0.5, 1.0, 2.0} of estimated aggregate capacity, split
evenly across classes. Each class's deadline budget is a small multiple
of its calibrated batch time, so **goodput** (answers within deadline /
offered) discriminates: under-load runs complete nearly everything in
budget, the 2.0 overload run sheds at the bounded queue and keeps the
accepted requests' queueing delay — and therefore goodput — from
collapsing. The highest-load level runs serial and pipelined
front-ends on identical arrival traces (best of ``rounds``); the
committed full run records ``pipelined_ge_serial`` there.

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.frontend_bench [--smoke] [--check]
                                                       [--out F]

Full runs merge the payload under the ``"frontend"`` key of
``BENCH_serving.json`` (so the serving trajectory stays one file);
``--smoke`` writes a standalone ``BENCH_frontend_smoke.json``.
``--check`` exits nonzero when a virtual-pass gate fails or a class
records zero goodput — the CI guard.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from collections import defaultdict
from typing import Dict, List, Tuple

if __package__ in (None, ""):     # `python benchmarks/frontend_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import jax
import numpy as np

from benchmarks.common import csv_row, write_bench_json
from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.serving import NAIServingEngine, ServingFrontend, SLOClass

IMPL = "segment"      # real async XLA CPU compute (interpret-mode Pallas
                      # is emulation — open-loop timing would be noise)


def _setup(smoke: bool):
    """Same serving shape family as serving_bench, with a smaller batch
    size — the front-end forms batches from single arrivals, so the age
    trigger must be reachable inside a bench-sized run — and a wider
    feature slice: this bench is segment-only (no interpret-mode Pallas
    to keep small), and the device stage must carry real work for the
    serial-vs-pipelined comparison to measure overlap rather than
    Python-loop overhead."""
    g = load_dataset("pubmed-like", scale=0.02 if smoke else 0.05, seed=0)
    feat = 256
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :feat]))
    cfg = GNNConfig("sgc", feat, g.num_classes, k=2, hidden=32,
                    mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2,
                    batch_size=16 if smoke else 32)
    return g, cfg, params, nai


def _classes(nai: NAIConfig, max_wait_s: float = 0.05) -> List[SLOClass]:
    """The ROADMAP's two tiers: ``gold`` at the full T_max (accuracy),
    ``best_effort`` at T_max = T_min (cheapest compiled shape). Budgets
    and waits here are provisional — the open-loop pass re-derives them
    from calibrated batch times."""
    # 2 batches of bounded queueing: deep enough to ride out a burst,
    # shallow enough that an accepted request's queueing delay stays
    # well inside the deadline budget under overload
    qd = 2 * nai.batch_size
    return [
        SLOClass("gold", nai, deadline_s=1.0, max_wait_s=max_wait_s,
                 queue_depth=qd),
        SLOClass("best_effort",
                 dataclasses.replace(nai, t_max=nai.t_min),
                 deadline_s=1.0, max_wait_s=max_wait_s, queue_depth=qd),
    ]


def _frontend(g, cfg, params, classes, depth: int) -> ServingFrontend:
    return ServingFrontend(cfg, params, g, classes, mode="compiled",
                           spmm_impl=IMPL, pipeline_depth=depth)


# ------------------------------------------------- virtual-time trace
def _trace(g, nai, n_bursts: int, seed: int = 0):
    """Deterministic bursty arrivals: (virtual_time, class, node) tuples.
    Bursts bigger than batch_size close batches on size; the lull after
    each burst (longer than max_wait) ages the remainder out — both
    former triggers fire on every replay, and partial batches visit the
    smaller buckets."""
    rng = np.random.default_rng(seed)
    events: List[Tuple[float, str, int]] = []
    t = 0.0
    for _ in range(n_bursts):
        size = int(rng.integers(nai.batch_size // 2,
                                2 * nai.batch_size + 1))
        nodes = rng.choice(g.test_idx, size=size, replace=True)
        for nid in nodes:
            cls = "gold" if rng.random() < 0.5 else "best_effort"
            events.append((t, cls, int(nid)))
            t += 1e-4
        t += 0.2              # lull >> max_wait: age out the stragglers
    return events


def _replay_virtual(fe: ServingFrontend, events) -> List:
    """Drive the front-end on the virtual clock; returns the submitted
    `Request` objects (all completed — the trace ends with a drain)."""
    reqs = []
    for t, cls, nid in events:
        r = fe.submit(nid, cls, now=t)
        assert r is not None, "virtual trace must not overflow the lanes"
        reqs.append(r)
        fe.step(now=t)
    t_end = events[-1][0] + 10.0
    fe.step(now=t_end)        # age the final stragglers out
    fe.flush()
    return reqs


def _direct_replay(g, cfg, params, classes, reqs) -> bool:
    """Regroup the front-end's completions into the exact engine batches
    it formed (`Request.batch_id`) and replay them through fresh direct
    engines — same class configs, no front-end. Bit-identical
    predictions and exit orders mean the front-end added routing, not
    numerics."""
    by_cls = {c.name: c for c in classes}
    groups: Dict[Tuple[str, int], List] = defaultdict(list)
    for r in reqs:
        groups[(r.slo_class, r.batch_id)].append(r)
    ok = True
    for name, c in by_cls.items():
        eng = NAIServingEngine(cfg, c.nai, params, g, max_wait_s=10.0,
                               mode="compiled", spmm_impl=IMPL)
        batches = sorted(k for k in groups if k[0] == name)
        for key in batches:
            orig = groups[key]
            eng.submit([r.node_id for r in orig])
            replay = eng.step()        # depth 1: completes immediately
            for a, b in zip(orig, replay):
                if (a.node_id != b.node_id
                        or a.prediction != b.prediction
                        or a.exit_order != b.exit_order):
                    ok = False
    return ok


def _virtual_pass(g, cfg, params, nai, smoke: bool) -> Dict:
    classes = _classes(nai)
    events = _trace(g, nai, n_bursts=4 if smoke else 8)
    serial = _frontend(g, cfg, params, classes, depth=1)
    piped = _frontend(g, cfg, params, classes, depth=2)
    runs = {}
    for tag, fe in (("serial", serial), ("pipelined", piped)):
        # warm replays: run 1 grows the bucket high-water marks (same
        # trace ever after -> same supports -> HWMs are fixed), the rest
        # converge the rotating pack pool — pipeline_depth + 1 slots per
        # bucket, so deeper pipelines need more replays to touch them all
        for _ in range(fe.pipeline_depth + 2):
            _replay_virtual(fe, events)
        base = {n: (e.jit_stats["compiles"], e.pack_stats["allocs"])
                for n, e in fe.engines.items()}
        reqs = _replay_virtual(fe, events)          # counted replay
        runs[tag] = (fe, base, reqs)
    fe_p, base_p, reqs_p = runs["pipelined"]
    _, _, reqs_s = runs["serial"]
    par_depth = all(
        a.node_id == b.node_id and a.prediction == b.prediction
        and a.exit_order == b.exit_order
        for a, b in zip(reqs_s, reqs_p))
    par_direct = _direct_replay(g, cfg, params, classes, reqs_p)
    steady = {
        tag: {n: {"steady_compiles": e.jit_stats["compiles"] - b[n][0],
                  "steady_pack_allocs": e.pack_stats["allocs"] - b[n][1]}
              for n, e in fe.engines.items()}
        for tag, (fe, b, _) in runs.items()}
    return {
        "trace_requests": len(events),
        "trace_batches": len({(r.slo_class, r.batch_id) for r in reqs_p}),
        "parity_pipelined_vs_serial": bool(par_depth),
        "parity_frontend_vs_direct": bool(par_direct),
        "steady": steady,
    }


# -------------------------------------------------- open-loop goodput
def _warm_engine(eng, g, batch_size: int, rng) -> None:
    """Push every bucket high-water mark to its plateau before timing:
    random node sets grow the support-size HWMs batch by batch, so a
    fixed warm-up count leaves compile stalls inside the timed open-loop
    runs (and one 100 ms compile amid 2 ms batches distorts a whole
    level's goodput). Batches of the HIGHEST-degree test nodes pin the
    support-size tail deterministically; random rounds then repeat until
    a full round neither compiles nor allocates."""
    heavy = np.asarray(g.test_idx)[
        np.argsort(g.degrees[g.test_idx])[::-1]]
    for s in range(8, batch_size + 1, 8):
        for rep in range(eng.pipeline_depth + 2):
            eng.submit(heavy[rep * s:(rep + 1) * s])
            eng.step()
    eng.flush()
    for _ in range(10):
        c0, a0 = eng.jit_stats["compiles"], eng.pack_stats["allocs"]
        for s in range(8, batch_size + 1, 8):
            for _ in range(eng.pipeline_depth + 2):
                eng.submit(rng.choice(g.test_idx, size=s, replace=True))
                eng.step()
        eng.flush()
        if eng.jit_stats["compiles"] == c0 \
                and eng.pack_stats["allocs"] == a0:
            return


def _calibrate(engines: Dict[str, NAIServingEngine], g,
               batch_size: int) -> Dict[str, float]:
    """Per-class full-batch service time, measured closed-loop on the
    ALREADY-WARM front-end engines that will serve the open-loop runs."""
    out = {}
    rng = np.random.default_rng(1)
    for name, eng in engines.items():
        times = []
        for _ in range(7):
            nodes = rng.choice(g.test_idx, size=batch_size, replace=False)
            t0 = time.perf_counter()
            eng.submit(nodes)
            eng.step()
            eng.flush()
            times.append(time.perf_counter() - t0)
        out[name] = float(np.median(times))
    return out


def _tuned_classes(nai, t_batch: Dict[str, float]) -> List[SLOClass]:
    """Re-derive waits and budgets from calibrated batch times: a class
    waits up to ~2 batch times to fill, and its deadline budget covers
    the wait plus a few services' worth of queueing — tight enough that
    an unbounded queue would blow it, loose enough that the bounded
    queue keeps accepted requests inside it."""
    out = []
    for c in _classes(nai):
        tb = t_batch[c.name]
        wait = max(2.0 * tb, 1e-3)
        # the budget covers the age wait plus the bounded queue's drain
        # time with headroom for two effects the calibration can't see:
        # both class engines contend for the same cores (~2x per-batch
        # latency when both are busy) and a depth-2 pipeline holds one
        # extra batch in flight — the budget must sit clear of the
        # overload latency cliff, so goodput measures service rate, not
        # which side of the cliff the noise landed on
        out.append(dataclasses.replace(
            c, max_wait_s=wait, deadline_s=wait + 16.0 * tb))
    return out


def _poisson_events(g, rates: Dict[str, float], duration: float, seed: int):
    """Merged per-class Poisson arrivals: (t, class, node), time-sorted."""
    rng = np.random.default_rng(seed)
    events = []
    for cls, rate in rates.items():
        t = rng.exponential(1.0 / rate)
        while t < duration:
            events.append((t, cls, int(rng.choice(g.test_idx))))
            t += rng.exponential(1.0 / rate)
    events.sort()
    return events


def _open_loop_run(fe: ServingFrontend, events, duration: float) -> Dict:
    """Offer the trace in real time (open loop: arrivals don't wait for
    the server), then drain. Each request's arrival — and therefore its
    deadline and measured latency — is stamped at the trace's INTENDED
    event time, not when the submit loop got to it, so a busy server
    can't launder its own queueing delay (coordinated omission)."""
    fe.reset_stats()
    start = time.perf_counter()
    i = 0
    deadline_guard = start + duration + 30.0
    while True:
        now = time.perf_counter()
        while i < len(events) and events[i][0] <= now - start:
            t_ev, cls, nid = events[i]
            fe.submit(nid, cls, now=start + t_ev)
            i += 1
        fe.step()
        if i >= len(events) and fe.pending() == 0:
            break
        if now > deadline_guard:      # wedged run: report what completed
            fe.flush()
            break
    wall = time.perf_counter() - start
    return {"wall_s": round(wall, 3), "classes": fe.summary()}


def _class_row(s: Dict) -> Dict:
    return {"offered": s["offered"], "accepted": s["accepted"],
            "rejected": s["rejected"], "completed": s["completed"],
            "deadline_hits": s["deadline_hits"],
            "deadline_misses": s["deadline_misses"],
            "goodput_frac": round(s["goodput_frac"], 4),
            "p50_ms": round(s["p50_ms"], 3),
            "p99_ms": round(s["p99_ms"], 3)}


def _open_loop_pass(g, cfg, params, nai, smoke: bool) -> Dict:
    # build both front-ends first, warm every engine to compile
    # quiescence, and only then calibrate — capacity estimated on a
    # still-compiling engine is fiction, and the timed runs must see
    # zero compile stalls
    frontends = {d: _frontend(g, cfg, params, _classes(nai), depth=d)
                 for d in (1, 2)}
    rng = np.random.default_rng(2)
    for fe in frontends.values():
        for eng in fe.engines.values():
            _warm_engine(eng, g, nai.batch_size, rng)
    t_batch = _calibrate(frontends[1].engines, g, nai.batch_size)
    classes = _tuned_classes(nai, t_batch)
    # SLOClass is frozen; swap the tuned tiers into the live front-ends
    # (budgets are read per submit, max_wait lives on the engine)
    for fe in frontends.values():
        for c in classes:
            fe.classes[c.name] = c
            fe.engines[c.name].max_wait_s = c.max_wait_s
    capacity = {c.name: nai.batch_size / t_batch[c.name] for c in classes}

    # split the offered load evenly across classes: both engines share
    # the same cores, so "1.0" means the MACHINE is at estimated capacity
    def rates_for(frac):
        return {n: max(frac * cap / 2.0, 1.0)
                for n, cap in capacity.items()}

    duration = 0.4 if smoke else 1.5
    load_fracs = (0.5, 1.0, 2.0)
    # best of 2 rounds at every level: a stray compile (an unlucky node
    # set past the warmed HWM tail) or scheduler hiccup wrecks one round,
    # not the level
    rounds = 2
    loads = []
    for frac in load_fracs:
        rates = rates_for(frac)
        events = _poisson_events(g, rates, duration, seed=int(10 * frac))
        per_cfg = {}
        # the highest level carries the serial-vs-pipelined record —
        # give it one extra round
        n_rounds = rounds + 1 if frac == load_fracs[-1] else rounds
        for tag, depth in (("serial", 1), ("pipelined", 2)):
            best = None
            for _ in range(n_rounds):
                res = _open_loop_run(frontends[depth], events, duration)
                good = sum(c["deadline_hits"]
                           for c in res["classes"].values())
                if best is None or good > best[0]:
                    best = (good, res)
            per_cfg[tag] = {
                "wall_s": best[1]["wall_s"],
                "classes": {n: _class_row(s)
                            for n, s in best[1]["classes"].items()}}
        loads.append({
            "load_frac": frac,
            "offered_req_per_s": {n: round(r, 1)
                                  for n, r in rates.items()},
            **per_cfg})
    top = loads[-1]
    good = {tag: sum(c["deadline_hits"]
                     for c in top[tag]["classes"].values())
            for tag in ("serial", "pipelined")}
    return {
        "impl": IMPL,
        "duration_s": duration,
        "batch_service_s": {n: round(t, 5) for n, t in t_batch.items()},
        "capacity_req_per_s": {n: round(c, 1)
                               for n, c in capacity.items()},
        "classes": {c.name: {
            "t_max": c.nai.t_max, "batch_size": c.nai.batch_size,
            "max_wait_ms": round(1e3 * c.max_wait_s, 2),
            "deadline_ms": round(1e3 * c.deadline_s, 2),
            "queue_depth": c.queue_depth} for c in classes},
        "loads": loads,
        "highest_load_comparison": {
            "load_frac": top["load_frac"],
            "serial_goodput": good["serial"],
            "pipelined_goodput": good["pipelined"],
            "pipelined_ge_serial": good["pipelined"] >= good["serial"],
        },
    }


def collect(smoke: bool = False) -> Dict:
    g, cfg, params, nai = _setup(smoke)
    return {
        "bench": "frontend_bench",
        "smoke": bool(smoke),
        "unix_time": time.time(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "shape": {"batch_size": nai.batch_size,
                  "feat": int(g.features.shape[1]),
                  "n": g.n, "impl": IMPL},
        "structural": _virtual_pass(g, cfg, params, nai, smoke),
        "open_loop": _open_loop_pass(g, cfg, params, nai, smoke),
    }


def check(payload: Dict) -> List[str]:
    """CI gates. Structural (virtual-time, deterministic): both parities
    and zero steady-state compiles/allocs per class per depth. Open loop
    (real time): every class must record nonzero goodput somewhere —
    machine-speed-proof, unlike the load-curve shapes."""
    errs = []
    st = payload["structural"]
    if not st["parity_pipelined_vs_serial"]:
        errs.append("pipelined front-end diverged from serial on the "
                    "virtual trace (predictions/exit orders)")
    if not st["parity_frontend_vs_direct"]:
        errs.append("front-end-served predictions diverged from direct "
                    "engine serving of the same batches")
    for tag, per_cls in st["steady"].items():
        for name, c in per_cls.items():
            if c["steady_compiles"] > 0:
                errs.append(f"{tag}/{name}: {c['steady_compiles']} jit "
                            f"compiles in steady state")
            if c["steady_pack_allocs"] > 0:
                errs.append(f"{tag}/{name}: {c['steady_pack_allocs']} "
                            f"bucket-sized pack allocations in steady "
                            f"state")
    hits = defaultdict(int)
    for load in payload["open_loop"]["loads"]:
        for tag in ("serial", "pipelined"):
            for name, c in load[tag]["classes"].items():
                hits[(tag, name)] += c["deadline_hits"]
    for (tag, name), h in sorted(hits.items()):
        if h == 0:
            errs.append(f"open_loop/{tag}/{name}: zero goodput across "
                        f"every load level")
    return errs


def _rows(payload: Dict) -> List[str]:
    rows = []
    for load in payload["open_loop"]["loads"]:
        for tag in ("serial", "pipelined"):
            for name, c in load[tag]["classes"].items():
                rname = (f"frontend/{tag}/{name}/"
                         f"load{load['load_frac']}")
                us = 1e3 * c["p99_ms"]
                derived = (
                    f"goodput_frac={c['goodput_frac']};"
                    f"offered={c['offered']};rejected={c['rejected']};"
                    f"deadline_hits={c['deadline_hits']};"
                    f"p50_ms={c['p50_ms']};p99_ms={c['p99_ms']}")
                rows.append(csv_row(rname, us, derived))
    st = payload["structural"]
    rows.append(csv_row(
        "frontend/structural", 0.0,
        f"parity_direct={st['parity_frontend_vs_direct']};"
        f"parity_depth={st['parity_pipelined_vs_serial']};"
        f"trace_requests={st['trace_requests']};"
        f"trace_batches={st['trace_batches']}"))
    return rows


def run() -> list:
    return _rows(collect(smoke=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / short runs (CI smoke job)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on a parity/steady-state/goodput "
                         "gate failure")
    ap.add_argument("--out", default="",
                    help="JSON output path (default: merge under the "
                         "'frontend' key of BENCH_serving.json; with "
                         "--smoke, standalone BENCH_frontend_smoke.json)")
    args = ap.parse_args()
    payload = collect(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in _rows(payload):
        print(r, flush=True)
    if args.out:
        out_path, merge = args.out, args.out == "BENCH_serving.json"
    elif args.smoke:
        out_path, merge = "BENCH_frontend_smoke.json", False
    else:
        out_path, merge = "BENCH_serving.json", True
    write_bench_json(out_path, payload,
                     section="frontend" if merge else None)
    cmp_ = payload["open_loop"]["highest_load_comparison"]
    if not cmp_["pipelined_ge_serial"]:
        # timing-dependent, so advisory (a contended runner can flip it);
        # the committed full-size record is the claim
        print(f"WARNING: pipelined goodput < serial at load "
              f"{cmp_['load_frac']} ({cmp_['pipelined_goodput']} vs "
              f"{cmp_['serial_goodput']}) — noise on this run?",
              file=sys.stderr)
    if args.check:
        errs = check(payload)
        for e in errs:
            print(f"GATE FAILURE: {e}", file=sys.stderr)
        if errs:
            sys.exit(1)
        print("# frontend gates OK (parity, 0 steady compiles/allocs, "
              "goodput > 0 per class)")


if __name__ == "__main__":
    main()
