"""Deadline-aware serving front-end (PR 6): SLO-class routing into
per-class engines, bounded-lane backpressure, the fixed batch former
(close on size OR age, unconditionally), goodput accounting, and the
bit-parity invariants — front-end == direct engine serving, pipelined ==
serial — on a deterministic virtual-clock request stream."""
import dataclasses
from collections import defaultdict

import jax
import numpy as np
import pytest

from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.serving import (NAIServingEngine, ServingFrontend, SLOClass,
                           default_slo_classes)


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("pubmed-like", scale=0.02, seed=4)
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=8)
    return g, cfg, params, nai


def _two_classes(nai, queue_depth=64):
    return [
        SLOClass("gold", nai, deadline_s=10.0, max_wait_s=0.02,
                 queue_depth=queue_depth),
        SLOClass("best_effort", dataclasses.replace(nai, t_max=nai.t_min),
                 deadline_s=10.0, max_wait_s=0.01,
                 queue_depth=queue_depth),
    ]


def _bursty_events(g, nai, n_bursts=5, seed=0):
    """Deterministic virtual-time arrivals: bursts bigger than a batch
    (size closes) separated by lulls longer than max_wait (age closes)."""
    rng = np.random.default_rng(seed)
    events, t = [], 0.0
    for _ in range(n_bursts):
        size = int(rng.integers(3, 2 * nai.batch_size + 1))
        for nid in rng.choice(g.test_idx, size=size, replace=True):
            cls = "gold" if rng.random() < 0.5 else "best_effort"
            events.append((t, cls, int(nid)))
            t += 1e-4
        t += 1.0
    return events


def _replay(fe, events):
    reqs = []
    for t, cls, nid in events:
        r = fe.submit(nid, cls, now=t)
        assert r is not None
        reqs.append(r)
        fe.step(now=t)
    fe.step(now=events[-1][0] + 100.0)   # age out the final stragglers
    fe.flush()
    return reqs


# -------------------------------------------------- NAIConfig validation
def test_nai_config_validation():
    """The front-end builds per-class configs programmatically, so a
    nonsensical combination must fail at construction — not serve -1
    predictions or never-exiting loops in production."""
    NAIConfig(t_s=1.0, t_min=1, t_max=2, batch_size=4)   # valid
    with pytest.raises(ValueError, match="t_min"):
        NAIConfig(t_s=1.0, t_min=0, t_max=2, batch_size=4)
    with pytest.raises(ValueError, match="t_min"):
        NAIConfig(t_s=1.0, t_min=3, t_max=2, batch_size=4)
    with pytest.raises(ValueError, match="t_s"):
        NAIConfig(t_s=-0.5, t_min=1, t_max=2, batch_size=4)
    with pytest.raises(ValueError, match="batch_size"):
        NAIConfig(t_s=1.0, t_min=1, t_max=2, batch_size=0)


def test_slo_class_validation(setup):
    nai = setup[3]
    with pytest.raises(ValueError):
        SLOClass("", nai, deadline_s=1.0, max_wait_s=0.01)
    with pytest.raises(ValueError):
        SLOClass("x", nai, deadline_s=0.0, max_wait_s=0.01)
    with pytest.raises(ValueError):
        SLOClass("x", nai, deadline_s=1.0, max_wait_s=-1.0)
    with pytest.raises(ValueError):
        SLOClass("x", nai, deadline_s=1.0, max_wait_s=0.01, queue_depth=0)


def test_default_slo_classes_tiers(setup):
    nai = setup[3]
    gold, be = default_slo_classes(nai)
    assert gold.nai.t_max == nai.t_max          # accuracy tier
    assert be.nai.t_max == nai.t_min            # cheapest compiled shape
    assert be.deadline_s < gold.deadline_s


# -------------------------------------------------------- batch former
def test_form_batch_waits_young_partial(setup):
    g, cfg, params, nai = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=0.05)
    eng.submit([1, 2, 3], now=100.0)
    assert eng.form_batch(now=100.01) == []      # young partial: wait
    assert len(eng.queue) == 3


def test_form_batch_closes_on_size(setup):
    g, cfg, params, nai = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=1e9)
    eng.submit(np.arange(nai.batch_size + 3), now=100.0)
    batch = eng.form_batch(now=100.0)            # full: close immediately
    assert len(batch) == nai.batch_size
    assert len(eng.queue) == 3


@pytest.mark.parametrize("queued", [1, 2, 3, 5])
def test_form_batch_aged_takes_everything(setup, queued):
    """The deadline-inversion fix: once the oldest request has aged past
    max_wait the batch closes UNCONDITIONALLY with everything queued —
    no minimum-fill guard, no degeneration to size-1 batches (the old
    former required batch_size // 4 post-deadline fill, which held
    batches hostage and collapsed to singletons for batch_size <= 3)."""
    g, cfg, params, nai = setup
    small = dataclasses.replace(nai, batch_size=3)
    eng = NAIServingEngine(cfg, small, params, g, max_wait_s=0.05)
    eng.submit(np.arange(queued), now=100.0)
    if queued < small.batch_size:
        assert eng.form_batch(now=100.01) == []  # young partial: wait
    batch = eng.form_batch(now=100.06)           # aged: close it all
    assert len(batch) == min(queued, small.batch_size)
    assert len(eng.queue) == max(0, queued - small.batch_size)


def test_form_batch_force_and_empty(setup):
    g, cfg, params, nai = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=1e9)
    assert eng.form_batch(force=True) == []
    eng.submit([7], now=100.0)
    batch = eng.form_batch(force=True)           # closed-loop path
    assert [r.node_id for r in batch] == [7]


# ------------------------------------------------- routing/backpressure
def test_routing_and_backpressure(setup):
    g, cfg, params, nai = setup
    fe = ServingFrontend(cfg, params, g, _two_classes(nai, queue_depth=5),
                         mode="host")
    for i in range(8):
        fe.submit(int(g.test_idx[i]), "gold", now=0.0)
    st = fe.stats["gold"]
    assert (st.offered, st.accepted, st.rejected) == (8, 5, 3)
    assert len(fe.engines["gold"].queue) == 5
    assert len(fe.engines["best_effort"].queue) == 0
    assert fe.stats["best_effort"].offered == 0
    with pytest.raises(KeyError):
        fe.submit(0, "platinum", now=0.0)
    fe.flush()                                   # free the gold lane
    # default class is the first in the sequence
    r = fe.submit(int(g.test_idx[0]), now=0.0)
    assert r.slo_class == "gold"


def test_frontend_requires_classes(setup):
    g, cfg, params, nai = setup
    with pytest.raises(ValueError):
        ServingFrontend(cfg, params, g, [], mode="host")
    with pytest.raises(ValueError):
        ServingFrontend(cfg, params, g,
                        _two_classes(nai) + _two_classes(nai),
                        mode="host")


# ------------------------------------------------------ parity + steady
def test_pipelined_matches_serial_with_zero_steady_state(setup):
    """The tentpole invariants on one bursty virtual-clock stream: a
    depth-2 front-end serves bit-identically to a depth-1 front-end,
    and after warm-up a replay of the same stream compiles nothing and
    allocates no bucket-sized pack buffers in either class engine."""
    g, cfg, params, nai = setup
    events = _bursty_events(g, nai)
    results = {}
    for depth in (1, 2):
        fe = ServingFrontend(cfg, params, g, _two_classes(nai),
                             mode="compiled", spmm_impl="segment",
                             pipeline_depth=depth)
        for _ in range(depth + 2):               # warm HWMs + pack pool
            _replay(fe, events)
        base = {n: (e.jit_stats["compiles"], e.pack_stats["allocs"])
                for n, e in fe.engines.items()}
        reqs = _replay(fe, events)
        assert all(r.prediction >= 0 for r in reqs)
        for name, eng in fe.engines.items():
            assert eng.jit_stats["compiles"] == base[name][0], name
            assert eng.pack_stats["allocs"] == base[name][1], name
        results[depth] = reqs
    for a, b in zip(results[1], results[2]):
        assert (a.node_id, a.slo_class) == (b.node_id, b.slo_class)
        assert a.prediction == b.prediction
        assert a.exit_order == b.exit_order


def test_frontend_matches_direct_engine(setup):
    """Front-end-served predictions are bit-identical to replaying the
    same batches (regrouped via Request.batch_id) through direct
    engines: the front-end adds routing and deadlines, never numerics."""
    g, cfg, params, nai = setup
    classes = _two_classes(nai)
    fe = ServingFrontend(cfg, params, g, classes, mode="compiled",
                         spmm_impl="segment", pipeline_depth=2)
    reqs = _replay(fe, _bursty_events(g, nai, seed=3))
    groups = defaultdict(list)
    for r in reqs:
        assert r.batch_id >= 0
        groups[(r.slo_class, r.batch_id)].append(r)
    for c in classes:
        eng = NAIServingEngine(cfg, c.nai, params, g, max_wait_s=10.0,
                               mode="compiled", spmm_impl="segment")
        for key in sorted(k for k in groups if k[0] == c.name):
            orig = groups[key]
            eng.submit([r.node_id for r in orig])
            replay = eng.step()
            assert len(replay) == len(orig)
            for a, b in zip(orig, replay):
                assert a.node_id == b.node_id
                assert a.prediction == b.prediction
                assert a.exit_order == b.exit_order


# ------------------------------------------------------------- goodput
def test_goodput_accounting(setup):
    """Real-clock run: a generous budget lands inside the deadline, a
    zero budget cannot — and both are counted in the right bucket."""
    g, cfg, params, nai = setup
    fe = ServingFrontend(cfg, params, g, _two_classes(nai), mode="host")
    hit = fe.submit(int(g.test_idx[0]), "gold", budget_s=1e6)
    miss = fe.submit(int(g.test_idx[1]), "gold", budget_s=0.0)
    fe.flush()                         # drain the partial batch
    assert hit.within_deadline
    assert not miss.within_deadline
    st = fe.stats["gold"]
    assert st.completed == 2
    assert st.deadline_hits == 1
    assert st.deadline_misses == 1
    s = fe.summary()["gold"]
    assert s["goodput_frac"] == pytest.approx(0.5)
    assert s["batches"] >= 1


def test_pending_and_reset(setup):
    g, cfg, params, nai = setup
    fe = ServingFrontend(cfg, params, g, _two_classes(nai), mode="host")
    fe.submit(int(g.test_idx[0]), "gold", now=0.0)
    fe.submit(int(g.test_idx[1]), "best_effort", now=0.0)
    assert fe.pending() == 2
    fe.flush()
    assert fe.pending() == 0
    fe.reset_stats()
    assert fe.stats["gold"].completed == 0
    assert fe.summary()["gold"]["batches"] == 0


# -------------------------------------------------------- conservation
def _conservation_run(setup, seed, n_bursts, queue_depth):
    """Drive bursty overload through a small-laned front-end and check
    the request ledger balances: offered == rejected + completed +
    failed per class, every accepted request terminal exactly once."""
    g, cfg, params, nai = setup
    fe = ServingFrontend(cfg, params, g,
                         _two_classes(nai, queue_depth=queue_depth),
                         mode="host")
    events = _bursty_events(g, nai, n_bursts=n_bursts, seed=seed)
    accepted, terminal = [], []
    for t, cls, nid in events:
        r = fe.submit(nid, cls, now=t, budget_s=1e9)
        if r is not None:
            accepted.append(r)
        terminal += fe.step(now=t)
    terminal += fe.step(now=events[-1][0] + 100.0)
    terminal += fe.flush()
    ids = [id(r) for r in terminal]
    assert len(ids) == len(set(ids)), "a request terminated twice"
    assert set(ids) == set(id(r) for r in accepted), \
        "lost or phantom requests"
    assert fe.pending() == 0
    assert all(r.status in ("completed", "failed") for r in accepted)
    for name, st in fe.stats.items():
        assert st.offered == st.accepted + st.rejected, name
        assert st.accepted == st.completed + st.failed, name
        # submitted == completed + shed (+ failed, zero on clean paths)
        assert st.offered == st.completed + st.rejected + st.failed, name
        assert st.failed == 0
    assert sum(st.rejected for st in fe.stats.values()) > 0, \
        "overload never shed — the property needs backpressure hits"
    return fe


def test_conservation_under_bursty_overload(setup):
    """Deterministic slice of the hypothesis property below — runs even
    where hypothesis is unavailable."""
    fe = _conservation_run(setup, seed=0, n_bursts=8, queue_depth=4)
    # reset_stats starts a fresh ledger that must balance on its own
    fe.reset_stats()
    g, _, _, nai = setup
    for i, nid in enumerate(g.test_idx[:10]):
        fe.submit(int(nid), "gold", now=1000.0 + i * 1e-4, budget_s=1e9)
    fe.step(now=2000.0)
    fe.flush()
    st = fe.stats["gold"]
    assert st.offered == 10
    assert st.offered == st.completed + st.rejected + st.failed
    assert fe.pending() == 0


def test_conservation_property(setup):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), n_bursts=st.integers(2, 10),
           queue_depth=st.integers(1, 12))
    def prop(seed, n_bursts, queue_depth):
        g, cfg, params, nai = setup
        fe = ServingFrontend(cfg, params, g,
                             _two_classes(nai, queue_depth=queue_depth),
                             mode="host")
        events = _bursty_events(g, nai, n_bursts=n_bursts, seed=seed)
        accepted, terminal = [], []
        for t, cls, nid in events:
            r = fe.submit(nid, cls, now=t, budget_s=1e9)
            if r is not None:
                accepted.append(r)
            terminal += fe.step(now=t)
        terminal += fe.step(now=events[-1][0] + 100.0)
        terminal += fe.flush()
        assert len(terminal) == len(accepted)
        assert set(map(id, terminal)) == set(map(id, accepted))
        assert fe.pending() == 0
        for name, s in fe.stats.items():
            assert s.offered == s.completed + s.rejected + s.failed

    prop()
