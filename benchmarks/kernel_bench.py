"""Kernel micro-benchmarks: interpret-mode timings are NOT TPU performance
(CPU emulation); the derived columns report the structural quantities that
matter on TPU — tiles touched vs skipped (NAP predication saving), VMEM
working set per BlockSpec, and arithmetic intensity.

The `kernels/nap_step/*` section times one full NAP propagation step —
SpMM + exit decision — under all three `spmm_impl` choices side by side:

* ``segment``    — jnp segment-sum + jnp distance reduction;
* ``two_launch`` — Pallas `spmm_block_ell` then `nap_exit` (the propagated
  features round-trip through HBM between the launches);
* ``fused``      — the fused `nap_step` kernel, one grid pass.

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke] [--out F]

which also records the rows to a ``BENCH_*.json`` so the perf trajectory
accumulates across commits (CI uploads the smoke variant as an artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Tuple

if __package__ in (None, ""):      # `python benchmarks/kernel_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_root, os.path.join(_root, "src")]

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.gnn import load_dataset
from repro.gnn.packing import pack_support, step_active_blocks
from repro.gnn.sampler import sample_support
from repro.gnn.store import as_store
from repro.kernels.nap_step import fused_step, two_launch_step
from repro.kernels.spmm import (CB, FB, RB, build_block_ell, pad_features,
                                spmm, spmm_block_ell)

Row = Tuple[str, float, str]


def _time_us(fn, iters: int) -> float:
    """Min wall time over `iters` calls (after one warmup), microseconds."""
    out = fn()
    jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a, out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        jax.tree_util.tree_map(
            lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
            else a, out)
        best = min(best, time.perf_counter() - t0)
    return 1e6 * best


def _random_graph(rng, n: int, deg: int):
    E = n * deg
    src = np.concatenate([rng.integers(0, n, E),
                          np.arange(n)]).astype(np.int32)
    dst = np.concatenate([rng.integers(0, n, E),
                          np.arange(n)]).astype(np.int32)
    key = dst.astype(np.int64) * n + src
    uk = np.unique(key)
    dst, src = (uk // n).astype(np.int32), (uk % n).astype(np.int32)
    coef = rng.random(len(src)).astype(np.float32)
    return src, dst, coef


def _spmm_micro_rows(rng, smoke: bool) -> List[Row]:
    rows: List[Row] = []
    n, deg, f = (256, 4, 128) if smoke else (1024, 8, 256)
    src, dst, coef = _random_graph(rng, n, deg)
    ell = build_block_ell(src, dst, coef, n)
    x = jnp.asarray(pad_features(rng.standard_normal((n, f)), ell.n_pad))
    n_rb = ell.tile_col.shape[0]

    for frac in (1.0, 0.5, 0.1):
        active = jnp.asarray((rng.random(n_rb) < frac).astype(np.int32))
        dt = _time_us(lambda: spmm(ell, x, active, interpret=True),
                      iters=2 if smoke else 3)
        tiles_total = int(ell.valid.sum())
        tiles_live = int(ell.valid[np.asarray(active) != 0].sum())
        vmem_kb = (RB * CB + CB * FB + RB * FB) * 4 / 1024
        ai = (2 * RB * CB * FB) / ((RB * CB + CB * FB + RB * FB) * 4)
        rows.append((
            f"kernels/spmm/active={frac}", dt,
            f"tiles_live={tiles_live}/{tiles_total};"
            f"predicated_saving={1 - tiles_live / tiles_total:.2f};"
            f"vmem_per_step_kb={vmem_kb:.0f};arith_intensity={ai:.1f}"))
    return rows


def _nap_step_rows(rng, smoke: bool) -> List[Row]:
    """One NAP propagation step (SpMM + exit decision) under the three
    spmm_impl choices on identical serving-shaped operands, each a single
    jitted call. The quantity the fusion targets is per-step latency:
    two_launch pays a second kernel launch plus a full (n_pad, F_pad) HBM
    round trip of the propagated features between the SpMM and the
    distance check (and materializes the dense (nb, F_pad) stationary
    state); fused pays none of those — it streams the rank-1 x_inf
    factors. Timings are averages over interleaved rounds (impls
    alternate within each round, so machine drift hits all three
    equally). Interpret-mode wall clock is CPU emulation (it models
    neither HBM nor launch overlap), so the structural columns —
    launches and exit-check operand bytes per step — carry the
    TPU-relevant signal alongside the timing."""
    rows: List[Row] = []
    n, deg, f, nb = (240, 5, 128, 64)       # engine-realistic support
    rounds = 10 if smoke else 50
    src, dst, coef = _random_graph(rng, n, deg)
    ell = build_block_ell(src, dst, coef, n)
    x = jnp.asarray(pad_features(rng.standard_normal((n, f)), ell.n_pad))
    f_pad = x.shape[1]
    c_inf = jnp.asarray(rng.random(nb).astype(np.float32) * 0.1)
    s_inf = jnp.asarray(np.pad(
        rng.standard_normal(f).astype(np.float32), (0, f_pad - f)))
    x_inf = c_inf[:, None] * s_inf[None, :]
    n_rb = ell.tile_col.shape[0]
    active = jnp.ones((n_rb,), jnp.int32)
    nact = jnp.ones((nb, 1), jnp.int32)
    t_s = float(np.sqrt(f))
    tiles = jnp.asarray(ell.tiles)
    tile_col = jnp.asarray(ell.tile_col)
    valid = jnp.asarray(ell.valid)
    sj = jnp.asarray(src)
    dj = jnp.asarray(dst)
    cj = jnp.asarray(coef)
    n_pad = ell.n_pad

    def segment_impl(x):
        out = jax.ops.segment_sum(cj[:, None] * x[sj], dj,
                                  num_segments=n_pad)
        d2 = jnp.sum((out[:nb] - x_inf) ** 2, axis=1, keepdims=True)
        exits = ((nact != 0) & (d2 < t_s * t_s)).astype(jnp.int32)
        blk = exits.reshape(-1, RB).min(axis=1)
        return out, exits, blk

    def two_launch_impl(x):
        return two_launch_step(tiles, tile_col, valid, active, x, c_inf,
                               s_inf, nact, t_s, interpret=True)

    def fused_impl(x):
        return fused_step(tiles, tile_col, valid, active, x, c_inf,
                          s_inf, nact, t_s, interpret=True)

    impls = {"segment": jax.jit(segment_impl),
             "two_launch": jax.jit(two_launch_impl),
             "fused": jax.jit(fused_impl)}

    def timed(fn):
        t0 = time.perf_counter()
        out = fn(x)
        jax.tree_util.tree_map(lambda a: a.block_until_ready(), out)
        return time.perf_counter() - t0

    for fn in impls.values():       # compile + warm
        timed(fn)
        timed(fn)
    total = {name: 0.0 for name in impls}
    for _ in range(rounds):
        for name, fn in impls.items():
            total[name] += timed(fn)
    us = {name: 1e6 * t / rounds for name, t in total.items()}
    # exit-check operand HBM bytes per step on TPU: two_launch re-reads
    # the propagated batch slice + the dense x_inf and re-writes dist/
    # exit/blk; fused streams only the rank-1 factors
    two_bytes = (nb * f_pad * 2 + nb * 3) * 4
    fused_bytes = (nb + f_pad + nb * 2) * 4
    shape = f"n={n};deg={deg};f={f};nb={nb};n_pad={n_pad};f_pad={f_pad}"
    for impl, dt in us.items():
        derived = shape
        if impl == "two_launch":
            derived += f";launches_per_step=2;exit_bytes={two_bytes}"
        if impl == "fused":
            derived += (
                f";launches_per_step=1;exit_bytes={fused_bytes}"
                f";speedup_vs_two_launch="
                f"{us['two_launch'] / max(dt, 1e-9):.2f}x")
        rows.append((f"kernels/nap_step/{impl}", dt, derived))
    return rows


def _support_rows(rng, smoke: bool) -> List[Row]:
    rows: List[Row] = []
    # ---- end-to-end serving operand: vectorized sample -> bucket-padded
    # pack -> kernel with the per-step hop mask (what the compiled engine
    # actually runs). Features sliced to one FB block so interpret mode
    # stays a micro-benchmark.
    g = load_dataset("pubmed-like", scale=0.01 if smoke else 0.02, seed=0)
    batch = rng.choice(g.test_idx, size=16 if smoke else 32, replace=False)
    t_max = 2
    t0 = time.perf_counter()
    sup = sample_support(as_store(g), batch, t_max, 0.5)
    sample_us = 1e6 * (time.perf_counter() - t0)
    x0 = g.features[sup.nodes][:, :FB].astype(np.float32)
    t0 = time.perf_counter()
    packed = pack_support(sup, x0,
                          np.zeros((sup.n_batch, FB), np.float32))
    pack_us = 1e6 * (time.perf_counter() - t0)
    step_act = step_active_blocks(packed.hop_rb, t_max)
    tiles_total = int(packed.valid.sum())
    rows.append((
        "kernels/spmm_support/pack", pack_us,
        f"S={packed.s_real};n_pad={packed.n_pad};"
        f"tb={packed.tiles.shape[1]};density={packed.density:.2f};"
        f"row_overshoot={packed.n_pad / max(packed.s_real, 1):.2f};"
        f"sample_us={sample_us:.0f}"))
    x = jnp.asarray(packed.x0)
    for l in range(1, t_max + 1):
        active = jnp.asarray(step_act[l - 1])
        t0 = time.perf_counter()
        x = spmm_block_ell(jnp.asarray(packed.tiles),
                           jnp.asarray(packed.tile_col),
                           jnp.asarray(packed.valid), active, x,
                           interpret=True)
        x.block_until_ready()
        dt = time.perf_counter() - t0
        live = int(packed.valid[np.asarray(step_act[l - 1]) != 0].sum())
        rows.append((
            f"kernels/spmm_support/step={l}", 1e6 * dt,
            f"tiles_live={live}/{tiles_total};"
            f"hop_mask_saving={1 - live / max(tiles_total, 1):.2f}"))
    return rows


def collect(smoke: bool = False) -> List[Row]:
    rng = np.random.default_rng(0)
    return (_spmm_micro_rows(rng, smoke) + _nap_step_rows(rng, smoke)
            + _support_rows(rng, smoke))


def run() -> list:
    return [csv_row(*r) for r in collect()]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters (CI parity smoke job)")
    ap.add_argument("--out", default="",
                    help="JSON output path (default BENCH_kernels.json, "
                         "or BENCH_smoke.json with --smoke)")
    args = ap.parse_args()
    out_path = args.out or ("BENCH_smoke.json" if args.smoke
                            else "BENCH_kernels.json")
    rows = collect(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(csv_row(*r), flush=True)
    payload = {
        "bench": "kernel_bench",
        "smoke": bool(args.smoke),
        "unix_time": time.time(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rows": [{"name": n, "us": round(us, 1), "derived": d}
                 for n, us, d in rows],
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
