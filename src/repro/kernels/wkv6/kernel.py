"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The GPU reference is a per-timestep CUDA loop (no TPU analogue); the
TPU-native form is the chunked linear-attention factorization used by
`repro.nn.rwkv._wkv_chunked`, here tiled so the (hd, hd) recurrent state
lives in VMEM scratch across the sequential chunk dimension of the grid:

    out_t = r_t · (S + u ⊙ k_t v_tᵀ + Σ_{s<t in chunk} decay(s,t) k_s v_sᵀ)
    S    <- diag(Πw) S + Σ_s decay(s, C) k_s v_sᵀ

Grid: (batch*heads, T/CHUNK) — the chunk dim iterates sequentially on TPU,
so scratch carries the state like a lax.scan carry, with no HBM round-trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16  # matches repro.nn.rwkv.CHUNK (f32-safe decay factorization)


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)        # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # (1, hd) bonus

    Lc = jnp.cumsum(lw, axis=0)               # inclusive log cumprod
    P = jnp.exp(Lc - lw)                      # prod_{s<t} w_s
    rp = r * P
    kd = k * jnp.exp(-Lc)

    C = r.shape[0]
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)
    A = jnp.dot(rp, kd.T, preferred_element_type=jnp.float32) * tri
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)          # (C, 1)
    out = jnp.dot(A, v, preferred_element_type=jnp.float32) \
        + diag * v \
        + jnp.dot(rp, state_scr[...], preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    Dtot = jnp.exp(Lc[-1:])                                   # (1, hd)
    kscale = k * jnp.exp(Lc[-1:] - Lc)
    state_scr[...] = state_scr[...] * Dtot.T \
        + jnp.dot(kscale.T, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, logw, u, *, interpret=True):
    """r/k/v/logw: (BH, T, hd) f32, T % CHUNK == 0; u: (BH, hd).
    Returns out (BH, T, hd) f32 with zero initial state."""
    BH, T, hd = r.shape
    assert T % CHUNK == 0, (T, CHUNK)
    grid = (BH, T // CHUNK)
    io_spec = pl.BlockSpec((1, CHUNK, hd), lambda b, t: (b, t, 0))
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, 1, hd), lambda b, t: (b, 0, 0))],
        out_specs=io_spec,
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )
    return fn(r, k, v, logw, u[:, None, :])
