"""mistral-large-123b — dense [hf:mistralai/Mistral-Large-Instruct-2407].
88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768."""
from repro.common import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    arch_type="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=1000000.0,
)
