"""RWKV-6 "Finch" layer (arXiv:2404.05892): time-mix with data-dependent
per-channel decay + channel-mix.

TPU adaptation: the sequential WKV recurrence is computed in CHUNKS — a
quadratic intra-chunk part (MXU-friendly matmuls) plus an inter-chunk linear
state carry via `lax.scan`. This is the standard linear-attention chunking;
the GPU reference kernel is a per-timestep CUDA loop with no TPU analogue.
Note: the ddlerp token-shift LoRA of full RWKV-6 is simplified to static
interpolation weights (documented in DESIGN.md); the data-dependent decay
(the architectural core of Finch) IS implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.params import ParamDef
from repro.sharding import constrain

CHUNK = 16
LORA_R = 32
# Per-step log-decay is clamped to >= MIN_LOGW so the intra-chunk
# factorization exp(Lc_t)·exp(-Lc_s) stays inside f32 range:
# |CHUNK * MIN_LOGW| = 80 < log(f32_max) ~ 88. A channel at the clamp
# forgets to 6.7e-3 in one step — numerically indistinguishable from the
# unclamped recurrence (documented TPU adaptation).
MIN_LOGW = -5.0


def _heads(cfg):
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    return H, hd


def rwkv_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    H, hd = _heads(cfg)
    D = H * hd
    mix = {f"mu_{n}": ParamDef((d,), ("embed",), "zeros") for n in
           ("r", "k", "v", "g", "w")}
    tmix = dict(
        mix,
        w_r=ParamDef((d, D), ("embed", "rnn")),
        w_k=ParamDef((d, D), ("embed", "rnn")),
        w_v=ParamDef((d, D), ("embed", "rnn")),
        w_g=ParamDef((d, D), ("embed", "rnn")),
        w0=ParamDef((D,), ("rnn",), "normal", 0.5),
        w_lora_a=ParamDef((d, LORA_R), ("embed", None), "small"),
        w_lora_b=ParamDef((LORA_R, D), (None, "rnn"), "small"),
        u=ParamDef((D,), ("rnn",), "small"),
        ln_scale=ParamDef((D,), ("rnn",), "ones"),
        w_o=ParamDef((D, d), ("rnn", "embed")),
    )
    cmix = dict(
        mu_ck=ParamDef((d,), ("embed",), "zeros"),
        mu_cr=ParamDef((d,), ("embed",), "zeros"),
        w_ck=ParamDef((d, f), ("embed", "mlp")),
        w_cv=ParamDef((f, d), ("mlp", "embed")),
        w_cr=ParamDef((d, d), ("embed", "embed")),
    )
    return {"tmix": tmix, "cmix": cmix}


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _shift(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _decay(p, xw):
    """log-decay (negative) per channel: w = exp(-exp(w0 + lora(x)))."""
    lora = (xw @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    return jnp.maximum(logw, MIN_LOGW)


def _group_norm(p, x, H, hd, eps=1e-5):
    B, T, D = x.shape
    xg = x.reshape(B, T, H, hd).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = jnp.square(xg - mu).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, T, D) * p["ln_scale"].astype(jnp.float32)).astype(x.dtype)


def _wkv_chunked(r, k, v, logw, u, state):
    """r/k/v (B,T,H,hd) f32; logw (B,T,H,hd) f32 (<=0); u (H,hd);
    state (B,H,hd,hd). Returns (out (B,T,H,hd), new state)."""
    B, T, H, hd = r.shape
    assert T % CHUNK == 0
    n = T // CHUNK
    rc = r.reshape(B, n, CHUNK, H, hd)
    kc = k.reshape(B, n, CHUNK, H, hd)
    vc = v.reshape(B, n, CHUNK, H, hd)
    wc = logw.reshape(B, n, CHUNK, H, hd)

    tri = jnp.tril(jnp.ones((CHUNK, CHUNK), jnp.float32), k=-1)

    def chunk_step(S, inp):
        # NOTE: pinning the state's sharding here was tried and is a no-op
        # (GSPMD re-derives the same flip-flop; the per-chunk state
        # all-gather is a 40-head/16-axis mismatch — §Perf follow-up)
        rr, kk, vv, lw = inp                      # (B,C,H,hd)
        Lc = jnp.cumsum(lw, axis=1)               # inclusive log cumprod
        P = jnp.exp(Lc - lw)                      # prod_{s<t} w_s
        Dv = jnp.exp(Lc)                          # prod_{s<=t} w_s
        rp = rr * P
        kd = kk * jnp.exp(-Lc)                    # k_s / D_s
        A = jnp.einsum("bthc,bshc->bhts", rp, kd) * tri[None, None]
        diag = jnp.einsum("bthc,bthc->bth", rr * u[None, None], kk)
        out = jnp.einsum("bhts,bshc->bthc", A, vv) \
            + diag[..., None] * vv \
            + jnp.einsum("bthc,bhcd->bthd", rp, S)
        Dtot = jnp.exp(Lc[:, -1])                 # (B,H,hd)
        kscale = kk * jnp.exp(Lc[:, -1][:, None] - Lc)   # prod_{s<tau<=C} w
        S_new = S * Dtot[..., None] + jnp.einsum("bshc,bshd->bhcd", kscale, vv)
        return S_new, out

    inp = tuple(a.transpose(1, 0, 2, 3, 4) for a in (rc, kc, vc, wc))
    state, outs = jax.lax.scan(chunk_step, state, inp)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd), state


def _tmix_project(cfg, p, x, x_prev):
    r = _lerp(x, x_prev, p["mu_r"]) @ p["w_r"]
    k = _lerp(x, x_prev, p["mu_k"]) @ p["w_k"]
    v = _lerp(x, x_prev, p["mu_v"]) @ p["w_v"]
    g = _lerp(x, x_prev, p["mu_g"]) @ p["w_g"]
    logw = _decay(p, _lerp(x, x_prev, p["mu_w"]))
    return r, k, v, g, logw


def rwkv_time_mix_full(cfg, p, x, state):
    """x (B,T,d); state (B,H,hd,hd) f32. Returns (y, state)."""
    B, T, d = x.shape
    H, hd = _heads(cfg)
    pad = (-T) % CHUNK
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    r, k, v, g, logw = _tmix_project(cfg, p, xp, _shift(xp))
    shp = (B, T + pad, H, hd)
    rf, kf, vf = (a.astype(jnp.float32).reshape(shp) for a in (r, k, v))
    lw = logw.reshape(shp)
    if pad:  # padded steps: w=1 (logw=0), k=0 -> state untouched
        mask = (jnp.arange(T + pad) < T)[None, :, None, None]
        kf = kf * mask
        lw = lw * mask
    out, state = _wkv_chunked(rf, kf, vf, lw, p["u"].astype(jnp.float32)
                              .reshape(H, hd), state)
    out = out[:, :T].reshape(B, T, H * hd).astype(x.dtype)
    out = _group_norm(p, out, H, hd) * jax.nn.silu(g[:, :T])
    out = constrain(out, "batch", None, None)
    return out @ p["w_o"], state


def rwkv_channel_mix_full(cfg, p, x):
    kx = _lerp(x, _shift(x), p["mu_ck"]) @ p["w_ck"]
    kx = jnp.square(jax.nn.relu(kx))
    kx = constrain(kx, "batch", None, None)
    rx = jax.nn.sigmoid(_lerp(x, _shift(x), p["mu_cr"]) @ p["w_cr"])
    return rx * (kx @ p["w_cv"])


def init_rwkv_cache(cfg, batch: int, dtype) -> dict:
    H, hd = _heads(cfg)
    return {
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_t": jnp.zeros((batch, cfg.d_model), dtype),   # tmix shift state
        "x_c": jnp.zeros((batch, cfg.d_model), dtype),   # cmix shift state
    }


def rwkv_tmix_decode(cfg, p, x, state, x_prev):
    """One token time-mix. x (B,1,d); state (B,H,hd,hd) f32; x_prev (B,d).
    Returns (y (B,1,d), new_state)."""
    B = x.shape[0]
    H, hd = _heads(cfg)
    r, k, v, g, logw = _tmix_project(cfg, p, x, x_prev[:, None, :])
    rf = r.astype(jnp.float32).reshape(B, H, hd)
    kf = k.astype(jnp.float32).reshape(B, H, hd)
    vf = v.astype(jnp.float32).reshape(B, H, hd)
    w = jnp.exp(logw.reshape(B, H, hd))
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    kv = jnp.einsum("bhc,bhd->bhcd", kf, vf)
    out = jnp.einsum("bhc,bhcd->bhd", rf, state + u[..., None] * kv)
    state = state * w[..., None] + kv
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    out = _group_norm(p, out, H, hd) * jax.nn.silu(g)
    return out @ p["w_o"], state


def rwkv_cmix_decode(cfg, p, x, x_prev):
    """One token channel-mix. x (B,1,d); x_prev (B,d)."""
    xp = x_prev[:, None, :]
    kx = jnp.square(jax.nn.relu(_lerp(x, xp, p["mu_ck"]) @ p["w_ck"]))
    rx = jax.nn.sigmoid(_lerp(x, xp, p["mu_cr"]) @ p["w_cr"])
    return rx * (kx @ p["w_cv"])
