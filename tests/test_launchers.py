"""CLI driver smoke tests: the train/serve launchers run end-to-end.

Whole module is `slow`: each test forks a fresh interpreter and retrains
from scratch; tier-1 covers the same code paths in-process."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m"] + args, cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_train_lm_smoke_cli(tmp_path):
    ckpt = str(tmp_path / "lm.msgpack")
    out = _run(["repro.launch.train", "--arch", "rwkv6-3b", "--smoke",
                "--steps", "6", "--batch", "2", "--seq", "32",
                "--log-every", "2", "--ckpt", ckpt])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss=" in out.stdout
    assert os.path.exists(ckpt)


def test_train_gnn_cli():
    out = _run(["repro.launch.train", "--gnn", "pubmed-like", "--k", "2",
                "--scale", "0.03", "--epochs", "20"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "NAI acc=" in out.stdout


def test_serve_lm_cli():
    out = _run(["repro.launch.serve", "--arch", "gemma-7b", "--smoke",
                "--tokens", "6", "--batch", "2"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ms/step" in out.stdout


def test_serve_gnn_cli():
    out = _run(["repro.launch.serve", "--gnn", "pubmed-like", "--requests",
                "200", "--epochs", "20", "--k", "2", "--scale", "0.03"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "p50=" in out.stdout
