"""Pallas TPU kernels for the perf-critical compute (DESIGN.md §2):

* spmm            -- block-ELL sparse feature propagation with NAP row-block
                    predication (the paper's hot loop)
* nap_exit        -- fused distance-to-stationary + exit decision (Eq. 8 +
                    Algorithm 1 line 11)
* flash_attention -- tiled attention with sliding-window banding (local
                    layers + the long-context serving variant)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle). Validated in interpret=True mode on CPU;
TPU is the compile target.
"""
