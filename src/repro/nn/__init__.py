from repro.nn.params import (ParamDef, abstract_tree, count_params, init_tree,
                             spec_tree, tree_bytes)

__all__ = ["ParamDef", "abstract_tree", "count_params", "init_tree",
           "spec_tree", "tree_bytes"]
