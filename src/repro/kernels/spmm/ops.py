"""jit'd wrapper + host-side converter for the block-ELL SpMM kernel."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm.kernel import CB, FB, RB, spmm_block_ell


@dataclasses.dataclass
class BlockEll:
    tiles: np.ndarray      # (n_rb, max_tb, RB, CB) f32
    tile_col: np.ndarray   # (n_rb, max_tb) int32
    valid: np.ndarray      # (n_rb, max_tb) int32
    n: int                 # original (unpadded) node count
    n_pad: int

    @property
    def density(self) -> float:
        return float(self.valid.mean())


def build_block_ell(src, dst, coef, n: int) -> BlockEll:
    """Edge list (local ids) -> block-ELL tiles. Rows/cols padded to CB so
    feature blocks index cleanly."""
    n_pad = -(-n // CB) * CB
    n_rb = n_pad // RB
    rb = dst // RB
    cb = src // CB
    key = rb.astype(np.int64) * (n_pad // CB) + cb
    uniq, inverse = np.unique(key, return_inverse=True)
    tiles_of_rb: dict = {}
    for u in uniq:
        r, c = int(u) // (n_pad // CB), int(u) % (n_pad // CB)
        tiles_of_rb.setdefault(r, []).append(c)
    max_tb = max((len(v) for v in tiles_of_rb.values()), default=1)

    tiles = np.zeros((n_rb, max_tb, RB, CB), np.float32)
    tile_col = np.zeros((n_rb, max_tb), np.int32)
    valid = np.zeros((n_rb, max_tb), np.int32)
    slot_of = {}
    for r, cols in tiles_of_rb.items():
        for t, c in enumerate(sorted(cols)):
            tile_col[r, t] = c
            valid[r, t] = 1
            slot_of[(r, c)] = t
    t_idx = np.fromiter((slot_of[(int(r), int(c))] for r, c in zip(rb, cb)),
                        np.int64, len(rb))
    tiles[rb, t_idx, dst % RB, src % CB] += coef
    return BlockEll(tiles=tiles, tile_col=tile_col, valid=valid, n=n,
                    n_pad=n_pad)


def pad_features(x: np.ndarray, n_pad: int) -> np.ndarray:
    f_pad = -(-x.shape[1] // FB) * FB
    out = np.zeros((n_pad, f_pad), np.float32)
    out[:x.shape[0], :x.shape[1]] = x
    return out


def spmm(ell: BlockEll, x, active=None, *, interpret: bool = True):
    """One propagation step. x (n_pad, F_pad); active (n_rb,) or None
    (= all active). Returns (n_pad, F_pad)."""
    n_rb = ell.tile_col.shape[0]
    if active is None:
        active = jnp.ones((n_rb,), jnp.int32)
    return spmm_block_ell(jnp.asarray(ell.tiles), jnp.asarray(ell.tile_col),
                          jnp.asarray(ell.valid), active, x,
                          interpret=interpret)


def active_blocks_from_nodes(node_active, n_pad: int) -> jnp.ndarray:
    """Node-level NAP mask -> row-block predicate (any node active)."""
    m = jnp.zeros((n_pad,), bool).at[:len(node_active)].set(node_active)
    return m.reshape(-1, RB).any(axis=1).astype(jnp.int32)
