"""Propagated-feature cache (PR 9): store mutation semantics
(`add_edges`/`add_nodes` with per-VERSION_BLOCK version stamping, COW on
the zero-copy InMemoryStore, overlay on MmapStore with the disk files
untouched), PropCache unit behavior (LRU, capacity, stale eviction with
memoized validity, shard partitioning), the serving-level bit-parity
gates — cached == cold predictions AND exit orders for every backend,
including across graph mutations — the zero-steady-state invariant with
the cache enabled, stats hygiene under `reset_stats()`, and the shared
Zipf request-stream generator's determinism."""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.gnn.propcache import PropCache
from repro.gnn.store import (VERSION_BLOCK, InMemoryStore, MmapStore,
                             make_graph, save_graph_store)
from repro.kernels.spmm.kernel import CB
from repro.serving import NAIServingEngine, ServingFrontend, SLOClass

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g = load_dataset("pubmed-like", scale=0.02, seed=4)
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
    path = str(tmp_path_factory.mktemp("store") / "pubmed_store")
    save_graph_store(g, path)
    return g, cfg, params, nai, path


def _serve(engine, nodes):
    engine.submit(nodes)
    done = []
    while engine.queue:
        done += engine.step()
    done += engine.flush()
    return (np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))


def _overlap_stream(g, n_batches=5, size=32, pool=64, seed=3):
    """Batches drawn from a small node pool: heavy cross-batch frontier
    overlap, which is what produces cache hits (batch rows are never
    probed — their series IS the output — so a repeated identical batch
    alone hits nothing)."""
    rng = np.random.default_rng(seed)
    nodes = rng.choice(g.test_idx, size=min(pool, len(g.test_idx)),
                       replace=False)
    return [rng.choice(nodes, size=size, replace=False)
            for _ in range(n_batches)]


# -------------------------------------------------- mutation API (store)
def test_version_block_matches_cb():
    """Invalidation granularity == the packer's CB superblock, the unit
    the halo/sharding machinery already speaks."""
    assert VERSION_BLOCK == CB == 128


def test_add_edges_semantics_and_cow(setup):
    g, *_ = setup
    store = InMemoryStore(g)
    ptr0, idx0 = np.asarray(g.csr()[0]).copy(), np.asarray(g.csr()[1]).copy()
    deg0 = store.degrees.copy()
    m0, clock0 = store.num_edges, store.mutation_clock
    bv0 = store.block_versions.copy()

    added = store.add_edges([5, 7], [300, 200])
    assert added == 2
    assert store.num_edges == m0 + 2
    assert store.mutation_clock > clock0
    # undirected: each endpoint gains one in-neighbor
    for v in (5, 7, 300, 200):
        assert store.degrees[v] == deg0[v] + 1
    # CSR stays valid: monotone row_ptr, every row keeps exactly one
    # self loop, and the new neighbor lands at the END of its row
    # (add_edges appends after existing entries, self loop included)
    row_ptr = np.asarray(store.row_ptr)
    col_idx = np.asarray(store.col_idx)
    assert row_ptr[0] == 0 and row_ptr[-1] == len(col_idx)
    assert (np.diff(row_ptr) >= 1).all()
    for v in range(store.n):
        row = col_idx[row_ptr[v]:row_ptr[v + 1]]
        assert int(np.sum(row == v)) == 1
    for v, nb in ((5, 300), (7, 200), (300, 5), (200, 7)):
        assert col_idx[row_ptr[v + 1] - 1] == nb
    # stamping is block-granular: ONLY the endpoint blocks moved
    stamped = {v // VERSION_BLOCK for v in (5, 7, 300, 200)}
    for b in range(len(bv0)):
        if b in stamped:
            assert store.block_versions[b] > bv0[b]
        else:
            assert store.block_versions[b] == bv0[b]
    # copy-on-write: the wrapped Graph's arrays are untouched
    np.testing.assert_array_equal(np.asarray(g.csr()[0]), ptr0)
    np.testing.assert_array_equal(np.asarray(g.csr()[1]), idx0)
    # self pairs are structural (exactly one loop per row, store-managed)
    with pytest.raises(ValueError):
        store.add_edges([3], [3])


def test_add_nodes_semantics(setup):
    g, *_ = setup
    store = InMemoryStore(g)
    n0, m0 = store.n, store.num_edges
    bv_len0 = len(store.block_versions)
    bv0 = store.block_versions.copy()
    feats = np.ones((2, store.feat_dim), np.float32)

    ids = store.add_nodes(feats)
    np.testing.assert_array_equal(ids, [n0, n0 + 1])
    assert store.n == n0 + 2 and store.num_edges == m0
    assert store.num_self_loops == n0 + 2
    # new rows: exactly the self loop, degree 0, label -1, features kept
    row_ptr = np.asarray(store.row_ptr)
    col_idx = np.asarray(store.col_idx)
    for v in ids:
        assert row_ptr[v + 1] - row_ptr[v] == 1
        assert col_idx[row_ptr[v]] == v
        assert store.degrees[v] == 0
        assert store.labels[v] == -1
    np.testing.assert_array_equal(store.gather_features(ids), feats)
    # only NEW blocks are stamped: no existing cache entry goes stale
    # (an isolated new node changes no existing propagated value)
    np.testing.assert_array_equal(store.block_versions[:bv_len0], bv0)
    # wire them in: add_edges to a new node works end to end
    store.add_edges([ids[0]], [0])
    assert store.degrees[ids[0]] == 1


def test_mmap_store_mutation_overlay_leaves_disk_untouched(setup):
    g, _, _, _, path = setup
    st = MmapStore(path)
    mem = InMemoryStore(g)
    src, dst = [5, 7], [300, 200]
    feats = np.full((3, st.feat_dim), 0.5, np.float32)
    for s in (st, mem):
        s.add_edges(src, dst)
        ids = s.add_nodes(feats)
    # the mutated mmap store serves the same rows as the mutated RAM one
    np.testing.assert_array_equal(st.row_ptr, mem.row_ptr)
    np.testing.assert_array_equal(st.col_idx, mem.col_idx)
    np.testing.assert_array_equal(st.degrees, mem.degrees)
    probe = np.concatenate([np.arange(0, st.n, 97), ids])
    np.testing.assert_array_equal(st.gather_features(probe),
                                  mem.gather_features(probe))
    assert st.num_edges == mem.num_edges
    # the on-disk files never change: a fresh open sees the old graph
    fresh = MmapStore(path)
    assert fresh.n == g.n and fresh.num_edges == g.num_edges
    # verify() raises StoreCorruption on any checksum mismatch and
    # returns the array names it actually checked
    assert "row_ptr" in fresh.verify() and "col_idx" in fresh.verify()
    fresh.close()
    st.close()


# ------------------------------------------------------- PropCache units
def _tiny_store():
    return make_graph(300, avg_deg=4.0, alpha=2.2, seed=1, feat_dim=4)


def test_propcache_validation():
    for bad in (dict(capacity=0, t_max=1), dict(capacity=4, t_max=0),
                dict(capacity=4, t_max=1, n_shards=0)):
        with pytest.raises(ValueError):
            PropCache(**bad)
    c = PropCache(4, 2)
    with pytest.raises(ValueError):        # series shape must match
        c.fill(_tiny_store(), np.array([0]), np.zeros((1, 3, 4)),
               np.array([0]), 0)


def test_propcache_lru_and_capacity():
    store = _tiny_store()
    cache = PropCache(capacity=2, t_max=1)
    vals = np.arange(2 * 1 * 4, dtype=np.float32).reshape(2, 1, 4)
    cache.fill(store, np.array([0, 1]), vals, np.array([0, 1]),
               store.mutation_clock)
    assert len(cache) == 2 and cache.fills == 2
    np.testing.assert_array_equal(cache.gather(np.array([0])), vals[:1])
    # probing 0 bumps its recency, so inserting 2 evicts 1 (the LRU)
    assert cache.probe(store, np.array([0])).all()
    cache.fill(store, np.array([2]), vals[:1], np.array([2]),
               store.mutation_clock)
    assert cache.evictions == 1
    mask = cache.probe(store, np.array([0, 1, 2]))
    np.testing.assert_array_equal(mask, [True, False, True])
    st = cache.stats()
    assert st["size"] == 2 and st["capacity"] == 2
    assert st["hits"] == 3 and st["misses"] == 1
    assert 0.0 < st["hit_rate"] < 1.0
    cache.reset_stats()
    assert cache.stats()["hits"] == 0 and len(cache) == 2   # contents kept
    cache.clear()
    assert len(cache) == 0


def test_propcache_stale_eviction_on_block_stamp():
    store = _tiny_store()
    cache = PropCache(capacity=8, t_max=1)
    vals = np.zeros((2, 1, 4), np.float32)
    # deps span blocks {0, 1}; nodes live in block 0
    cache.fill(store, np.array([0, 1]), vals, np.array([0, 130]),
               store.mutation_clock)
    assert cache.probe(store, np.array([0, 1])).all()
    # stamp dependency block 1 (both endpoints in 128..255): every entry
    # depending on it goes stale and is evicted at its next probe
    store.add_edges([130], [200])
    mask = cache.probe(store, np.array([0, 1]))
    assert not mask.any()
    assert cache.stale == 2 and len(cache) == 0
    # a fill AFTER the mutation is valid at the new clock
    cache.fill(store, np.array([0]), vals[:1], np.array([0, 130]),
               store.mutation_clock)
    assert cache.probe(store, np.array([0])).all()


def test_propcache_survives_unrelated_block_stamp():
    store = _tiny_store()
    cache = PropCache(capacity=8, t_max=1)
    vals = np.zeros((1, 1, 4), np.float32)
    cache.fill(store, np.array([0]), vals, np.array([0, 50]),
               store.mutation_clock)     # deps only in block 0
    store.add_edges([130], [200])        # stamps only block 1
    assert cache.probe(store, np.array([0])).all()
    assert cache.stale == 0
    # dependency blocks past the end of block_versions (nodes added
    # later) are treated as unstamped — sound, and must not crash
    cache.fill(store, np.array([1]), vals,
               np.array([1, store.n + VERSION_BLOCK * 4]),
               store.mutation_clock)
    assert cache.probe(store, np.array([1])).all()


def test_propcache_shard_partitioning():
    store = _tiny_store()
    cache = PropCache(capacity=8, t_max=1, n_shards=2)
    vals = np.zeros((3, 1, 4), np.float32)
    # blocks 0, 1, 2 -> partitions 0, 1, 0 (CB-superblock round-robin)
    cache.fill(store, np.array([0, 128, 256]), vals,
               np.array([0, 128, 256]), store.mutation_clock)
    assert sorted(cache._parts[0]) == [0, 256]
    assert sorted(cache._parts[1]) == [128]
    assert cache.probe(store, np.array([0, 128, 256])).all()


# ------------------------------------------------- zipf stream generator
def test_zipf_requests_deterministic():
    if _ROOT not in sys.path:
        sys.path.insert(0, _ROOT)
    from benchmarks.common import zipf_requests
    ids = np.arange(100, 200)
    a = zipf_requests(ids, 500, exponent=1.0, seed=3)
    np.testing.assert_array_equal(
        a, zipf_requests(ids, 500, exponent=1.0, seed=3))
    assert a.shape == (500,) and set(a) <= set(ids)
    assert not np.array_equal(a, zipf_requests(ids, 500, exponent=1.0,
                                               seed=4))
    # exponent=1 concentrates traffic vs the exponent=0 uniform control
    u = zipf_requests(ids, 500, exponent=0.0, seed=3)
    assert np.bincount(a - 100).max() > np.bincount(u - 100).max()
    for bad in (dict(exponent=-0.5,), ):
        with pytest.raises(ValueError):
            zipf_requests(ids, 5, **bad)
    with pytest.raises(ValueError):
        zipf_requests(np.zeros((2, 2)), 5)
    with pytest.raises(ValueError):
        zipf_requests(np.array([]), 5)


# ---------------------------------------------- serving-level bit parity
def test_cached_serving_bit_parity_all_backends(setup):
    """The acceptance gate: cache on == cache off, predictions AND exit
    orders, for every registered backend — with real hits."""
    g, cfg, params, nai, _ = setup
    from repro.gnn.backends import BACKENDS
    stream = _overlap_stream(g)
    for impl in sorted(BACKENDS):
        hot = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                               mode="compiled", spmm_impl=impl,
                               cache_nodes=4096)
        cold = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                                mode="compiled", spmm_impl=impl)
        for nodes in stream:
            ph, oh = _serve(hot, nodes)
            pc, oc = _serve(cold, nodes)
            np.testing.assert_array_equal(ph, pc, err_msg=impl)
            np.testing.assert_array_equal(oh, oc, err_msg=impl)
        cs = hot.cache_stats
        assert cs["hits"] > 0, (impl, cs)
        assert cs["rows_packed"] < cs["rows_support"], (impl, cs)
        # the cold engine reports row accounting too, with no saving
        ccs = cold.cache_stats
        assert ccs["rows_packed"] == ccs["rows_support"] > 0
        assert "hits" not in ccs


def test_cached_serving_parity_across_mutations(setup):
    """Parity must survive add_edges/add_nodes: lockstep-mutated stores,
    cached vs cold, with stale invalidations actually observed."""
    g, cfg, params, nai, _ = setup
    rng = np.random.default_rng(7)
    s_hot, s_cold = InMemoryStore(g), InMemoryStore(g)
    hot = NAIServingEngine(cfg, nai, params, s_hot, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           cache_nodes=4096)
    cold = NAIServingEngine(cfg, nai, params, s_cold, max_wait_s=10.0,
                            mode="compiled", spmm_impl="segment")
    stream = _overlap_stream(g)
    for nodes in stream[:3]:
        ph, oh = _serve(hot, nodes)
        pc, oc = _serve(cold, nodes)
        np.testing.assert_array_equal(ph, pc)
        np.testing.assert_array_equal(oh, oc)
    # mutate BOTH stores identically: edges between already-served nodes
    # (so invalidation lands on live entries) plus two fresh nodes
    served = np.unique(np.concatenate(stream[:3]))
    src = rng.choice(served, size=8, replace=False)
    dst = (src + 1) % g.n
    src, dst = src[src != dst], dst[src != dst]
    feats = rng.normal(size=(2, 64)).astype(np.float32)
    for s in (s_hot, s_cold):
        s.add_edges(src, dst)
        new_ids = s.add_nodes(feats)
    tail = stream[3:] + [np.concatenate([new_ids, served[:30]])]
    for nodes in tail:
        ph, oh = _serve(hot, nodes)
        pc, oc = _serve(cold, nodes)
        np.testing.assert_array_equal(ph, pc)
        np.testing.assert_array_equal(oh, oc)
    cs = hot.cache_stats
    assert cs["stale"] > 0, cs       # invalidation actually fired
    assert cs["hits"] > 0, cs        # and the cache still serves


def test_cache_zero_steady_state(setup):
    """Repeat batches with the cache enabled: zero jit compiles and zero
    bucket-sized pack allocations once warm (seed shapes must bucket
    like every other operand)."""
    g, cfg, params, nai, _ = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           pipeline_depth=2, cache_nodes=4096)
    stream = _overlap_stream(g)
    for _ in range(3):               # warm: fills, hit saturation, pool
        for nodes in stream:
            _serve(eng, nodes)
    c0, a0 = eng.jit_stats["compiles"], eng.pack_stats["allocs"]
    for _ in range(2):
        for nodes in stream:
            _serve(eng, nodes)
    assert eng.jit_stats["compiles"] == c0
    assert eng.pack_stats["allocs"] == a0
    assert eng.cache_stats["hits"] > 0


# ------------------------------------------------------- stats hygiene
def test_reset_stats_hygiene(setup):
    g, cfg, params, nai, _ = setup
    eng = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           cache_nodes=4096)
    stream = _overlap_stream(g)
    for nodes in stream:
        _serve(eng, nodes)
    eng.stats.failed += 3            # simulate fault-path accounting
    eng.stats.retried += 1
    cs = eng.cache_stats
    assert eng.stats.served > 0 and cs["hits"] > 0
    assert cs["rows_support"] > 0 and cs["size"] > 0
    hwm = dict(eng._bucket_hwm)
    compiles = eng.jit_stats["compiles"]

    eng.reset_stats()
    assert eng.stats.served == eng.stats.batches == 0
    assert eng.stats.failed == eng.stats.retried == 0
    assert not eng.batch_timings
    cs = eng.cache_stats
    assert cs["hits"] == cs["misses"] == cs["fills"] == 0
    assert cs["rows_support"] == cs["rows_packed"] == 0
    # serving state survives: cache contents, hwm, compile cache
    assert cs["size"] > 0
    assert eng._bucket_hwm == hwm
    assert eng.jit_stats["compiles"] == compiles
    # a warm engine resumes with hits immediately
    _serve(eng, stream[0])
    assert eng.cache_stats["hits"] > 0


def test_frontend_close_idempotent_with_shared_store(setup):
    """Per-class engines share one store; close() closes it once per
    engine — must be safe to call repeatedly."""
    g, cfg, params, nai, path = setup
    store = MmapStore(path)
    classes = [
        SLOClass("gold", nai, deadline_s=10.0, max_wait_s=0.02,
                 queue_depth=64),
        SLOClass("best_effort", dataclasses.replace(nai, t_max=nai.t_min),
                 deadline_s=10.0, max_wait_s=0.01, queue_depth=64),
    ]
    fe = ServingFrontend(cfg, params, store, classes, mode="host")
    assert len({id(e.store) for e in fe.engines.values()}) == 1
    r = fe.submit(int(g.test_idx[0]), "gold", now=0.0)
    assert r is not None
    fe.step(now=1.0)
    fe.close()
    fe.close()                        # idempotent
    # frontend reset_stats routes through engine.reset_stats
    fe.reset_stats()
    for eng in fe.engines.values():
        assert eng.stats.served == 0


# ------------------------------------------------- sharded (subprocess)
SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, numpy as np
from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.launch.mesh import make_serving_mesh
from repro.serving import NAIServingEngine

g = load_dataset("pubmed-like", scale=0.02, seed=4)
g = dataclasses.replace(g, features=np.ascontiguousarray(g.features[:, :64]))
cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=32)
rng = np.random.default_rng(3)
pool = rng.choice(g.test_idx, size=64, replace=False)
stream = [rng.choice(pool, size=32, replace=False) for _ in range(5)]

def serve(eng):
    done = []
    for nodes in stream:
        eng.submit(nodes)
        done += eng.step()
    done += eng.flush()
    return (np.array([r.prediction for r in done]),
            np.array([r.exit_order for r in done]))

# shard-local caches: cached sharded serving == cold sharded serving,
# for the halo and dense exchanges at D=2 and halo at D=4
for D, gm in ((2, "halo"), (2, "dense"), (4, "halo")):
    hot = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                           mode="compiled", spmm_impl="segment",
                           pipeline_depth=2, mesh=make_serving_mesh(D),
                           gather_mode=gm, cache_nodes=4096)
    cold = NAIServingEngine(cfg, nai, params, g, max_wait_s=10.0,
                            mode="compiled", spmm_impl="segment",
                            pipeline_depth=2, mesh=make_serving_mesh(D),
                            gather_mode=gm)
    assert hot.cache is not None and hot.cache.n_shards == D, (D, gm)
    ph, oh = serve(hot)
    pc, oc = serve(cold)
    assert np.array_equal(ph, pc), (D, gm)
    assert np.array_equal(oh, oc), (D, gm)
    assert hot.cache_stats["hits"] > 0, (D, gm)
    if (D, gm) == (2, "halo"):
        # zero steady state holds with the cache on in the sharded path
        serve(hot); serve(hot)
        c0, a0 = hot.jit_stats["compiles"], hot.pack_stats["allocs"]
        serve(hot)
        assert hot.jit_stats["compiles"] == c0, hot.jit_stats
        assert hot.pack_stats["allocs"] == a0, hot.pack_stats
print("SHARDED_CACHE_OK")
"""


def test_sharded_cache_parity_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         cwd=_ROOT, env=env, capture_output=True,
                         text=True, timeout=600)
    assert "SHARDED_CACHE_OK" in out.stdout, out.stdout + out.stderr
