"""Fault injection + failure-domain isolation (PR 8): deterministic
`FaultPlan` schedules, per-batch failure isolation in the engine (host /
device / NaN-guard / watchdog), the graceful-degradation retry on the
reference host path, typed store errors (checksum corruption, bounded
short-read retry), store close()/context-manager lifecycle, and the
front-end circuit breaker's state machine — plus the invariant that
wiring all of it up with an EMPTY plan stays bit-identical to the
pre-fault engine."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.gnn import GNNConfig, init_classifiers, load_dataset
from repro.gnn.nai import NAIConfig
from repro.gnn.store import (MmapStore, StoreCorruption, StoreIOError,
                             save_graph_store)
from repro.serving import (BreakerConfig, CircuitBreaker, EngineConfig,
                           FaultPlan, FaultSpec, FaultyStore,
                           NAIServingEngine, ServingFrontend, SLOClass)

IMPL = "segment"     # CPU-cheap reference backend for fault tests


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("pubmed-like", scale=0.02, seed=4)
    g = dataclasses.replace(
        g, features=np.ascontiguousarray(g.features[:, :64]))
    cfg = GNNConfig("sgc", 64, g.num_classes, k=2, hidden=32, mlp_layers=2)
    params = {"cls": init_classifiers(cfg, jax.random.PRNGKey(0))}
    nai = NAIConfig(t_s=6.0, t_min=1, t_max=2, batch_size=8)
    return g, cfg, params, nai


def _engine(setup, **over):
    g, cfg, params, nai = setup
    ec = EngineConfig(**{"mode": "compiled", "spmm_impl": IMPL,
                         "pipeline_depth": 2, **over})
    return NAIServingEngine(cfg, nai, params, g, config=ec)


def _serve(eng, nids, bs=8):
    done = []
    for i in range(0, len(nids), bs):
        eng.submit(nids[i:i + bs])
        done += eng.step()
    done += eng.flush()
    return done


def _nodes(setup, n=40, seed=0):
    # unique ids: each node appears in exactly one batch, so clean and
    # faulted runs (same batching) are comparable keyed by node id even
    # though NAI results depend on batch-support composition
    g = setup[0]
    rng = np.random.default_rng(seed)
    return rng.choice(g.test_idx, size=n, replace=False)


# ------------------------------------------------------------ fault plan
def test_fault_plan_deterministic_and_seed_sensitive():
    plan = FaultPlan([FaultSpec("host", rate=0.3),
                      FaultSpec("device", at=(2, 5))], seed=9)
    a, b = plan.injector(), plan.injector()
    hits_a = [(a.fire("host") is not None, a.fire("device") is not None)
              for _ in range(50)]
    hits_b = [(b.fire("host") is not None, b.fire("device") is not None)
              for _ in range(50)]
    assert hits_a == hits_b                      # same plan => same run
    assert any(h for h, _ in hits_a)             # rate spec fired
    assert [d for _, d in hits_a[:7]] == [False, False, True, False,
                                          False, True, False]
    c = FaultPlan([FaultSpec("host", rate=0.3)], seed=10).injector()
    hits_c = [c.fire("host") is not None for _ in range(50)]
    assert hits_c != [h for h, _ in hits_a]      # different seed differs


def test_fault_plan_max_fires_and_validation():
    inj = FaultPlan([FaultSpec("host", rate=1.0, max_fires=2)]).injector()
    assert [inj.fire("host") is not None for _ in range(4)] == \
        [True, True, False, False]
    with pytest.raises(ValueError, match="unknown fault stage"):
        FaultSpec("warp_core", rate=0.1)
    with pytest.raises(ValueError, match="rate"):
        FaultSpec("host", rate=1.5)


# --------------------------------------------- engine batch isolation
def test_host_fault_fails_only_its_batch(setup):
    nids = _nodes(setup)
    clean = _serve(_engine(setup), nids)
    eng = _engine(setup, faults=FaultPlan([FaultSpec("host", at=(1,))]))
    done = _serve(eng, nids)
    assert len(done) == len(nids)
    failed = [r for r in done if r.status == "failed"]
    ok = [r for r in done if r.status == "completed"]
    assert len(failed) == 8 and eng.stats.failed == 8
    assert all("InjectedFault" in r.error for r in failed)
    assert all(r.prediction == -1 for r in failed)
    # the surviving batches match the clean run bit-for-bit (inference
    # is deterministic per node, so node id keys the comparison)
    by_clean = {r.node_id: (r.prediction, r.exit_order) for r in clean}
    for r in ok:
        assert (r.prediction, r.exit_order) == by_clean[r.node_id]


def test_device_fault_fails_only_its_batch(setup):
    eng = _engine(setup, faults=FaultPlan([FaultSpec("device", at=(0,))]))
    done = _serve(eng, _nodes(setup))
    sts = [r.status for r in done]
    assert sts.count("failed") == 8 and sts.count("completed") == 32
    assert eng._inflight == type(eng._inflight)()   # pipeline clean


def test_nan_guard_never_completes_poisoned_batch(setup):
    eng = _engine(setup, faults=FaultPlan([FaultSpec("nan", at=(0, 2))]))
    done = _serve(eng, _nodes(setup))
    failed = [r for r in done if r.status == "failed"]
    assert len(failed) == 16
    assert all("NaNGuardError" in r.error for r in failed)
    # no completed request carries a poisoned result
    for r in done:
        if r.status == "completed":
            assert 0 <= r.prediction < setup[1].num_classes
            assert 1 <= r.exit_order <= setup[3].t_max


def test_poll_finalizes_host_materialized_results(setup):
    """Open-loop regression (found by chaos_bench): a batch whose
    in-flight results are plain host arrays (no `is_ready` — e.g. a
    NaN-poisoned batch) must still be finalized by poll() while it sits
    BELOW pipeline_depth; treating missing `is_ready` as not-ready
    parks it there forever and wedges open-loop serving until flush."""
    eng = _engine(setup, retry_failed=True,
                  faults=FaultPlan([FaultSpec("nan", at=(0,))]))
    eng.submit(_nodes(setup, n=8))
    done = eng.poll()                    # dispatches the poisoned batch
    for _ in range(50):
        if done:
            break
        done += eng.poll()               # empty queue: opportunistic path
    assert len(done) == 8, "poll() never finalized the in-flight batch"
    assert all(r.status == "completed" and r.retried for r in done)
    assert not eng._inflight


def test_retry_recovers_on_reference_path_bit_identical(setup):
    nids = _nodes(setup)
    clean = _serve(_engine(setup), nids)
    eng = _engine(setup, retry_failed=True,
                  faults=FaultPlan([FaultSpec("nan", at=(1,)),
                                    FaultSpec("device", at=(3,))]))
    done = _serve(eng, nids)
    assert all(r.status == "completed" for r in done)
    assert eng.stats.retried == 16 and eng.stats.failed == 0
    assert sum(r.retried for r in done) == 16
    # the host reference path gives the same answers as the compiled one
    # (keyed by node: a dispatch-time retry completes ahead of the
    # in-flight batch before it, so terminal order differs)
    by_clean = {r.node_id: (r.prediction, r.exit_order) for r in clean}
    for r in done:
        assert (r.prediction, r.exit_order) == by_clean[r.node_id]


def test_watchdog_fails_hung_batch_and_rearms(setup):
    eng = _engine(setup, watchdog_s=0.2,
                  faults=FaultPlan([FaultSpec("hang", at=(1,))]))
    done = _serve(eng, _nodes(setup))
    failed = [r for r in done if r.status == "failed"]
    assert len(failed) == 8
    assert all("WatchdogTimeout" in r.error for r in failed)
    # the pipeline re-armed: batches AFTER the hung one completed
    assert [r.status for r in done].count("completed") == 32
    assert not eng._inflight


def test_fault_free_wiring_bit_identical(setup):
    """The whole isolation stack armed but idle — empty plan, watchdog,
    NaN guard, retry enabled — must not perturb results or stats."""
    nids = _nodes(setup, n=48, seed=3)
    plain = _engine(setup)
    wired = _engine(setup, faults=FaultPlan(), watchdog_s=5.0,
                    retry_failed=True, nan_guard=True)
    d0, d1 = _serve(plain, nids), _serve(wired, nids)
    assert [r.prediction for r in d1] == [r.prediction for r in d0]
    assert [r.exit_order for r in d1] == [r.exit_order for r in d0]
    assert wired.stats.failed == 0 and wired.stats.retried == 0
    assert all(r.status == "completed" for r in d1)
    assert wired.jit_stats == plain.jit_stats
    assert wired.pack_stats == plain.pack_stats


# ------------------------------------------------- submit validation
def test_submit_rejects_out_of_range_ids_atomically(setup):
    g = setup[0]
    eng = _engine(setup)
    for bad in (-1, g.n, g.n + 7):
        with pytest.raises(ValueError, match="out of range"):
            eng.submit([0, 1, bad])
    assert not eng.queue            # nothing half-submitted
    from repro.serving.engine import Request
    with pytest.raises(ValueError, match="out of range"):
        eng.submit_request(Request(g.n, 0.0))


def test_frontend_submit_rejects_bad_id_without_accounting(setup):
    g, cfg, params, nai = setup
    fe = ServingFrontend(cfg, params, g,
                         [SLOClass("gold", nai, deadline_s=1.0,
                                   max_wait_s=0.01)],
                         mode="host")
    with pytest.raises(ValueError, match="out of range"):
        fe.submit(g.n, "gold", now=0.0)
    assert fe.stats["gold"].offered == 0    # caller error, not shed
    assert fe.submit(int(g.test_idx[0]), "gold", now=0.0) is not None
    fe.flush()
    assert fe.stats["gold"].completed == 1


# ------------------------------------------------------- faulty store
def test_faulty_store_raises_typed_errors_per_plan(setup):
    g = setup[0]
    inj = FaultPlan([FaultSpec("store_read", at=(1,))], seed=2).injector()
    from repro.gnn.store import as_store
    fs = FaultyStore(as_store(g), inj)
    nodes = np.arange(4)
    ok = fs.gather_features(nodes)                    # event 0: clean
    assert np.array_equal(ok, as_store(g).gather_features(nodes))
    with pytest.raises(StoreIOError, match="injected read failure"):
        fs.gather_features(nodes)                     # event 1: fires


def test_store_faults_fail_batches_not_engine(setup):
    g, cfg, params, nai = setup
    from repro.gnn.store import as_store
    plan = FaultPlan([FaultSpec("store_read", at=(1, 4))], seed=6)
    fs = FaultyStore(as_store(g), plan.injector())
    ec = EngineConfig(mode="compiled", spmm_impl=IMPL, pipeline_depth=2)
    eng = NAIServingEngine(cfg, nai, params, fs, config=ec)
    done = _serve(eng, _nodes(setup, n=64, seed=5))
    assert len(done) == 64
    failed = [r for r in done if r.status == "failed"]
    assert failed and all("StoreIOError" in r.error for r in failed)
    assert any(r.status == "completed" for r in done)
    assert eng.stats.failed == len(failed)


# ------------------------------------------- mmap store: io + lifecycle
@pytest.fixture()
def store_dir(setup, tmp_path):
    d = str(tmp_path / "store")
    save_graph_store(setup[0], d)
    return d


def test_checksums_written_and_verified(store_dir):
    with MmapStore(store_dir, verify=True) as ms:
        assert set(ms.verify()) == {"row_ptr", "col_idx", "features",
                                    "degrees", "labels"}


def test_corruption_detected_by_checksum(store_dir):
    p = os.path.join(store_dir, "features.npy")
    size = os.path.getsize(p)
    with open(p, "r+b") as fh:
        fh.seek(size - 5)
        b = fh.read(1)
        fh.seek(size - 5)
        fh.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(StoreCorruption, match="checksum mismatch"):
        MmapStore(store_dir, verify=True)
    ms = MmapStore(store_dir)                  # lazy open still allowed
    with pytest.raises(StoreCorruption):
        ms.verify(("features",))
    ms.close()


def test_truncated_array_detected_by_shape_check(store_dir, setup):
    g = setup[0]
    np.save(os.path.join(store_dir, "degrees.npy"),
            np.asarray(g.degrees)[: g.n // 2])
    ms = MmapStore(store_dir)
    with pytest.raises(StoreCorruption, match="shape"):
        _ = ms.degrees
    ms.close()


def test_short_read_retries_then_raises(store_dir, monkeypatch):
    ms = MmapStore(store_dir, io_retries=2, io_backoff_s=1e-4)
    nodes = np.array([3, 9, 10, 11, 50])
    want = np.load(os.path.join(store_dir, "features.npy"))[nodes]
    real = os.preadv
    calls = {"n": 0}

    def flaky(fd, bufs, off):                  # short once, then real
        calls["n"] += 1
        if calls["n"] % 2 == 1:
            short = [memoryview(bufs[0])[: len(bufs[0]) // 2]]
            return real(fd, short, off)
        return real(fd, bufs, off)

    monkeypatch.setattr(os, "preadv", flaky)
    out = ms.gather_features(nodes)
    assert np.array_equal(out, want)           # retry completed the read

    calls["n"] = 0
    monkeypatch.setattr(
        os, "preadv", lambda fd, bufs, off: 0)  # never progresses
    with pytest.raises(StoreIOError, match="short read"):
        ms.gather_features(nodes)
    monkeypatch.undo()
    ms.close()


def test_mmap_store_close_and_context_manager(store_dir):
    with MmapStore(store_dir) as ms:
        ms.gather_features(np.array([0, 1, 2]))
        assert ms._feat_fd >= 0
        fd = ms._feat_fd
    assert ms._feat_fd == -1
    with pytest.raises(OSError):
        os.fstat(fd)                           # fd really closed
    ms.close()                                 # idempotent
    with pytest.raises(ValueError, match="closed"):
        ms.gather_features(np.array([0]))
    with pytest.raises(ValueError, match="closed"):
        _ = ms.row_ptr


def test_engine_close_releases_store(setup, store_dir):
    g, cfg, params, nai = setup
    ms = MmapStore(store_dir)
    ec = EngineConfig(mode="compiled", spmm_impl=IMPL, pipeline_depth=2)
    eng = NAIServingEngine(cfg, nai, params, ms, config=ec)
    done = _serve(eng, _nodes(setup, n=16, seed=7))
    assert all(r.status == "completed" for r in done)
    eng.close()
    assert ms._feat_fd == -1
    eng.close()                                # idempotent


# -------------------------------------------------- circuit breaker
def test_breaker_state_machine_on_virtual_clock():
    br = CircuitBreaker(BreakerConfig(window=8, trip_frac=0.5,
                                      min_events=4, cooldown_s=1.0,
                                      probes=2))
    t = 0.0
    assert br.route(t) == "native"
    for _ in range(4):                         # sustained failures: trip
        br.on_terminal(True, False, t)
    assert br.state == "open" and br.trips == 1
    assert br.route(t + 0.5) == "reroute"      # still cooling down
    assert br.route(t + 1.1) == "probe"        # half_open: probe 1
    assert br.route(t + 1.1) == "probe"        # probe 2
    assert br.route(t + 1.1) == "reroute"      # probe budget spent
    br.on_terminal(False, True, t + 1.2)       # probe ok
    br.on_terminal(False, True, t + 1.2)       # second ok: close
    assert br.state == "closed"
    # trip again, then a failing probe re-opens with a fresh cooldown
    for _ in range(4):
        br.on_terminal(True, False, t + 2.0)
    assert br.state == "open"
    assert br.route(t + 3.5) == "probe"
    br.on_terminal(True, True, t + 3.6)
    assert br.state == "open" and br.trips == 3
    assert br.route(t + 3.7) == "reroute"      # cooldown restarted
    assert [(a, b) for _, a, b in br.transitions] == [
        ("closed", "open"), ("open", "half_open"),
        ("half_open", "closed"), ("closed", "open"),
        ("open", "half_open"), ("half_open", "open")]


def test_breaker_non_closed_ignores_stale_outcomes():
    br = CircuitBreaker(BreakerConfig(window=8, trip_frac=0.5,
                                      min_events=4, cooldown_s=1.0,
                                      probes=1))
    for _ in range(4):
        br.on_terminal(True, False, 0.0)
    assert br.state == "open"
    # pre-trip traffic draining as failures must not re-trip/extend
    br.on_terminal(True, False, 0.5)
    assert br.trips == 1
    assert br.route(1.5) == "probe"
    br.on_terminal(True, False, 1.6)           # non-probe while half_open
    assert br.state == "half_open"
    br.on_terminal(False, True, 1.7)
    assert br.state == "closed"


def test_frontend_demotes_gold_and_recovers(setup):
    g, cfg, params, nai = setup
    classes = [
        SLOClass("gold", nai, deadline_s=10.0, max_wait_s=0.001,
                 queue_depth=64, demote_to="best_effort",
                 engine=EngineConfig(
                     mode="compiled", spmm_impl=IMPL,
                     faults=FaultPlan([FaultSpec("device",
                                                 at=tuple(range(0, 3)))],
                                      seed=3))),
        SLOClass("best_effort", dataclasses.replace(nai, t_max=nai.t_min),
                 deadline_s=10.0, max_wait_s=0.001, queue_depth=64),
    ]
    br = BreakerConfig(window=8, trip_frac=0.5, min_events=8,
                       cooldown_s=0.05, probes=1, count_misses=False)
    fe = ServingFrontend(cfg, params, g, classes, breaker=br,
                         mode="compiled", spmm_impl=IMPL)
    rng = np.random.default_rng(11)
    import time as _t
    term = []
    for _ in range(40):
        for nid in rng.choice(g.test_idx, size=8, replace=True):
            fe.submit(int(nid), "gold")
        guard = _t.perf_counter() + 1.0
        while fe.pending() and _t.perf_counter() < guard:
            term += fe.step()
        if fe.breakers["gold"].state == "closed" and \
                fe.stats["gold"].degraded:
            break
    term += fe.flush()
    st = fe.stats["gold"]
    brk = fe.breakers["gold"]
    assert brk.trips >= 1
    assert st.degraded > 0                      # demotion happened
    assert brk.state == "closed"                # and it recovered
    assert st.offered == st.accepted + st.rejected
    assert st.accepted == st.completed + st.failed
    assert fe.pending() == 0
    fe.close()
