"""Distributed feature propagation: the paper's substrate at pod scale.

Node-partitioned SpMM under `shard_map`: nodes (and their in-edges) are
split across the 'data' axis; features are split across 'model'. One
propagation step is

    out[i] = sum_j coef(j->i) x[j]

with x gathered across node shards (`all_gather` over 'data') and the
feature dim staying sharded — each device reduces its own (rows x feature
slice) block. For the paper's graphs (feature dim 100-500, nodes in the
millions) the gather is the right trade: x is (n, f/16) per device and the
adjacency never moves.

The NAP loop composes on top: per-shard exit masks feed the same
`active_blocks_from_nodes` predication the Pallas kernel consumes; the
distance reduction is local (features sharded), followed by a psum over
'model' for the l2 norm.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.gnn.graph import Graph, edge_coefficients


def partition_graph(g: Graph, n_shards: int, r: float = 0.5):
    """Split nodes contiguously into `n_shards`; each shard keeps the edges
    whose DESTINATION lands in the shard (src stays global). Returns padded
    per-shard edge arrays (stacked, shard-major) + padded feature matrix."""
    n_pad = -(-g.n // n_shards) * n_shards
    rows = n_pad // n_shards
    coef = edge_coefficients(g, r)
    shard_of = g.dst // rows
    counts = np.bincount(shard_of, minlength=n_shards)
    e_pad = -(-counts.max() // 8) * 8

    src = np.zeros((n_shards, e_pad), np.int32)
    dst = np.zeros((n_shards, e_pad), np.int32)     # LOCAL row within shard
    cf = np.zeros((n_shards, e_pad), np.float32)    # 0 padding = no-op edge
    for s in range(n_shards):
        m = shard_of == s
        k = int(m.sum())
        src[s, :k] = g.src[m]
        dst[s, :k] = g.dst[m] - s * rows
        cf[s, :k] = coef[m]
    x = np.zeros((n_pad, g.features.shape[1]), np.float32)
    x[:g.n] = g.features
    return src, dst, cf, x, rows


def make_distributed_propagate(mesh, rows: int, n_shards: int):
    """Returns a jitted `propagate(src, dst, coef, x) -> x'` running under
    shard_map on (data=node shards, model=feature shards)."""

    def local_step(src, dst, coef, x):
        # src/dst/coef: (1, E) this shard's edges; x: (rows_total, f_loc)
        src, dst, coef = src[0], dst[0], coef[0]
        x_full = jax.lax.all_gather(x, "data", axis=0, tiled=True)
        contrib = coef[:, None] * x_full[src]
        return jax.ops.segment_sum(contrib, dst, num_segments=rows)

    return jax.jit(shard_map(
        local_step, mesh=mesh,
        in_specs=(P("data", None), P("data", None), P("data", None),
                  P("data", "model")),
        out_specs=P("data", "model")))


def distributed_series(mesh, g: Graph, k: int, r: float = 0.5):
    """[X^(0..k)] computed with the distributed step; host-verifiable."""
    n_shards = mesh.shape["data"]
    src, dst, cf, x, rows = partition_graph(g, n_shards, r)
    prop = make_distributed_propagate(mesh, rows, n_shards)
    srcj, dstj, cfj = (jnp.asarray(a) for a in (src, dst, cf))
    out = [jnp.asarray(x)]
    for _ in range(k):
        out.append(prop(srcj, dstj, cfj, out[-1]))
    return out


def distributed_nap_distances(mesh, x, x_inf):
    """Per-node ||x - x_inf|| with features sharded over 'model': local
    partial sum of squares + psum over the feature axis."""

    def local(x, xi):
        d2 = jnp.sum(jnp.square(x - xi), axis=1, keepdims=True)
        return jax.lax.psum(d2, "model")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("data", "model"), P("data", "model")),
                   out_specs=P("data", None))
    return jnp.sqrt(fn(x, x_inf)[:, 0])
