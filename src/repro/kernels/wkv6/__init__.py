from repro.kernels.wkv6.kernel import CHUNK, wkv6
from repro.kernels.wkv6.ops import wkv6_heads
from repro.kernels.wkv6.ref import ref_wkv6_sequential

__all__ = ["CHUNK", "wkv6", "wkv6_heads", "ref_wkv6_sequential"]
