"""The vectorized frontier-expansion sampler must be bit-identical to the
legacy per-node dict BFS: same support set in the same discovery order,
same hop layers, same induced edge list, same coefficients."""
import functools

import numpy as np
import pytest

from repro.gnn import load_dataset
from repro.gnn.sampler import sample_support, sample_support_legacy
from repro.gnn.store import as_store


@functools.lru_cache(maxsize=None)
def _graph(name, scale, seed):
    return load_dataset(name, scale=scale, seed=seed)


CASES = [("pubmed-like", 0.03, 0), ("flickr-like", 0.008, 1)]


@pytest.mark.parametrize("name,scale,seed", CASES)
@pytest.mark.parametrize("hops", [1, 2, 3])
@pytest.mark.parametrize("bs", [1, 17, 128])
def test_vectorized_matches_legacy(name, scale, seed, hops, bs):
    g = _graph(name, scale, seed)
    rng = np.random.default_rng(seed + hops + bs)
    batch = rng.choice(g.test_idx, size=min(bs, len(g.test_idx)),
                       replace=False)
    for r in (0.5, 0.3):
        a = sample_support(as_store(g), batch, hops, r)
        b = sample_support_legacy(g, batch, hops, r)
        assert np.array_equal(a.nodes, b.nodes)
        assert np.array_equal(a.hop, b.hop)
        assert a.n_batch == b.n_batch == len(batch)
        assert np.array_equal(a.src, b.src)
        assert np.array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.coef, b.coef)
        assert a.sub_edges == b.sub_edges


def test_isolated_batch_node():
    """A batch node whose only edge is its self loop still samples."""
    g = _graph("pubmed-like", 0.03, 0)
    deg = np.diff(g.csr()[0])
    lone = int(np.argmin(deg))
    a = sample_support(as_store(g), np.array([lone]), 2, 0.5)
    b = sample_support_legacy(g, np.array([lone]), 2, 0.5)
    assert np.array_equal(a.nodes, b.nodes)
    assert np.array_equal(a.src, b.src)
    assert a.nodes[0] == lone


def test_whole_test_set_batch():
    """Large batch (the serving engine's full batch) stays identical."""
    g = _graph("pubmed-like", 0.03, 0)
    batch = g.test_idx[:  min(300, len(g.test_idx))]
    a = sample_support(as_store(g), batch, 2, 0.5)
    b = sample_support_legacy(g, batch, 2, 0.5)
    assert np.array_equal(a.nodes, b.nodes)
    assert np.array_equal(a.hop, b.hop)
    assert np.array_equal(a.src, b.src)
    assert np.array_equal(a.dst, b.dst)
    np.testing.assert_array_equal(a.coef, b.coef)


def test_sampler_invariants_without_hypothesis():
    """The core sampler invariants, runnable even where the hypothesis
    property suite (tests/test_property.py) is skipped: batch nodes
    first at hop 0, hop monotonicity, coefficient positivity, unique
    support, in-range local edges."""
    g = _graph("pubmed-like", 0.03, 0)
    rng = np.random.default_rng(5)
    for hops in (1, 3):
        batch = rng.choice(g.test_idx, size=40, replace=False)
        sup = sample_support(as_store(g), batch, hops, 0.5)
        assert np.array_equal(sup.nodes[:len(batch)], batch)
        assert (sup.hop[:len(batch)] == 0).all()
        assert (np.diff(sup.hop) >= 0).all()
        assert sup.hop.max() <= hops
        assert (sup.coef > 0).all()
        assert len(np.unique(sup.nodes)) == len(sup)
        assert sup.src.max() < len(sup) and sup.dst.max() < len(sup)
