"""Checkpoint-manifest contract tests (repro.launch.checkpoint).

The offline driver's resume parity reduces to these invariants: bit-
exact payload round-trips, atomic commits (trailing un-committed files
are invisible), typed corruption detection (CRC mismatch, truncation,
missing files, garbage manifests), and fingerprint binding. Each gets
a deterministic test; the round-trip also gets a hypothesis property
when the package is available (the CI image has no pip access)."""
import json
import os

import numpy as np
import pytest

from repro.launch.checkpoint import (FORMAT, MANIFEST, CheckpointCorruption,
                                     CheckpointError, CheckpointManager,
                                     CheckpointMismatch)
from repro.serving.faults import FaultPlan, FaultSpec, InjectedFault

FP = {"store": "t", "n": 10, "shards": 1}


def _arrays(rng, dtypes=(np.float32, np.int32, np.float64, np.int64)):
    out = {}
    for i, dt in enumerate(dtypes):
        shape = tuple(int(s) for s in rng.integers(1, 7, size=2))
        a = rng.standard_normal(shape) * 100
        out[f"a{i}"] = a.astype(dt)
    return out


# --------------------------------------------------------- round trip
def test_round_trip_bit_identical(tmp_path):
    rng = np.random.default_rng(0)
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    saved = {}
    for step in range(4):
        saved[step] = _arrays(rng)
        mgr.save_step(step, saved[step])
    assert mgr.steps() == [0, 1, 2, 3]
    assert mgr.latest_complete() == 3
    assert mgr.latest_complete(verify=True) == 3
    # reopen from disk: same steps, same bytes, same dtypes/shapes
    re = CheckpointManager(str(tmp_path), fingerprint=FP)
    for step, arrays in saved.items():
        got = re.load_step(step)
        assert set(got) == set(arrays)
        for k, a in arrays.items():
            assert got[k].dtype == a.dtype and got[k].shape == a.shape
            np.testing.assert_array_equal(got[k], a)
    assert re.total_bytes() == mgr.total_bytes() > 0


def test_round_trip_property_hypothesis(tmp_path):
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    dtypes = st.sampled_from([np.float32, np.float64, np.int32,
                              np.int64, np.uint8, np.bool_])
    arrays = dtypes.flatmap(lambda dt: hnp.arrays(
        dt, hnp.array_shapes(min_dims=1, max_dims=3, max_side=8)))

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(payload=st.dictionaries(
        st.text("abcdefgh_", min_size=1, max_size=8), arrays,
        min_size=1, max_size=4), step=st.integers(0, 99))
    def prop(payload, step):
        root = str(tmp_path / f"p{step}_{abs(hash(str(sorted(payload))))}")
        mgr = CheckpointManager(root, fingerprint=FP)
        mgr.save_step(step, payload)
        got = CheckpointManager(root, fingerprint=FP).load_step(step)
        assert set(got) == set(payload)
        for k, a in payload.items():
            assert got[k].dtype == np.asarray(a).dtype
            np.testing.assert_array_equal(got[k], a)

    prop()


def test_result_round_trip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    assert mgr.load_result() is None
    res = {"predictions": np.arange(9, dtype=np.int32),
           "exit_orders": np.ones(9, np.int32)}
    mgr.save_result(res)
    got = CheckpointManager(str(tmp_path), fingerprint=FP).load_result()
    np.testing.assert_array_equal(got["predictions"], res["predictions"])
    np.testing.assert_array_equal(got["exit_orders"], res["exit_orders"])


# ----------------------------------------------------------- atomicity
def test_uncommitted_trailing_payloads_are_invisible(tmp_path):
    """A crash between payload write and manifest commit (the ckpt_write
    injection window) leaves step files no manifest entry names — a
    resume must not see them."""
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP,
                            injector=FaultPlan(
                                [FaultSpec("ckpt_write", at=(1,))]
                            ).injector())
    mgr.save_step(0, {"x": np.zeros(4, np.float32)})
    with pytest.raises(InjectedFault):
        mgr.save_step(1, {"x": np.ones(4, np.float32)})
    # payload dir exists on disk, but the commit never happened
    assert os.path.isdir(tmp_path / "step_00001")
    re = CheckpointManager(str(tmp_path), fingerprint=FP)
    assert re.steps() == [0]
    assert re.latest_complete(verify=True) == 0
    with pytest.raises(CheckpointError):
        re.load_step(1)


def test_commit_replaces_manifest_atomically(tmp_path):
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    mgr.save_step(0, {"x": np.zeros(3, np.float32)})
    assert not os.path.exists(str(tmp_path / MANIFEST) + ".tmp")
    doc = json.load(open(tmp_path / MANIFEST))
    assert doc["format"] == FORMAT and "0" in doc["steps"]


# ---------------------------------------------------------- corruption
def test_corruption_is_typed_and_bounded(tmp_path):
    rng = np.random.default_rng(1)
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    for step in range(3):
        mgr.save_step(step, {"x": rng.standard_normal(8).astype(
            np.float32)})
    # flip one byte mid-file in step 2
    path = tmp_path / "step_00002" / "x.npy"
    with open(path, "r+b") as fh:
        fh.seek(os.path.getsize(path) // 2)
        b = fh.read(1)
        fh.seek(-1, 1)
        fh.write(bytes([b[0] ^ 0xFF]))
    re = CheckpointManager(str(tmp_path), fingerprint=FP)
    with pytest.raises(CheckpointCorruption, match="CRC mismatch"):
        re.load_step(2)
    re.load_step(1)                          # earlier steps unharmed
    assert re.latest_complete() == 2         # committed, but...
    assert re.latest_complete(verify=True) == 1   # ...not verifiable


def test_truncated_and_missing_payloads_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    mgr.save_step(0, {"x": np.arange(64, dtype=np.float64)})
    mgr.save_step(1, {"x": np.arange(64, dtype=np.float64)})
    path = tmp_path / "step_00000" / "x.npy"
    with open(path, "r+b") as fh:
        fh.truncate(os.path.getsize(path) // 2)
    re = CheckpointManager(str(tmp_path), fingerprint=FP)
    with pytest.raises(CheckpointCorruption):
        re.load_step(0)
    os.remove(tmp_path / "step_00001" / "x.npy")
    with pytest.raises(CheckpointCorruption, match="missing"):
        re.load_step(1)
    assert re.latest_complete(verify=True) is None


def test_garbage_manifest_rejected(tmp_path):
    with open(tmp_path / MANIFEST, "w") as fh:
        fh.write("{not json")
    with pytest.raises(CheckpointCorruption, match="not valid JSON"):
        CheckpointManager(str(tmp_path))
    with open(tmp_path / MANIFEST, "w") as fh:
        json.dump({"format": FORMAT, "nothing": 1}, fh)
    with pytest.raises(CheckpointCorruption, match="steps table"):
        CheckpointManager(str(tmp_path))


def test_injected_read_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    mgr.save_step(0, {"x": np.zeros(2, np.float32)})
    bad = CheckpointManager(str(tmp_path), fingerprint=FP,
                            injector=FaultPlan(
                                [FaultSpec("ckpt_read", at=(0,))]
                            ).injector())
    with pytest.raises(CheckpointCorruption, match="injected"):
        bad.load_step(0)
    # the next read (injection exhausted) succeeds
    bad.load_step(0)


# --------------------------------------------------------- fingerprint
def test_fingerprint_binds_checkpoint_to_run(tmp_path):
    mgr = CheckpointManager(str(tmp_path), fingerprint=FP)
    mgr.save_step(0, {"x": np.zeros(2, np.float32)})
    CheckpointManager(str(tmp_path), fingerprint=dict(FP))   # same: fine
    with pytest.raises(CheckpointMismatch):
        CheckpointManager(str(tmp_path),
                          fingerprint={**FP, "shards": 2})
    # foreign format version is a mismatch, not a guess
    doc = json.load(open(tmp_path / MANIFEST))
    doc["format"] = "some-other-format"
    json.dump(doc, open(tmp_path / MANIFEST, "w"))
    with pytest.raises(CheckpointMismatch, match="format"):
        CheckpointManager(str(tmp_path), fingerprint=FP)
