"""Fused NAP exit-decision Pallas kernel.

Computes, per node tile, the squared L2 distance to the stationary state
(paper Eq. 8) and the exit decision d < T_s in one pass over the feature
blocks — the propagated features are read once, no (n, f) temporary is
materialized. Also emits the per-row-block `any still active` predicate that
feeds the next SpMM step's block predication.

Grid: (node_blocks, feature_blocks); feature loop innermost accumulates the
squared distance in the output tile, the final feature block turns it into
{exit, active} flags in-place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NB = 8      # nodes per tile
FB = 128    # feature block


def _kernel(x_ref, xinf_ref, active_ref, ts2_ref, dist_ref, exit_ref,
            blk_active_ref):
    fb = pl.program_id(1)
    nfb = pl.num_programs(1)

    @pl.when(fb == 0)
    def _init():
        dist_ref[...] = jnp.zeros_like(dist_ref)

    diff = (x_ref[...] - xinf_ref[...]).astype(jnp.float32)
    dist_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    @pl.when(fb == nfb - 1)
    def _decide():
        was_active = active_ref[...] != 0
        exits = was_active & (dist_ref[...] < ts2_ref[0])
        still = was_active & ~exits
        exit_ref[...] = exits.astype(jnp.int32)
        blk_active_ref[0, 0] = jnp.any(still).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nap_exit(x, x_inf, active, t_s, *, interpret=True):
    """x, x_inf: (n_pad, F_pad) propagated/stationary features;
    active: (n_pad, 1) int32 per-node 'not yet exited';
    t_s: scalar threshold (distance, not squared).
    Returns (dist2 (n_pad, 1) f32, exit (n_pad, 1) int32,
             blk_active (n_blocks, 1) int32)."""
    n, F = x.shape
    assert n % NB == 0 and F % FB == 0
    grid = (n // NB, F // FB)
    ts2 = jnp.asarray([t_s * t_s], jnp.float32)
    out_shape = (
        jax.ShapeDtypeStruct((n, 1), jnp.float32),
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
        jax.ShapeDtypeStruct((n // NB, 1), jnp.int32),
    )
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((NB, FB), lambda nb, fb: (nb, fb)),
            pl.BlockSpec((NB, FB), lambda nb, fb: (nb, fb)),
            pl.BlockSpec((NB, 1), lambda nb, fb: (nb, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=(
            pl.BlockSpec((NB, 1), lambda nb, fb: (nb, 0)),
            pl.BlockSpec((NB, 1), lambda nb, fb: (nb, 0)),
            pl.BlockSpec((1, 1), lambda nb, fb: (nb, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(x, x_inf, active, ts2)
