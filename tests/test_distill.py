"""Inception Distillation tests — the Table 6 claim at reduced scale:
distillation improves the weakest classifier f^(1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import (DistillConfig, GNNConfig, evaluate_classifier,
                       load_dataset, train_nai)
from repro.gnn.distill import _fit, _tc
from repro.gnn.graph import propagated_series
from repro.gnn.models import apply_classifier, init_classifiers
from repro.core.inception_distill import hard_ce


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("flickr-like", scale=0.02, seed=0)
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=3,
                    hidden=32, mlp_layers=2, dropout=0.0)
    series = np.stack(propagated_series(g, g.features, cfg.k))
    return g, cfg, series


def _train_f1_no_distill(cfg, g, series, epochs=120):
    """f^(1) trained with hard labels only (the 'w/o ID' row of Table 6)."""
    params = init_classifiers(cfg, jax.random.PRNGKey(0))[1]
    feats_vl = jnp.asarray(series[:, g.train_idx])
    y = jnp.asarray(g.labels[g.train_idx])

    def loss(p, rng):
        return hard_ce(apply_classifier(cfg, p, feats_vl, 1, key=rng), y)

    params, _ = _fit(loss, params, epochs,
                     _tc(DistillConfig()), jax.random.PRNGKey(1))
    return params


def test_distillation_improves_f1(setup):
    g, cfg, series = setup
    base = _train_f1_no_distill(cfg, g, series)
    acc_no_id = evaluate_classifier(cfg, base, series, g.labels, g.test_idx, 1)

    dc = DistillConfig(epochs_base=120, epochs_offline=80, epochs_online=80)
    params, _ = train_nai(cfg, g, dc)
    acc_id = evaluate_classifier(cfg, params["cls"][1], series, g.labels,
                                 g.test_idx, 1)
    # Table 6: ID should not hurt, and usually helps, the weakest student
    assert acc_id >= acc_no_id - 0.01, (acc_id, acc_no_id)


def test_all_orders_trained(setup):
    g, cfg, series = setup
    dc = DistillConfig(epochs_base=80, epochs_offline=40, epochs_online=40)
    params, info = train_nai(cfg, g, dc)
    assert set(params["cls"]) == {1, 2, 3}
    for l in range(1, 4):
        acc = evaluate_classifier(cfg, params["cls"][l], series, g.labels,
                                  g.test_idx, l)
        assert acc > 1.5 / cfg.num_classes, (l, acc)  # far above chance
    assert "online_loss" in info and np.isfinite(info["online_loss"])


@pytest.mark.slow
@pytest.mark.parametrize("base_model", ["s2gc", "sign", "gamlp"])
def test_generalization_to_other_base_models(base_model):
    """Table 7: NAI applies to any linear-propagation GNN."""
    g = load_dataset("pubmed-like", scale=0.04, seed=1)
    cfg = GNNConfig(base_model, g.features.shape[1], g.num_classes, k=3,
                    hidden=24, mlp_layers=2, dropout=0.0)
    dc = DistillConfig(epochs_base=60, epochs_offline=30, epochs_online=30)
    params, _ = train_nai(cfg, g, dc)
    series = np.stack(propagated_series(g, g.features, cfg.k))
    acc = evaluate_classifier(cfg, params["cls"][cfg.k], series, g.labels,
                              g.test_idx, cfg.k)
    assert acc > 1.5 / cfg.num_classes, acc
