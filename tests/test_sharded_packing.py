"""Sharded packing is a pure partition + permutation of single-device
packing: same tiles (bitwise), each landing on exactly one shard, row
order moved by the shard-major superblock round-robin — so per-shard
SpMM over the gathered frontier reassembles to the single-device kernel
output BIT-exactly (no multi-device runtime needed: shards are plain
slices of the leading axis)."""
import numpy as np
import pytest

from repro.gnn import load_dataset
from repro.gnn.nai import support_stationary_factors
from repro.gnn.packing import (CB, RB, batch_bucket, pack_support,
                               shard_batch_perm, shard_block_perm,
                               shard_row_perm)
from repro.gnn.sampler import sample_support
from repro.gnn.store import as_store


@pytest.fixture(scope="module")
def graph():
    return load_dataset("pubmed-like", scale=0.03, seed=1)


def _packs(g, batch_size, seed, n_shards, **kw):
    """(sharded, single-device-with-identical-geometry) pack pair."""
    rng = np.random.default_rng(seed)
    batch = rng.choice(g.test_idx, size=batch_size, replace=False)
    sup = sample_support(as_store(g), batch, 2, 0.5)
    x0 = g.features[sup.nodes][:, :64].astype(np.float32)
    c, s = support_stationary_factors(g, sup, x0, 0.5)
    c, s = c.astype(np.float32), s.astype(np.float32)
    x_inf = c[:, None] * s[None, :]
    sh = pack_support(sup, x0, x_inf, n_shards=n_shards,
                      x_inf_factors=(c, s), **kw)
    base = pack_support(sup, x0, x_inf, nb_bucket=sh.n_batch,
                        s_bucket=sh.n_pad, tb_bucket=sh.tiles.shape[1],
                        x_inf_factors=(c, s), **kw)
    assert (base.n_pad, base.n_batch) == (sh.n_pad, sh.n_batch)
    return sup, sh, base


def _rb_perm(n_pad, n_shards):
    """Original row block -> packed row block (blocks move in CB-sized
    groups of CB//RB)."""
    spb = CB // RB
    rb = np.arange(n_pad // RB)
    return shard_block_perm(n_pad // CB, n_shards)[rb // spb] * spb \
        + rb % spb


def _check_partition(sup, sh, base):
    D = sh.n_shards
    rbp = _rb_perm(sh.n_pad, D)
    cbp = shard_block_perm(sh.n_pad // CB, D)
    rowp = shard_row_perm(sh.n_pad, D)

    # tiles are the SAME tiles (bitwise), row-block axis permuted, column
    # ids remapped to packed superblocks — slot order untouched
    np.testing.assert_array_equal(sh.tiles[rbp], base.tiles)
    np.testing.assert_array_equal(sh.valid[rbp], base.valid)
    np.testing.assert_array_equal(
        np.where(base.valid == 1, sh.tile_col[rbp], 0),
        np.where(base.valid == 1, cbp[base.tile_col], 0))
    # every real tile lands on exactly one shard (row blocks partition)
    n_rb_loc = sh.n_rb // D
    per_shard = [int(sh.valid[s * n_rb_loc:(s + 1) * n_rb_loc].sum())
                 for s in range(D)]
    assert sum(per_shard) == int(base.valid.sum())

    # rows, hops, batch-region operands follow their permutations
    np.testing.assert_array_equal(sh.x0[rowp], base.x0)
    np.testing.assert_array_equal(sh.hop_rb[rbp], base.hop_rb)
    bp = shard_batch_perm(sh.n_batch, D)
    np.testing.assert_array_equal(sh.x_inf[bp], base.x_inf)
    np.testing.assert_array_equal(sh.c_inf[bp], base.c_inf)
    np.testing.assert_array_equal(sh.s_inf, base.s_inf)

    # batch rows sit at the FRONT of every shard's row range, in both
    # the full row space and the batch-only space (what lets shard_map
    # slice exits/series with a plain contiguous spec)
    nb_loc, rows_loc = sh.n_batch // D, sh.n_pad // D
    r = np.arange(sh.n_batch)
    np.testing.assert_array_equal(rowp[r] // rows_loc, bp // nb_loc)
    np.testing.assert_array_equal(rowp[r] % rows_loc, bp % nb_loc)


def test_sharded_pack_is_permuted_partition(graph):
    for D, bs, seed in ((2, 37, 0), (4, 24, 1), (8, 16, 2), (3, 40, 3)):
        sup, sh, base = _packs(graph, bs, seed, D)
        _check_partition(sup, sh, base)


def test_sharded_pack_hypothesis(graph):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(bs=st.integers(4, 48), seed=st.integers(0, 31),
           D=st.sampled_from([2, 4]))
    def prop(bs, seed, D):
        sup, sh, base = _packs(graph, bs, seed, D)
        _check_partition(sup, sh, base)

    prop()


def test_sharded_edges_partition(graph):
    """Segment-path edge arrays: every original edge appears on exactly
    one shard (the one owning its destination row), same coefficient,
    original relative order preserved within the shard."""
    for D in (2, 4):
        sup, sh, base = _packs(graph, 30, 5, D, build_tiles=False)
        rowp = shard_row_perm(sh.n_pad, D)
        rows_loc = sh.n_pad // D
        got = []
        for s in range(D):
            real = sh.coef[s] != 0.0
            gdst = sh.dst[s][real] + s * rows_loc   # local -> packed
            assert (gdst // rows_loc == s).all()
            got.append(np.stack([sh.src[s][real], gdst,
                                 sh.coef[s][real]]))
        got = np.concatenate(got, axis=1)
        real_b = base.coef != 0.0
        want = np.stack([rowp[base.src[real_b]], rowp[base.dst[real_b]],
                         base.coef[real_b]])
        # same multiset of (packed src, packed dst, coef)
        assert got.shape == want.shape
        order_g = np.lexsort(got)
        order_w = np.lexsort(want)
        np.testing.assert_array_equal(got[:, order_g], want[:, order_w])
        # per-destination-row contribution order is the original edge
        # order (what keeps sharded segment-sum accumulation identical)
        for s in range(D):
            real = sh.coef[s] != 0.0
            assert (np.diff(np.flatnonzero(real)) > 0).all()


def test_sharded_spmm_reassembles_bit_equal(graph):
    """Slice each shard's tiles, run the kernel against the permuted
    frontier, concatenate, un-permute: bitwise equal to the
    single-device kernel output."""
    import jax.numpy as jnp
    from repro.kernels.spmm import spmm_block_ell

    for D in (2, 4):
        sup, sh, base = _packs(graph, 37, 7, D)
        out_base = np.asarray(spmm_block_ell(
            jnp.asarray(base.tiles), jnp.asarray(base.tile_col),
            jnp.asarray(base.valid), jnp.ones(base.n_rb, jnp.int32),
            jnp.asarray(base.x0), interpret=True))
        n_rb_loc = sh.n_rb // D
        parts = []
        for s in range(D):
            sl = slice(s * n_rb_loc, (s + 1) * n_rb_loc)
            parts.append(np.asarray(spmm_block_ell(
                jnp.asarray(sh.tiles[sl]), jnp.asarray(sh.tile_col[sl]),
                jnp.asarray(sh.valid[sl]),
                jnp.ones(n_rb_loc, jnp.int32),
                jnp.asarray(sh.x0), interpret=True)))
        out_sh = np.concatenate(parts, axis=0)
        rowp = shard_row_perm(sh.n_pad, D)
        np.testing.assert_array_equal(out_sh[rowp], out_base)


def _halo_packs(g, batch_size, seed, n_shards, **kw):
    """(dense-sharded, halo-sharded) pack pair with identical geometry
    (the halo pack pins the dense pack's buckets, so tiles/valid/rows are
    byte-identical and only the coordinate systems differ)."""
    rng = np.random.default_rng(seed)
    batch = rng.choice(g.test_idx, size=batch_size, replace=False)
    sup = sample_support(as_store(g), batch, 2, 0.5)
    x0 = g.features[sup.nodes][:, :64].astype(np.float32)
    x_inf = np.zeros((sup.n_batch, 64), np.float32)
    dense = pack_support(sup, x0, x_inf, n_shards=n_shards, **kw)
    halo = pack_support(sup, x0, x_inf, n_shards=n_shards, halo=True,
                        nb_bucket=dense.n_batch, s_bucket=dense.n_pad,
                        tb_bucket=dense.tiles.shape[1],
                        e_bucket=dense.src.shape[-1], **kw)
    assert (halo.n_pad, halo.n_batch) == (dense.n_pad, dense.n_batch)
    return dense, halo


def _check_halo_cover(dense, halo):
    """Every shard's halo frame is EXACTLY the sorted union of the global
    CB blocks its tiles/edges reference: no missing block (coverage), no
    dead entry (minimality); frame-local coordinates round-trip to the
    dense pack's global ones; the all_to_all send/recv plan reassembles
    each frame."""
    D = halo.n_shards
    n_cb = halo.n_pad // CB
    n_cb_loc = n_cb // D
    bpad = halo.halo_send_pad
    has_tiles = dense.tiles.shape[1] > 0
    has_edges = dense.src.shape[-1] > 0 and dense.coef.size
    if has_tiles:
        np.testing.assert_array_equal(halo.tiles, dense.tiles)
        np.testing.assert_array_equal(halo.valid, dense.valid)
    n_rb_loc = halo.n_rb // D
    rows_loc = halo.n_pad // D
    for s in range(D):
        c = int(halo.halo_count[s])
        full_frame = (halo.halo_src_shard[s].astype(np.int64) * n_cb_loc
                      + halo.halo_src_block[s])
        frame = full_frame[:c]
        # frames are strictly sorted global block ids (grouped by owner)
        assert (np.diff(frame) > 0).all(), s
        assert c <= n_cb and halo.n_halo_pad >= c
        referenced = []
        if has_tiles:
            sl = slice(s * n_rb_loc, (s + 1) * n_rb_loc)
            v = dense.valid[sl] == 1
            referenced.append(dense.tile_col[sl][v])
            # frame-local tile_col maps back to the dense global blocks
            np.testing.assert_array_equal(
                full_frame[halo.tile_col[sl][v]], dense.tile_col[sl][v])
        if has_edges:
            real = dense.coef[s] != 0.0
            referenced.append(dense.src[s][real] // CB)
            src_h = halo.src[s][real].astype(np.int64)
            np.testing.assert_array_equal(
                full_frame[src_h // CB] * CB + src_h % CB,
                dense.src[s][real])
            # padding edges stay inside the frame
            assert halo.src[s].max() < halo.n_halo_pad * CB
        want = np.unique(np.concatenate(referenced))
        # coverage AND minimality in one shot
        np.testing.assert_array_equal(frame, want)
        # the exchange plan reassembles the frame: sender t's list to s
        # holds exactly s's frame entries owned by t, in frame order
        recv = (np.arange(D, dtype=np.int64)[:, None] * n_cb_loc
                + halo.halo_send_block[:, s, :])        # (D, B_pad) global
        np.testing.assert_array_equal(
            recv.reshape(-1)[halo.halo_frame_src[s, :c]], frame)
        assert bpad == halo.halo_send_block.shape[2]
    # every send-list slot is a legal local block id
    assert halo.halo_send_block.min() >= 0
    assert halo.halo_send_block.max() < max(n_cb_loc, 1)
    assert rows_loc % CB == 0


def test_halo_frame_covers_tile_cols(graph):
    for D, bs, seed in ((2, 37, 0), (4, 24, 1), (8, 16, 2), (3, 40, 3)):
        dense, halo = _halo_packs(graph, bs, seed, D)
        _check_halo_cover(dense, halo)
    # segment-path (edges-only) packs get the same guarantee
    for D in (2, 4):
        dense, halo = _halo_packs(graph, 30, 5, D, build_tiles=False)
        _check_halo_cover(dense, halo)


def test_halo_frame_hypothesis(graph):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(bs=st.integers(4, 48), seed=st.integers(0, 31),
           D=st.sampled_from([2, 4]))
    def prop(bs, seed, D):
        dense, halo = _halo_packs(graph, bs, seed, D)
        _check_halo_cover(dense, halo)

    prop()


def test_halo_shrinks_frame_on_padded_batches(graph):
    """The batch region pads to CB*D, so pure-padding superblocks exist
    and are never referenced — the halo frame must be strictly smaller
    than the dense frontier here (the --check guarantee)."""
    for D in (2, 4):
        _, halo = _halo_packs(graph, 24, 9, D)
        assert halo.halo_frac < 1.0, (D, halo.halo_frac)
        assert halo.halo_rows <= halo.n_halo_pad * CB <= halo.n_pad


def test_batch_bucket_alignment():
    assert batch_bucket(32) == 32            # RB-aligned single-device
    assert batch_bucket(32, 2) == CB * 2     # CB*D-aligned sharded
    assert batch_bucket(500, 4) == 512
    assert batch_bucket(CB * 4 + 1, 4) % (CB * 4) == 0


def test_sharded_bucket_floor_validation(graph):
    rng = np.random.default_rng(0)
    batch = rng.choice(graph.test_idx, size=16, replace=False)
    sup = sample_support(as_store(graph), batch, 2, 0.5)
    x0 = graph.features[sup.nodes][:, :64].astype(np.float32)
    x_inf = np.zeros((sup.n_batch, 64), np.float32)
    with pytest.raises(ValueError):
        pack_support(sup, x0, x_inf, n_shards=2,
                     s_bucket=CB * 3)            # not a CB*2 multiple
    with pytest.raises(ValueError):
        pack_support(sup, x0, x_inf, n_shards=2, nb_bucket=CB * 5)
