"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q, k, v: (BH, S, hd)."""
    BH, S, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, v.astype(jnp.float32)).astype(q.dtype)
