"""The compiled (masked) NAP path must agree with the host serving path."""
import jax.numpy as jnp
import numpy as np

from repro.gnn import GNNConfig, load_dataset
from repro.gnn.nai import NAIConfig, infer_batch_masked, _subgraph_spmm
from repro.gnn.sampler import sample_support
from repro.gnn.store import as_store


def _setup(tmax=3):
    g = load_dataset("pubmed-like", scale=0.05, seed=4)
    cfg = GNNConfig("sgc", g.features.shape[1], g.num_classes, k=tmax)
    batch = g.test_idx[:64]
    sup = sample_support(as_store(g), batch, tmax, cfg.r)
    x0 = g.features[sup.nodes].astype(np.float32)
    dt = (g.degrees[sup.nodes] + 1).astype(np.float64)
    denom = 2.0 * sup.sub_edges + len(sup)
    s_sum = ((dt ** 0.5)[:, None] * x0).sum(0)
    x_inf = ((dt[:sup.n_batch] ** 0.5) / denom)[:, None] * s_sum[None, :]
    return g, cfg, sup, x0, x_inf.astype(np.float32)


def test_masked_matches_host_propagation():
    g, cfg, sup, x0, x_inf = _setup()
    nai = NAIConfig(t_s=18.0, t_min=1, t_max=3)
    orders, series = infer_batch_masked(
        cfg, nai, None, jnp.asarray(sup.src), jnp.asarray(sup.dst),
        jnp.asarray(sup.coef), jnp.asarray(x0), jnp.asarray(x_inf),
        sup.n_batch)
    # the stacked history carries batch rows only (classification never
    # reads support rows; the (S, f) state stays inside the loop)
    assert series.shape == (nai.t_max + 1, sup.n_batch, x0.shape[1])
    # propagated batch-row features match the host subgraph SpMM at every
    # order
    xh = x0.copy()
    needed = np.ones(len(sup), bool)
    for l in range(1, 4):
        xh, _ = _subgraph_spmm(sup, xh, needed)
        np.testing.assert_allclose(np.asarray(series[l]),
                                   xh[:sup.n_batch], rtol=2e-4, atol=2e-4)
    o = np.asarray(orders)
    assert o.min() >= 1 and o.max() <= 3


def test_masked_exit_orders_match_distances():
    g, cfg, sup, x0, x_inf = _setup()
    nai = NAIConfig(t_s=18.0, t_min=1, t_max=3)
    orders, series = infer_batch_masked(
        cfg, nai, None, jnp.asarray(sup.src), jnp.asarray(sup.dst),
        jnp.asarray(sup.coef), jnp.asarray(x0), jnp.asarray(x_inf),
        sup.n_batch)
    o = np.asarray(orders)
    for l in (1, 2):
        d = np.linalg.norm(np.asarray(series[l]) - x_inf, axis=1)
        exited_here = o == l
        # anyone who exited at l crossed the threshold at l but not earlier
        assert (d[exited_here] < nai.t_s).all()
    # nodes that never crossed land at t_max
    d1 = np.linalg.norm(np.asarray(series[1]) - x_inf, axis=1)
    d2 = np.linalg.norm(np.asarray(series[2]) - x_inf, axis=1)
    never = (d1 >= nai.t_s) & (d2 >= nai.t_s)
    assert (o[never] == 3).all()
