"""Backend-based sharded propagation vs the host series, on a small faked
multi-device mesh (this file forces 8 host devices; keep it isolated).

The retired dense shard_map SpMM's numeric oracle survives: every
registered PropagationBackend, run sharded over the mesh's data axis via
`distributed_series`, must reproduce `propagated_series` on the host.
On top of that the sharded runs must be BIT-identical to a single-device
run of the same packed geometry (the superblock round-robin partition
preserves tile contents and accumulation order exactly)."""
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.gnn import load_dataset, propagated_series
from repro.gnn.backends import BACKENDS
from repro.gnn.distributed import (distributed_nap_distances,
                                   distributed_series, pack_graph)

mesh = jax.make_mesh((4, 2), ("data", "model"))
g = load_dataset("pubmed-like", scale=0.02, seed=0)
k = 3
host = propagated_series(g, g.features, k)

# pin the packing geometry of the widest shard count so every run
# (including single-device) packs bit-identical tiles
_, ref_packed = pack_graph(g, 4, spmm_impl="block_ell")
geom = dict(nb_bucket=ref_packed.n_batch, s_bucket=ref_packed.n_pad,
            tb_bucket=ref_packed.tiles.shape[1])

# numeric oracle (inherited from the dense path): every backend, sharded,
# agrees with the host propagation series
by_impl = {}
for impl in sorted(BACKENDS):
    dist = distributed_series(mesh, g, k, spmm_impl=impl, **geom)
    by_impl[impl] = [np.asarray(d) for d in dist]
    for l in range(k + 1):
        err = np.abs(by_impl[impl][l] - host[l]).max()
        assert err < 2e-3, (impl, l, err)

# bit-parity oracle: 4-shard == 2-shard == single-device, same geometry
mesh2 = jax.make_mesh((2, 2), ("data", "model"))
mesh1 = jax.make_mesh((1, 2), ("data", "model"))
for impl in ("block_ell", "fused", "segment"):
    d4 = by_impl[impl]
    for m in (mesh2, mesh1):
        dm = distributed_series(m, g, k, spmm_impl=impl, **geom)
        for l in range(k + 1):
            assert np.array_equal(np.asarray(dm[l]), d4[l]), \
                (impl, m.shape, l)

# NAP distance helper (feature-axis psum) agrees with numpy (rows padded
# to the data axis; the series is returned unpadded)
x = by_impl["segment"][k]
n_pad = -(-g.n // 4) * 4
xp = np.zeros((n_pad, x.shape[1]), np.float32)
xp[:g.n] = x
dd = np.asarray(distributed_nap_distances(mesh, jnp.asarray(xp),
                                          jnp.asarray(np.zeros_like(xp))))
ref = np.linalg.norm(x, axis=1)
assert np.abs(dd[:g.n] - ref).max() < 2e-2, np.abs(dd[:g.n] - ref).max()
print("DISTRIBUTED_OK")
"""


def test_distributed_propagation_matches_host():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=480)
    assert "DISTRIBUTED_OK" in out.stdout, out.stdout + out.stderr
