from repro.gnn.graph import Graph, propagated_series, stationary_weights
from repro.gnn.datasets import load_dataset, PRESETS
from repro.gnn.models import GNNConfig, apply_classifier, init_classifiers
from repro.gnn.distill import DistillConfig, train_nai, evaluate_classifier
from repro.gnn.nai import (NAIConfig, NAIResult, accuracy, infer_all,
                           order_distribution)

__all__ = [
    "Graph", "propagated_series", "stationary_weights", "load_dataset",
    "PRESETS", "GNNConfig", "apply_classifier", "init_classifiers",
    "DistillConfig", "train_nai", "evaluate_classifier", "NAIConfig",
    "NAIResult", "accuracy", "infer_all", "order_distribution",
]
