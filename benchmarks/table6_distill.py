"""Table 6: Inception Distillation ablation — accuracy of the weakest
classifier f^(1) under {no ID, offline-only, online-only, full ID}."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_row, dataset
from repro.core.inception_distill import hard_ce
from repro.gnn import DistillConfig, GNNConfig, evaluate_classifier, train_nai
from repro.gnn.distill import _fit, _tc
from repro.gnn.graph import propagated_series
from repro.gnn.models import apply_classifier, init_classifiers

DATASETS = ["pubmed-like", "flickr-like", "arxiv-like", "products-like"]


def _cfg(g):
    return GNNConfig("sgc", g.features.shape[1], g.num_classes, k=3,
                     hidden=64, mlp_layers=2, dropout=0.0)


def _f1_no_id(cfg, g, series, epochs=150):
    params = init_classifiers(cfg, jax.random.PRNGKey(0))[1]
    import jax.numpy as jnp
    feats_vl = jnp.asarray(series[:, g.train_idx])
    y = jnp.asarray(g.labels[g.train_idx])

    def loss(p, rng):
        return hard_ce(apply_classifier(cfg, p, feats_vl, 1, key=rng), y)

    params, _ = _fit(loss, params, epochs, _tc(DistillConfig()),
                     jax.random.PRNGKey(1))
    return params


def run(datasets=DATASETS) -> list:
    rows = []
    for name in datasets:
        g = dataset(name)
        cfg = _cfg(g)
        series = np.stack(propagated_series(g, g.features, cfg.k))

        variants = {
            "wo_ID": None,
            "wo_ON": DistillConfig(epochs_base=150, epochs_offline=80,
                                   epochs_online=0),
            "wo_OFF": DistillConfig(epochs_base=150, epochs_offline=0,
                                    epochs_online=80),
            "full": DistillConfig(epochs_base=150, epochs_offline=80,
                                  epochs_online=80),
        }
        for tag, dc in variants.items():
            if dc is None:
                p1 = _f1_no_id(cfg, g, series)
            else:
                params, _ = train_nai(cfg, g, dc)
                p1 = params["cls"][1]
            acc = evaluate_classifier(cfg, p1, series, g.labels,
                                      g.test_idx, 1)
            rows.append(csv_row(f"table6/{name}/{tag}", 0.0,
                                f"f1_acc={acc:.4f}"))
    return rows
