"""GraphStore — streaming graph storage behind the sampler.

Everything upstream of this module (sampler, packer, serving engine)
used to assume the whole graph lives in one in-memory numpy CSR owned by
a `Graph`. That assumption caps the servable graph at host RAM and is
exactly what the paper's headline setting (ogbn-products, ~2.4M nodes,
124M edges) breaks. The fix is the InferTurbo/DGI premise: the compute
engine consumes graph storage through a NARROW VIEW INTERFACE it does
not own, so the storage layer is free to be an in-RAM array today and a
memory-mapped file (or a remote shard) tomorrow without the engine
noticing.

The interface (`GraphStore`) is three zero-copy array views plus
build-time metadata:

* ``row_ptr`` (n+1,) int64 / ``col_idx`` (E,) int32 — the in-neighbor
  CSR the frontier sampler walks (row i lists the sources j of edges
  j -> i; each node's self loop is stored in its row);
* ``features`` (n, f) float32 — node features, gathered row-wise
  (`gather_features`) so only the rows a batch's support touches are
  ever materialized;
* ``degrees`` (n,) int64 and the ``num_edges`` / ``num_self_loops``
  scalars — the self-loop/degree accounting fixed in PR 6, computed
  ONCE when the store is built and persisted as metadata instead of
  being recounted O(E) on every batch.

Two implementations:

* `InMemoryStore` — wraps today's `Graph` bit-identically (same CSR
  arrays, same features); the degree metadata is cached at
  construction.
* `MmapStore` — a directory of ``.npy`` files: the CSR views open
  lazily with ``np.load(mmap_mode="r")`` and are NEVER copied wholesale
  into RAM, while feature row gathers bypass the mapping entirely
  (``preadv`` into the output array — the page cache absorbs locality
  and is not charged to the process), so host residency scales with the
  working set (supports actually sampled), not the graph.

`make_graph(n, avg_deg, alpha, seed)` generates a synthetic power-law
graph at 1e5–1e7-node scale straight to disk (fixed-size chunks, one
`np.random.Generator`, deterministic under seed) so CI and the
``serving_bench --graph-scale`` sweep exercise the shape without a
dataset download. The module is runnable —

    python -m repro.gnn.store --n 1000000 --avg-deg 16 --out /tmp/g1m

— which is how the benchmark generates graphs in a SUBPROCESS, keeping
the serving process's peak RSS an honest measure of what serving (not
generation) touches.

On-disk layout (format ``repro-graphstore-v1``)::

    store_dir/
      meta.json       n, feat_dim, num_classes, num_edges,
                      num_self_loops, name, generator params
      row_ptr.npy     (n+1,) int64   CSR row pointers
      col_idx.npy     (E,)   int32   in-neighbor ids (self loop in-row)
      features.npy    (n, f) float32
      degrees.npy     (n,)   int64   degree WITHOUT self loop
      labels.npy      (n,)   int32   optional
"""
from __future__ import annotations

import json
import mmap as _mmap
import os
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

# madvise is Linux/py3.8+; elsewhere MmapStore still works, just without
# the bounded-residency guarantees (RSS then includes readahead pages).
_HAVE_MADVISE = hasattr(_mmap, "MADV_RANDOM") and hasattr(_mmap,
                                                          "MADV_DONTNEED")

from repro.gnn.graph import Graph

FORMAT = "repro-graphstore-v1"

_ARRAYS = ("row_ptr", "col_idx", "features", "degrees", "labels")

# Granularity of mutation versioning: one version counter per
# VERSION_BLOCK consecutive node ids. Deliberately equal to the SpMM
# kernel's CB (repro.kernels.spmm.kernel.CB) — the column-block /
# superblock granularity the packer, the halo exchange, and the sharded
# row partition already speak — so propagated-feature cache invalidation
# (repro.gnn.propcache) is block-granular in exactly the units the rest
# of the serving stack is built around. Pinned by tests.
VERSION_BLOCK = 128


class StoreError(Exception):
    """Base class for typed storage failures. Catching this (rather than
    bare IOError/ValueError) is how upstream layers distinguish "the
    storage tier failed" from their own bugs."""


class StoreIOError(StoreError, OSError):
    """A read against the backing files failed (short read / OS error)
    and did not recover within the bounded retry budget. Transient by
    nature — the engine may retry the batch on another path."""


class StoreCorruption(StoreError, ValueError):
    """The bytes on disk do not match the build-time metadata (checksum
    or shape mismatch). NOT transient: retrying the same store cannot
    help, the store must be rebuilt."""


def _file_crc32(path: str, chunk: int = 8 << 20) -> str:
    """crc32 of a whole file, chunked so graph-scale arrays never
    materialize in RAM. Hex string, zero-padded (JSON-friendly)."""
    crc = 0
    with open(path, "rb") as fh:
        while True:
            buf = fh.read(chunk)
            if not buf:
                break
            crc = zlib.crc32(buf, crc)
    return f"{crc & 0xFFFFFFFF:08x}"


def _store_checksums(path: str) -> Dict[str, str]:
    """Checksums of every array file present in a store directory,
    computed at BUILD time and persisted in meta.json — verification at
    open/demand compares against these, so corruption is detected as a
    typed error instead of surfacing as garbage predictions."""
    out = {}
    for key in _ARRAYS:
        p = os.path.join(path, f"{key}.npy")
        if os.path.exists(p):
            out[f"{key}.npy"] = _file_crc32(p)
    return out


class GraphStore:
    """The narrow storage interface the sampler/packer/engine consume.

    Subclasses provide ``row_ptr`` / ``col_idx`` / ``features`` /
    ``degrees`` properties returning array views (ndarray or np.memmap)
    plus the build-time scalars. Nothing here may copy an O(n) or O(E)
    array: views in, row gathers out.
    """

    name: str = "store"
    n: int = 0
    feat_dim: int = 0
    num_classes: int = 0
    num_edges: int = 0        # undirected count m (paper's 2m+n uses it)
    num_self_loops: int = 0

    # -- array views (subclass responsibility)
    @property
    def row_ptr(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def col_idx(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def features(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def degrees(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def labels(self) -> Optional[np.ndarray]:
        return None

    # -- derived API shared by all implementations
    def csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """(row_ptr, col_idx) — the view pair the frontier sampler walks."""
        return self.row_ptr, self.col_idx

    def gather_features(self, nodes: np.ndarray) -> np.ndarray:
        """Features at `nodes`, materialized as a fresh (len(nodes), f)
        ndarray. On a memmap this reads only the touched rows' pages —
        the support-sized working set, never the full matrix."""
        return np.asarray(self.features[nodes])

    def coo(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) int32 edge list in CSR order (dst-major) — derived
        from the views; used by full-graph packing (`graph_as_support`),
        which is O(E) by definition."""
        row_ptr = self.row_ptr
        counts = np.diff(row_ptr).astype(np.int64)
        dst = np.repeat(np.arange(self.n, dtype=np.int64),
                        counts).astype(np.int32)
        return np.asarray(self.col_idx, np.int32), dst

    def edge_coefficients(self, r: float = 0.5) -> np.ndarray:
        """Per-edge Â weight in CSR order: coef(j->i) =
        (d_i+1)^{r-1} (d_j+1)^{-r}, from the persisted degrees."""
        src, dst = self.coo()
        dt = (np.asarray(self.degrees) + 1).astype(np.float64)
        return (dt[dst] ** (r - 1.0) * dt[src] ** (-r)).astype(np.float32)

    def drop_resident(self) -> int:
        """Release any resident file-backed pages (no-op for in-RAM
        stores). Returns the estimated bytes released."""
        return 0

    # -- graph mutation (the inductive setting: the graph grows while
    # the engine serves). Mutations are copy-on-write: the first one
    # materializes private CSR/degree arrays (`_materialize_mutable`),
    # after which the store no longer reads the wrapped Graph / the
    # on-disk files for those views. Every mutation bumps a monotone
    # `mutation_clock` and stamps the VERSION_BLOCK-granular
    # `block_versions` of exactly the touched node blocks — what the
    # propagated-feature cache (repro.gnn.propcache) validates against.
    @property
    def mutation_clock(self) -> int:
        """Monotone store-wide mutation counter (0 = never mutated)."""
        return self.__dict__.get("_mut_clock", 0)

    @property
    def block_versions(self) -> np.ndarray:
        """(ceil(n / VERSION_BLOCK),) int64 — the mutation_clock value at
        which each node block was last touched (0 = never). Grows with
        `add_nodes`; existing stamps keep their positions because node
        ids are append-only."""
        bv = self.__dict__.get("_block_versions")
        n_blocks = max(-(-self.n // VERSION_BLOCK), 1)
        if bv is None or len(bv) < n_blocks:
            grown = np.zeros(n_blocks, np.int64)
            if bv is not None:
                grown[:len(bv)] = bv
            self.__dict__["_block_versions"] = bv = grown
        return bv

    def _stamp_blocks(self, nodes: np.ndarray) -> int:
        """Bump the clock and stamp the blocks containing `nodes`.
        Stamping ONLY the touched endpoints' blocks is sound for the
        propagated-feature cache because a cached X^(l)[v] depends only
        on x0 / degrees / CSR rows of nodes the fill support contained —
        and those dependency blocks are recorded per fill, so any stamp
        on one of them invalidates the entry (see repro.gnn.propcache)."""
        clock = self.mutation_clock + 1
        self.__dict__["_mut_clock"] = clock
        blocks = np.unique(np.asarray(nodes, np.int64) // VERSION_BLOCK)
        self.block_versions[blocks] = clock
        return clock

    def _mutable(self) -> Dict[str, Optional[np.ndarray]]:
        own = self.__dict__.get("_own")
        if own is None:
            own = self._materialize_mutable()
            self.__dict__["_own"] = own
        return own

    def _materialize_mutable(self) -> Dict[str, Optional[np.ndarray]]:
        raise NotImplementedError(
            f"{type(self).__name__} does not support mutation")

    def _append_features(self, feats: np.ndarray,
                         labels: np.ndarray) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not support add_nodes")

    def add_edges(self, src, dst) -> int:
        """Add undirected edges (src[i], dst[i]): each endpoint is
        appended to the other's in-neighbor CSR row (after any existing
        entries, in call order — deterministic), degrees and `num_edges`
        are updated, and the endpoints' version blocks are stamped.
        Self pairs are rejected (self loops are structural, exactly one
        per row, managed by the store build). Returns the number of
        undirected edges added.

        Copy-on-write: reads through the store see the new topology
        immediately; a wrapped `Graph` / the on-disk files keep the
        pre-mutation data (all consumers must read through the store,
        which is what `as_store` memoization guarantees)."""
        src = np.atleast_1d(np.asarray(src, np.int64))
        dst = np.atleast_1d(np.asarray(dst, np.int64))
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError(f"src/dst must be equal-length 1-D id "
                             f"arrays, got {src.shape} and {dst.shape}")
        if len(src) == 0:
            return 0
        both = np.concatenate([src, dst])
        if both.min() < 0 or both.max() >= self.n:
            raise ValueError(f"edge endpoint out of range for n={self.n}")
        if np.any(src == dst):
            raise ValueError("self pairs are not addable edges (each row "
                             "already carries exactly one self loop)")
        own = self._mutable()
        # u->v lands in row v, v->u in row u (undirected: both rows grow)
        rows = np.concatenate([dst, src])
        vals = np.concatenate([src, dst]).astype(own["col_idx"].dtype)
        pos = own["row_ptr"][rows + 1]      # end of each row, old coords
        own["col_idx"] = np.insert(own["col_idx"], pos, vals)
        counts = np.bincount(rows, minlength=self.n)
        own["row_ptr"][1:] += np.cumsum(counts)
        np.add.at(own["degrees"], rows, 1)
        self.num_edges += len(src)
        self._stamp_blocks(both)
        return len(src)

    def add_nodes(self, features, labels=None) -> np.ndarray:
        """Append new nodes, each with its self loop and no other edges
        (connect them afterwards with `add_edges`). `features` is
        (k, feat_dim); `labels` optional (k,), default -1. Returns the
        new node ids. Bumps the clock and stamps only the NEW blocks —
        existing rows/degrees are untouched, so no cached entry over the
        old graph is invalidated (exactness is preserved: an isolated
        new node changes no existing propagated value)."""
        feats = np.atleast_2d(np.asarray(features, np.float32))
        k = len(feats)
        if k == 0:
            return np.empty(0, np.int64)
        if feats.shape[1] != self.feat_dim:
            raise ValueError(f"features must be (k, {self.feat_dim}), "
                             f"got {feats.shape}")
        labs = (np.full(k, -1, np.int32) if labels is None
                else np.atleast_1d(np.asarray(labels, np.int32)))
        if labs.shape != (k,):
            raise ValueError(f"labels must be ({k},), got {labs.shape}")
        own = self._mutable()
        n0 = self.n
        new_ids = np.arange(n0, n0 + k, dtype=np.int64)
        own["row_ptr"] = np.concatenate(
            [own["row_ptr"],
             own["row_ptr"][-1] + np.arange(1, k + 1, dtype=np.int64)])
        own["col_idx"] = np.concatenate(
            [own["col_idx"], new_ids.astype(own["col_idx"].dtype)])
        own["degrees"] = np.concatenate(
            [own["degrees"], np.zeros(k, own["degrees"].dtype)])
        self._append_features(feats, labs)
        self.n = n0 + k
        self.num_self_loops += k
        # stamp only FULLY-new blocks: a shared tail block also holds
        # pre-existing nodes, and stamping it would needlessly stale
        # their cached entries while an isolated new node changes no
        # existing propagated value. The clock still bumps; wiring a
        # new node in via add_edges stamps its block like any endpoint.
        n_old_blocks = -(-n0 // VERSION_BLOCK)
        fresh = new_ids[new_ids // VERSION_BLOCK >= n_old_blocks]
        if len(fresh):
            self._stamp_blocks(fresh)
        else:
            self.__dict__["_mut_clock"] = self.mutation_clock + 1
        return new_ids

    # -- lifecycle: stores are context managers so fds/maps are released
    # deterministically (engines and benches call close(); __del__ on
    # file-backed stores is only a backstop)
    def close(self) -> None:
        """Release OS resources held by the store. No-op for in-RAM
        stores; idempotent everywhere."""

    def __enter__(self) -> "GraphStore":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(name={self.name!r}, n={self.n}, "
                f"edges={self.num_edges}, f={self.feat_dim})")


class InMemoryStore(GraphStore):
    """Zero-copy wrap of an in-RAM `Graph` — the store the whole repo
    served from before this module existed, bit-identical: `row_ptr` /
    `col_idx` ARE `Graph.csr()`'s arrays and `features` IS
    `graph.features`. The degree/self-loop accounting runs once here
    (store-build time) instead of per batch — `Graph.degrees` is an
    O(E) bincount on every access."""

    def __init__(self, graph: Graph):
        self.graph = graph
        self.name = graph.name
        self.n = graph.n
        self.feat_dim = int(graph.features.shape[1])
        self.num_classes = graph.num_classes
        # build-time metadata (PR-6 accounting: actual self loops, never
        # one-per-node)
        self.num_self_loops = graph.num_self_loops
        self.num_edges = graph.num_edges
        self._degrees = graph.degrees

    def _materialize_mutable(self):
        """First mutation: private copies of every view (the wrapped
        Graph stays at its pre-mutation topology and must no longer be
        read directly — `as_store` memoizes one store per Graph, so all
        serving consumers already read through here)."""
        rp, ci = self.graph.csr()
        labels = self.graph.labels
        return {"row_ptr": np.array(rp, np.int64),
                "col_idx": np.array(ci, np.int32),
                "degrees": np.array(self._degrees, np.int64),
                "features": np.array(self.graph.features, np.float32),
                "labels": (None if labels is None
                           else np.array(labels, np.int32))}

    def _append_features(self, feats, labs):
        own = self._mutable()
        own["features"] = np.concatenate([own["features"], feats])
        if own["labels"] is not None:
            own["labels"] = np.concatenate([own["labels"], labs])

    @property
    def row_ptr(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["row_ptr"] if own is not None else self.graph.csr()[0]

    @property
    def col_idx(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["col_idx"] if own is not None else self.graph.csr()[1]

    @property
    def features(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["features"] if own is not None else self.graph.features

    @property
    def degrees(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["degrees"] if own is not None else self._degrees

    @property
    def labels(self) -> Optional[np.ndarray]:
        own = self.__dict__.get("_own")
        return own["labels"] if own is not None else self.graph.labels


class MmapStore(GraphStore):
    """Graph storage memory-mapped from a store directory.

    Arrays open lazily with ``np.load(mmap_mode="r")`` on first access
    and stay file-backed: the full feature matrix / edge list is never
    copied into RAM, only the pages row gathers touch become resident.
    ``mmap=False`` eagerly loads everything into RAM instead (the
    in-memory reference the parity gates compare against).

    Residency is BOUNDED, not just lazy — and the hot row-gather path
    does not go through the mapping at all:

    * `gather_features` reads rows with ``preadv`` (consecutive runs
      coalesced) straight into the output array. Reads are served from
      the kernel page cache, which is NOT charged to the process, so
      feature gathers add ZERO mapped residency no matter how large the
      graph. (A memmap fancy-index cannot give that bound on modern
      kernels: the page cache holds warm files in 2 MB large folios and
      a fault PTE-maps the touched row's entire folio, so one
      support-sized gather maps nearly the whole file — MADV_RANDOM
      only disables readahead i/o and MADV_NOHUGEPAGE doesn't stop
      folio mapping either, both measured. Dropping pages after the
      fact with MADV_DONTNEED works but costs TLB shootdowns across the
      compute thread pool, ~2x batch latency in the engine.)
    * the CSR views (`row_ptr`/`col_idx`/`degrees`) stay memory-mapped
      for the sampler's random walks, advised ``MADV_RANDOM``; their
      resident pages are shed with `drop_resident` every
      ``resident_budget`` bytes of gather traffic, so even the O(E)
      views can't creep toward file size over a long serving run."""

    def __init__(self, path: str, *, mmap: bool = True,
                 resident_budget: int = 128 << 20,
                 verify: bool = False, io_retries: int = 2,
                 io_backoff_s: float = 0.005):
        self.path = os.fspath(path)
        self._mmap_mode = "r" if mmap else None
        self.resident_budget = int(resident_budget)
        self.io_retries = int(io_retries)
        self.io_backoff_s = float(io_backoff_s)
        self._touched_est = 0
        self._feat_fd = -1
        self._feat_off = 0
        self._closed = False
        meta_path = os.path.join(self.path, "meta.json")
        with open(meta_path) as fh:
            meta = json.load(fh)
        if meta.get("format") != FORMAT:
            raise ValueError(f"{meta_path}: unknown store format "
                             f"{meta.get('format')!r} (expected {FORMAT})")
        self.meta = meta
        self.name = meta.get("name", os.path.basename(self.path))
        self.n = int(meta["n"])
        self._base_n = self.n       # on-disk node count (mutations are
                                    # in-RAM overlays; files never change)
        self.feat_dim = int(meta["feat_dim"])
        self.num_classes = int(meta.get("num_classes", 0))
        self.num_edges = int(meta["num_edges"])
        self.num_self_loops = int(meta["num_self_loops"])
        self._views = {}
        if verify:
            self.verify()

    def _check_open(self) -> None:
        if self._closed:
            raise ValueError(f"MmapStore({self.path!r}) is closed")

    def verify(self, arrays: Optional[Tuple[str, ...]] = None) -> List[str]:
        """Recompute file checksums and compare against the build-time
        values in meta.json. Raises `StoreCorruption` on the first
        mismatch; returns the list of array names actually verified
        (arrays without a recorded checksum — pre-checksum stores — are
        skipped, so old store dirs stay readable)."""
        self._check_open()
        recorded = self.meta.get("checksums", {})
        verified = []
        for key in (arrays if arrays is not None else _ARRAYS):
            fname = f"{key}.npy"
            want = recorded.get(fname)
            p = os.path.join(self.path, fname)
            if want is None or not os.path.exists(p):
                continue
            got = _file_crc32(p)
            if got != want:
                raise StoreCorruption(
                    f"{p}: checksum mismatch (stored {want}, file {got})"
                    f" — store is corrupt, rebuild it")
            verified.append(key)
        return verified

    def _expected_shape(self, key: str) -> Optional[Tuple[int, ...]]:
        """Build-time shape of an array view, from meta.json scalars —
        a cheap corruption check that needs no file reads beyond the
        .npy header (col_idx length comes from row_ptr's last slot)."""
        base_n = getattr(self, "_base_n", self.n)
        if key == "row_ptr":
            return (base_n + 1,)
        if key == "features":
            return (base_n, self.feat_dim)
        if key in ("degrees", "labels"):
            return (base_n,)
        if key == "col_idx":
            return (int(self._load("row_ptr")[-1]),)
        return None

    def _load(self, key: str) -> Optional[np.ndarray]:
        self._check_open()
        if key not in self._views:
            p = os.path.join(self.path, f"{key}.npy")
            if not os.path.exists(p):
                if key == "labels":
                    self._views[key] = None
                    return None
                raise FileNotFoundError(f"store {self.path} missing {p}")
            arr = np.load(p, mmap_mode=self._mmap_mode)
            self._views[key] = arr
            want = self._expected_shape(key)
            if want is not None and tuple(arr.shape) != want:
                del self._views[key]
                raise StoreCorruption(
                    f"{p}: shape {tuple(arr.shape)} does not match "
                    f"meta.json (expected {want}) — store is corrupt")
            if _HAVE_MADVISE:
                mm = getattr(arr, "_mmap", None)
                if mm is not None:
                    # random-access views: don't let a cold fault pull a
                    # ~128 KB readahead cluster per touched row
                    mm.madvise(_mmap.MADV_RANDOM)
        return self._views[key]

    def _feat_file(self) -> Tuple[int, int]:
        """(fd, data offset) of features.npy for pread-based gathers."""
        self._check_open()
        if self._feat_fd < 0:
            p = os.path.join(self.path, "features.npy")
            nbytes = self._base_n * self.feat_dim * 4
            off = os.path.getsize(p) - nbytes
            if off <= 0:
                raise ValueError(f"{p}: expected {nbytes} bytes of "
                                 f"float32 data after the .npy header")
            self._feat_fd = os.open(p, os.O_RDONLY)
            self._feat_off = off
        return self._feat_fd, self._feat_off

    def _materialize_mutable(self):
        """First mutation: the CSR/degree/label views move to RAM copies
        (O(E) — mutation on an MmapStore is meant for inductive serving
        tests and modest deltas, not for rewriting a 1e7-node graph).
        FEATURES stay on disk: appended nodes' rows live in an in-RAM
        overlay consumed by `gather_features`, so the dominant byte cost
        keeps its streaming behavior. The on-disk files are never
        touched (and `verify()` still checks them)."""
        own = {"row_ptr": np.array(self._load("row_ptr"), np.int64),
               "col_idx": np.array(self._load("col_idx"), np.int32),
               "degrees": np.array(self._load("degrees"), np.int64)}
        lab = self._load("labels")
        own["labels"] = None if lab is None else np.array(lab, np.int32)
        return own

    def _append_features(self, feats, labs):
        own = self._mutable()
        extra = self.__dict__.get("_extra_feat")
        self.__dict__["_extra_feat"] = (
            feats if extra is None else np.concatenate([extra, feats]))
        if own["labels"] is not None:
            own["labels"] = np.concatenate([own["labels"], labs])

    def gather_features(self, nodes: np.ndarray) -> np.ndarray:
        nodes = np.atleast_1d(np.asarray(nodes)).astype(np.int64,
                                                        copy=False)
        if self.n > self._base_n:
            # appended-node overlay: split the gather, base rows from
            # disk, overlay rows from RAM, reassembled in `nodes` order
            is_new = nodes >= self._base_n
            if is_new.any():
                out = np.empty((len(nodes), self.feat_dim), np.float32)
                out[is_new] = \
                    self._extra_feat[nodes[is_new] - self._base_n]
                old = ~is_new
                if old.any():
                    out[old] = self._gather_base(nodes[old])
                return out
        return self._gather_base(nodes)

    def _gather_base(self, nodes: np.ndarray) -> np.ndarray:
        if self._mmap_mode is None:
            return np.asarray(self._load("features")[nodes])
        row = self.feat_dim * 4
        fd, base = self._feat_file()
        out = np.empty((len(nodes), self.feat_dim), np.float32)
        flat = memoryview(out).cast("B")
        # one preadv per run of consecutive node ids (support node lists
        # are sorted, so runs do occur on smaller graphs)
        k = len(nodes)
        bounds = np.nonzero(np.diff(nodes) != 1)[0] + 1
        edges = np.concatenate(([0], bounds, [k]))
        for b in range(len(edges) - 1):
            i, j = int(edges[b]), int(edges[b + 1])
            self._pread_full(fd, flat[i * row:j * row],
                             base + int(nodes[i]) * row, int(nodes[i]))
        self._touched_est += k * row
        if self._touched_est >= self.resident_budget:
            self.drop_resident()
        return out

    def _pread_full(self, fd: int, view, offset: int, first_row: int) -> None:
        """Fill `view` from `offset`, retrying transient short reads /
        EINTR-class OS errors with bounded exponential backoff. A read
        that still cannot complete raises a typed `StoreIOError` — the
        caller (engine) treats that as a batch-level failure, not a
        process-level one."""
        want = len(view)
        got = 0
        attempts = self.io_retries
        backoff = self.io_backoff_s
        last_err: Optional[OSError] = None
        while True:
            try:
                nread = os.preadv(fd, [view[got:]], offset + got)
            except OSError as e:
                nread, last_err = 0, e
            if nread > 0:
                got += nread
            if got >= want:
                return
            if attempts <= 0:
                raise StoreIOError(
                    f"{self.path}/features.npy: short read at row "
                    f"{first_row} ({got}/{want} bytes) after "
                    f"{self.io_retries} retries") from last_err
            attempts -= 1
            time.sleep(backoff)
            backoff *= 2.0

    def drop_resident(self) -> int:
        """Drop the mapped views' resident pages back to the page cache
        (``MADV_DONTNEED``): process RSS shrinks, the sampler's next
        walk minor-faults the pages back without disk I/O. Returns the
        gathered-bytes estimate that was outstanding."""
        est, self._touched_est = self._touched_est, 0
        if not _HAVE_MADVISE or self._mmap_mode is None:
            return 0
        for arr in self._views.values():
            mm = getattr(arr, "_mmap", None)
            if mm is not None:
                mm.madvise(_mmap.MADV_DONTNEED)
        return est

    def close(self) -> None:
        """Close the feature fd and drop the mapped views. Idempotent;
        any later array access raises (the store is not reopenable).
        Engines and benches call this deterministically — `__del__` is
        only the GC backstop for stores that escape a `with` block."""
        if getattr(self, "_closed", False):
            return
        self._closed = True
        fd = getattr(self, "_feat_fd", -1)
        self._feat_fd = -1
        if fd >= 0:
            try:
                os.close(fd)
            except OSError:
                pass
        # dropping our references unmaps the views once no caller holds
        # one; live external views stay valid (mmap refcounts the map)
        self._views.clear()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    @property
    def row_ptr(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["row_ptr"] if own is not None else self._load("row_ptr")

    @property
    def col_idx(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["col_idx"] if own is not None else self._load("col_idx")

    @property
    def features(self) -> np.ndarray:
        """The on-disk (base) feature view — appended nodes' rows are NOT
        in it; `gather_features` is the mutation-aware read path."""
        return self._load("features")

    @property
    def degrees(self) -> np.ndarray:
        own = self.__dict__.get("_own")
        return own["degrees"] if own is not None else self._load("degrees")

    @property
    def labels(self) -> Optional[np.ndarray]:
        own = self.__dict__.get("_own")
        return own["labels"] if own is not None else self._load("labels")


def as_store(obj) -> GraphStore:
    """Normalize a `GraphStore` | `Graph` argument to a store.

    A raw `Graph` is wrapped in an `InMemoryStore` memoized ON the graph
    object, so repeated calls (one per served batch) reuse the cached
    degree metadata and sampler scratch instead of recounting. This is
    the supported zero-copy convenience for in-RAM graphs (engine /
    distributed entry points); `sample_support` itself is store-first
    and rejects raw Graphs — the PR-7 deprecation shim (and its
    warn-once machinery) was retired in PR 10."""
    if isinstance(obj, GraphStore):
        return obj
    if isinstance(obj, Graph):
        store = obj.__dict__.get("_store_cache")
        if store is None:
            store = InMemoryStore(obj)
            obj.__dict__["_store_cache"] = store
        return store
    raise TypeError(f"expected a GraphStore or Graph, got "
                    f"{type(obj).__name__}")


def save_graph_store(g: Graph, path: str) -> str:
    """Persist a `Graph` as a store directory. The saved `row_ptr` /
    `col_idx` are exactly `Graph.csr()`'s arrays, so an `MmapStore` of
    the result is bit-identical to `InMemoryStore(g)` — the property the
    store parity tests pin."""
    os.makedirs(path, exist_ok=True)
    row_ptr, col_idx = g.csr()
    np.save(os.path.join(path, "row_ptr.npy"),
            np.asarray(row_ptr, np.int64))
    np.save(os.path.join(path, "col_idx.npy"),
            np.asarray(col_idx, np.int32))
    np.save(os.path.join(path, "features.npy"),
            np.asarray(g.features, np.float32))
    np.save(os.path.join(path, "degrees.npy"), np.asarray(g.degrees))
    np.save(os.path.join(path, "labels.npy"), np.asarray(g.labels, np.int32))
    meta = {"format": FORMAT, "name": g.name, "n": int(g.n),
            "feat_dim": int(g.features.shape[1]),
            "num_classes": int(g.num_classes),
            "num_edges": int(g.num_edges),
            "num_self_loops": int(g.num_self_loops),
            "checksums": _store_checksums(path)}
    with open(os.path.join(path, "meta.json"), "w") as fh:
        json.dump(meta, fh, indent=1)
        fh.write("\n")
    return path


# ------------------------------------------------------------ generator
_CHUNK_ROWS = 1 << 17      # fixed chunk size => chunked and in-RAM
                           # generation are bit-identical under one seed


def _powerlaw_degrees(rng: np.random.Generator, n: int, avg_deg: float,
                      alpha: float, max_deg: int) -> np.ndarray:
    """In-degree sequence: Pareto(alpha - 1) tail rescaled to hit
    `avg_deg` in expectation, clipped to [1, max_deg]."""
    w = rng.pareto(max(alpha - 1.0, 0.05), n) + 1.0
    deg = np.maximum(np.rint(w * (avg_deg / w.mean())), 1.0)
    return np.minimum(deg, max_deg).astype(np.int64)


def make_graph(n: int, avg_deg: float = 16.0, alpha: float = 2.2,
               seed: int = 0, *, path: Optional[str] = None,
               feat_dim: int = 64, num_classes: int = 16,
               max_deg: Optional[int] = None,
               name: Optional[str] = None) -> GraphStore:
    """Synthetic power-law graph at store scale, deterministic under
    `seed` (one `np.random.Generator`, fixed chunk boundaries).

    Per-node in-degrees follow a clipped Pareto tail with exponent
    `alpha` (hub rows exist but are bounded by `max_deg`, default
    ``32 * avg_deg`` — frontier expansion through a hub stays
    support-sized, the same reason production samplers cap fan-in).
    Neighbor ids are uniform, each row carries its self loop (stored
    LAST, matching `repro.gnn.graph.add_self_loops` + CSR order), and
    features are class prototypes + noise so classification is
    non-degenerate.

    ``path=None`` materializes in RAM and returns an `InMemoryStore`
    (small-n tests); with ``path`` set every O(n)/O(E) array streams to
    ``.npy`` in fixed-size chunks — peak generator memory is
    O(n) int64 scratch plus one chunk, never the feature matrix — and
    the result is the `MmapStore` of that directory."""
    if n < 2:
        raise ValueError(f"need n >= 2 nodes, got {n}")
    if seed is None:
        raise ValueError("make_graph requires an explicit integer seed "
                         "(bench graphs must be reproducible across "
                         "processes)")
    rng = np.random.default_rng(seed)
    max_deg = int(max_deg if max_deg is not None
                  else max(64, 32 * avg_deg))
    max_deg = min(max_deg, n - 1)
    deg = _powerlaw_degrees(rng, n, avg_deg, alpha, max_deg)
    counts = deg + 1                        # + the self loop, stored last
    row_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    total = int(row_ptr[-1])

    if path is not None:
        os.makedirs(path, exist_ok=True)

        def _open(key, shape, dtype):
            return np.lib.format.open_memmap(
                os.path.join(path, f"{key}.npy"), mode="w+",
                dtype=dtype, shape=shape)
    else:
        def _open(key, shape, dtype):
            return np.zeros(shape, dtype)

    col_idx = _open("col_idx", (total,), np.int32)
    # neighbors chunked by node range: uniform sources drawn from
    # [0, n-1) and shifted past the row's own id (EXACTLY one self loop
    # per row, stored last — accidental loops would desync the
    # store-build degree metadata from a recount), duplicates allowed
    # (multi-edges, like any sampled graph)
    for lo in range(0, n, _CHUNK_ROWS):
        hi = min(lo + _CHUNK_ROWS, n)
        k = int(row_ptr[hi] - row_ptr[lo])
        rows = np.repeat(np.arange(lo, hi, dtype=np.int64),
                         counts[lo:hi])
        span = rng.integers(0, n - 1, size=k, dtype=np.int64)
        span += span >= rows
        # overwrite each row's last slot with the self loop
        ends = (row_ptr[lo + 1:hi + 1] - row_ptr[lo] - 1).astype(np.int64)
        span[ends] = np.arange(lo, hi, dtype=np.int64)
        col_idx[int(row_ptr[lo]):int(row_ptr[hi])] = \
            span.astype(np.int32)

    labels = _open("labels", (n,), np.int32)
    protos = rng.standard_normal((num_classes, feat_dim)).astype(np.float32)
    features = _open("features", (n, feat_dim), np.float32)
    for lo in range(0, n, _CHUNK_ROWS):
        hi = min(lo + _CHUNK_ROWS, n)
        lab = rng.integers(0, num_classes, size=hi - lo).astype(np.int32)
        labels[lo:hi] = lab
        noise = rng.standard_normal((hi - lo, feat_dim)).astype(np.float32)
        features[lo:hi] = protos[lab] + 1.5 * noise

    degrees = _open("degrees", (n,), np.int64)
    degrees[:] = deg
    name = name or f"powerlaw-n{n}-d{avg_deg:g}-a{alpha:g}-s{seed}"
    # undirected-m convention of Graph.num_edges: (stored - loops) // 2
    num_edges = (total - n) // 2

    if path is None:
        src = np.asarray(col_idx, np.int32)
        dst = np.repeat(np.arange(n, dtype=np.int64),
                        counts).astype(np.int32)
        g = Graph(n=n, src=src, dst=dst,
                  features=features, labels=labels,
                  num_classes=num_classes,
                  train_idx=np.empty(0, np.int32),
                  unlabeled_idx=np.empty(0, np.int32),
                  test_idx=np.arange(n, dtype=np.int32), name=name)
        return as_store(g)

    np.save(os.path.join(path, "row_ptr.npy"), row_ptr)
    for arr in (col_idx, labels, features, degrees):
        arr.flush()
    del col_idx, labels, features, degrees
    meta = {"format": FORMAT, "name": name, "n": int(n),
            "feat_dim": int(feat_dim), "num_classes": int(num_classes),
            "num_edges": int(num_edges), "num_self_loops": int(n),
            "generator": {"avg_deg": float(avg_deg), "alpha": float(alpha),
                          "seed": int(seed), "max_deg": int(max_deg)},
            "checksums": _store_checksums(path)}
    with open(os.path.join(path, "meta.json"), "w") as fh:
        json.dump(meta, fh, indent=1)
        fh.write("\n")
    return MmapStore(path)


def _main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="Generate a power-law graph store on disk "
                    "(the serving bench runs this in a subprocess so "
                    "generation never pollutes the serving process's "
                    "peak RSS).")
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--avg-deg", type=float, default=16.0)
    ap.add_argument("--alpha", type=float, default=2.2)
    ap.add_argument("--seed", type=int, required=True)
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--num-classes", type=int, default=16)
    ap.add_argument("--max-deg", type=int, default=None)
    ap.add_argument("--out", required=True, help="store directory")
    args = ap.parse_args(argv)
    store = make_graph(args.n, args.avg_deg, args.alpha, args.seed,
                       path=args.out, feat_dim=args.feat_dim,
                       num_classes=args.num_classes, max_deg=args.max_deg)
    print(f"wrote {store!r} -> {args.out}")


if __name__ == "__main__":
    _main()
