"""Launchers: mesh construction, multi-pod dry-run, training, serving,
roofline extraction. NOTE: repro.launch.dryrun force-sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import — never
import it from tests or benches that need the real single-device CPU."""
