from repro.serving.engine import (EngineConfig, EngineStats,
                                  NAIServingEngine, Request)
from repro.serving.faults import (FaultPlan, FaultSpec, FaultyStore,
                                  InjectedFault, NaNGuardError,
                                  WatchdogTimeout)
from repro.serving.frontend import (BreakerConfig, CircuitBreaker,
                                    ClassStats, ServingFrontend, SLOClass,
                                    default_slo_classes)
from repro.serving.lm_engine import LMRequest, LMServingEngine

__all__ = ["EngineConfig", "EngineStats", "NAIServingEngine", "Request",
           "FaultPlan", "FaultSpec", "FaultyStore", "InjectedFault",
           "NaNGuardError", "WatchdogTimeout",
           "BreakerConfig", "CircuitBreaker",
           "ClassStats", "ServingFrontend", "SLOClass",
           "default_slo_classes", "LMRequest", "LMServingEngine"]
